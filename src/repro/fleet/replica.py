"""EngineReplica: an RPC-shaped wrapper around one ``ContinuousEngine``
with a health state machine (docs/serving.md "Fleet").

The router talks to replicas through this narrow interface only —
``submit`` / ``step`` / ``cancel`` / ``result`` / ``first_token_seen`` /
``salvage`` / ``drain`` / ``stats`` plus the ``state`` / ``load`` /
``max_seq`` properties — so a host-side fake (tests) or a remote stub
(the ROADMAP's disaggregation item) drops in without router changes.

Health state machine::

    HEALTHY ──anomaly / step timeout──▶ DEGRADED
    DEGRADED ──recover_after clean steps──▶ HEALTHY
    DEGRADED/HEALTHY ──down_after consecutive timeouts──▶ DOWN   (hung)
    any ──exception in step / injected crash──▶ DOWN             (crashed)

Signals: dispatch heartbeats (wall time of each ``step`` call — a hang
fault or a wedged device program shows up as a step timeout),
``engine.anomalies`` (NaN/Inf-guard trips), SLO watchdog alerts (the
``slo.alerts`` counter an ``obs.slo.SloWatchdog`` bound to this
replica's registry bumps — sustained quality burn degrades the replica
the same way an anomaly does), and a consecutive-timeout counter.  DOWN
is terminal: the replica refuses further work and the router calls
``salvage()`` exactly once to recover its in-flight state.

``salvage`` reads the engine's host-side scheduler state (queue entries,
running slots' generated tokens, unconsumed terminal results).  In this
in-process reproduction that read is direct; over a real RPC boundary the
same information is the recovery log a control plane replays.  The dead
replica's device pool is abandoned — pool-restoration invariants apply to
SURVIVORS (the fleet chaos suite asserts exactly that).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..serve.scheduler import REJECTED

HEALTHY = "HEALTHY"
DEGRADED = "DEGRADED"
DOWN = "DOWN"

# numeric encoding for the replica.health gauge (telemetry only)
_HEALTH_LEVEL = {HEALTHY: 0.0, DEGRADED: 1.0, DOWN: 2.0}


@dataclasses.dataclass
class LostRequest:
    """One in-flight request recovered from a dead replica.

    ``resume_tokens`` is everything the replica had generated (queue
    resume state or a running slot's token list) — the router migrates the
    request to a survivor by resubmitting with these tokens, which
    recompute-prefill teacher-forces so greedy decode continues
    token-identically."""
    request: object
    resume_tokens: List[int]
    preemptions: int
    local_order: int


@dataclasses.dataclass
class Salvage:
    """Everything ``salvage()`` recovers: unconsumed terminal results
    (keyed by the replica-local order) and the lost in-flight requests."""
    results: Dict[int, Dict]
    lost: List[LostRequest]


class EngineReplica:
    """One engine behind the fleet interface, with health tracking.

    ``step_timeout_s`` is the dispatch-heartbeat bound: a ``step`` call
    exceeding it counts as a timeout (DEGRADED), and ``down_after``
    consecutive timeouts mark the replica DOWN (hung).  Any exception out
    of the engine — or an injected ``crash_p`` fault — is an immediate
    crash (DOWN).  ``recover_after`` consecutive clean steps return a
    DEGRADED replica to HEALTHY.  ``clock`` is injectable for
    deterministic tests.
    """

    def __init__(self, name: str, engine, *, faults=None,
                 step_timeout_s: float = 5.0, down_after: int = 3,
                 recover_after: int = 5,
                 clock: Callable[[], float] = time.perf_counter):
        self.name = str(name)
        self.engine = engine
        self.faults = faults
        self.step_timeout_s = float(step_timeout_s)
        self.down_after = int(down_after)
        self.recover_after = int(recover_after)
        self.clock = clock
        self.state = HEALTHY
        self.down_reason: Optional[str] = None
        self.salvaged = False
        self.last_heartbeat_s: Optional[float] = None
        self.consecutive_timeouts = 0
        self._clean_steps = 0
        self._last_anomalies = 0
        # arrival/deadline stamps arrive router-relative; a warmed engine's
        # serve clock would read them as seconds in the past
        reset = getattr(engine, "reset_serve_clock", None)
        if reset is not None:
            reset()
        # health telemetry rides the engine's (replica-scoped) registry
        reg = engine.obs.registry
        self._g_health = reg.gauge("replica.health")
        self._g_health.set(_HEALTH_LEVEL[HEALTHY])
        self._c_timeouts = reg.counter("replica.step_timeouts")
        self._c_crashes = reg.counter("replica.crashes")
        # SLO consumption: any watchdog bound to this registry bumps
        # labelled slo.alerts counters; the replica folds their SUM so a
        # sustained quality burn (drift, agreement, clip rate) degrades it
        # exactly like a NaN-guard anomaly would
        self._reg = reg
        self._last_slo_alerts = self._slo_alerts()

    # -- properties the router keys on ------------------------------------
    @property
    def live(self) -> bool:
        return self.state != DOWN

    @property
    def load(self) -> int:
        """Join-shortest-queue key: queued + running requests."""
        sched = self.engine.scheduler
        return sched.queue_depth + len(sched.running)

    @property
    def max_seq(self) -> Optional[int]:
        return getattr(self.engine, "max_seq", None)

    # -- request lifecycle -------------------------------------------------
    def submit(self, request, arrival_s: float = 0.0,
               resume_tokens: Optional[Sequence[int]] = None,
               preemptions: int = 0) -> Tuple[int, bool]:
        """Place one request; returns ``(local_order, accepted)``.

        A locally-REJECTED submission (bounded queue / draining) is a
        TRANSIENT placement failure at fleet level — the immediate
        REJECTED result the engine materialized is consumed here so the
        router can retry on another replica without leaking a terminal."""
        if not self.live:
            return -1, False
        order = self.engine.submit(request, arrival_s,
                                   resume_tokens=resume_tokens,
                                   preemptions=preemptions)
        res = self.engine.result(order)
        if res is not None and res["status"] == REJECTED:
            self.engine.result(order, pop=True)
            return order, False
        return order, True

    def step(self) -> bool:
        """One engine scheduler round, fenced by the health machine.
        Returns True if the engine made progress; a DOWN replica is inert."""
        if not self.live:
            return False
        if self.faults is not None and self.faults.maybe_crash():
            self._crash("injected crash")
            return False
        t0 = self.clock()
        hang = (self.faults.hang_delay() if self.faults is not None else 0.0)
        if hang > 0.0:
            time.sleep(hang)               # injected wedge: heartbeat stalls
        try:
            progress = bool(self.engine.step())
        except Exception as e:             # a real fault, not an injected one
            self._crash(f"engine.step raised: {e!r}")
            return False
        t1 = self.clock()
        self.last_heartbeat_s = t1
        anomalies = self.engine.anomalies
        anomaly_delta = anomalies - self._last_anomalies
        self._last_anomalies = anomalies
        slo_alerts = self._slo_alerts()
        slo_delta = slo_alerts - self._last_slo_alerts
        self._last_slo_alerts = slo_alerts
        timed_out = (t1 - t0) > self.step_timeout_s
        if timed_out:
            self._c_timeouts.inc()
            self.consecutive_timeouts += 1
            if self.consecutive_timeouts >= self.down_after:
                self._mark_down(f"hung: {self.consecutive_timeouts} "
                                f"consecutive step timeouts "
                                f"(> {self.step_timeout_s}s)")
                return progress
            self._degrade()
        elif anomaly_delta > 0 or slo_delta > 0:
            self.consecutive_timeouts = 0
            self._degrade()
        else:
            self.consecutive_timeouts = 0
            if self.state == DEGRADED:
                self._clean_steps += 1
                if self._clean_steps >= self.recover_after:
                    self.state = HEALTHY
                    self._g_health.set(_HEALTH_LEVEL[HEALTHY])
        return progress

    def _slo_alerts(self) -> float:
        """Sum of every ``slo.alerts*`` counter in the replica registry
        (the watchdog labels per rule/severity; health folds the total)."""
        total = 0.0
        for fname, m in self._reg.items():
            if fname.startswith("slo.alerts"):
                total += m.value
        return total

    def cancel(self, request_id) -> bool:
        if not self.live:
            return False
        return self.engine.cancel(request_id)

    def result(self, local_order: int, pop: bool = False) -> Optional[Dict]:
        return self.engine.result(local_order, pop=pop)

    def first_token_seen(self, local_order: int) -> bool:
        """Has this request streamed its first token here?  The hedging
        trigger.  Reads the engine's live trace when obs is enabled; with
        obs disabled hedging falls back to terminal-result absence."""
        tr = self.engine._traces.get(local_order)
        if tr is not None:
            return tr.first_token_s is not None
        return self.engine.result(local_order) is not None

    def drain(self) -> List[Dict]:
        if not self.live:
            return []
        return self.engine.drain()

    # -- failure + recovery ------------------------------------------------
    def force_crash(self, reason: str = "forced crash") -> None:
        """Deterministic kill switch (the fleet chaos suite's mid-serving
        replica kill)."""
        self._crash(reason)

    def _crash(self, reason: str) -> None:
        self._c_crashes.inc()
        self._mark_down(reason)

    def _mark_down(self, reason: str) -> None:
        if self.state == DOWN:
            return
        self.state = DOWN
        self.down_reason = reason
        self._g_health.set(_HEALTH_LEVEL[DOWN])

    def _degrade(self) -> None:
        self._clean_steps = 0
        if self.state == HEALTHY:
            self.state = DEGRADED
            self._g_health.set(_HEALTH_LEVEL[DEGRADED])

    def salvage(self) -> Salvage:
        """Recover a DOWN replica's in-flight state, exactly once.

        Returns unconsumed terminal results plus a ``LostRequest`` per
        queued entry (fresh or resume), doomed entry, and running slot —
        running slots contribute their generated tokens as resume state.
        The engine is left inert; its device pool is abandoned."""
        if self.state != DOWN:
            raise RuntimeError(f"salvage on {self.state} replica "
                               f"{self.name!r}: only DOWN replicas salvage")
        if self.salvaged:
            return Salvage({}, [])
        self.salvaged = True
        eng = self.engine
        results = dict(eng._results)
        eng._results.clear()
        lost: List[LostRequest] = []
        sched = eng.scheduler
        for entry in list(sched.queue):
            lost.append(LostRequest(entry.request,
                                    list(entry.resume_tokens),
                                    entry.preemptions, entry.order))
        sched.queue.clear()
        for entry in sched.drain_doomed():
            lost.append(LostRequest(entry.request,
                                    list(entry.resume_tokens),
                                    entry.preemptions, entry.order))
        for slot in sched.running:
            lost.append(LostRequest(slot.request, list(slot.tokens),
                                    slot.preemptions, slot.order))
        sched.close_intake()
        lost.sort(key=lambda l: l.local_order)
        return Salvage(results, lost)

    # -- telemetry ---------------------------------------------------------
    def stats(self) -> Dict:
        st = {
            "name": self.name,
            "state": self.state,
            "down_reason": self.down_reason,
            "load": self.load,
            "consecutive_timeouts": self.consecutive_timeouts,
            "step_timeouts": int(self._c_timeouts.value),
            "crashes": int(self._c_crashes.value),
            "slo_alerts": int(self._slo_alerts()),
            "last_heartbeat_s": self.last_heartbeat_s,
        }
        st["engine"] = self.engine.stats()
        return st
