"""repro.fleet — replicated serving: health-checked engine replicas behind
a failover router (docs/serving.md "Fleet").

The paper's hardware half scales through hierarchical control — one
top-level controller steering many identical PE blocks.  At serving scale
the analogue is a fleet of ``ContinuousEngine`` replicas behind a
``Router``: join-shortest-queue placement over healthy replicas, hedged
requests for tail latency, and — the hard part — crash failover that
migrates every lost in-flight request to a survivor via recompute-prefill
(the same teacher-forcing mechanism local preemption uses), so greedy
outputs stay token-identical to the B=1 oracle across a replica death.

``EngineReplica`` is the RPC-shaped seam: everything the router needs is
behind submit/step/cancel/result/salvage/drain/stats, so the ROADMAP's
disaggregated prefill/decode split can swap a remote stub in without
touching router logic.
"""
from .replica import (DEGRADED, DOWN, HEALTHY, EngineReplica, LostRequest,
                      Salvage)
from .router import Router

__all__ = ["EngineReplica", "Router", "LostRequest", "Salvage",
           "HEALTHY", "DEGRADED", "DOWN"]
