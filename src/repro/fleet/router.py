"""Fleet router: placement, retries, hedging, and crash failover over a
set of ``EngineReplica``s (docs/serving.md "Fleet").

The router is the fleet's single intake.  Each ``step()`` runs one
control round::

    1. step every live replica (their engines run one scheduler round)
    2. collect terminal results from every live leg (first winner settles;
       a hedge loser is cancelled and its late result discarded)
    3. fail over replicas that went DOWN this round: salvage their
       in-flight requests and re-enqueue them for migration — resubmitted
       to a survivor with ``resume_tokens``, so recompute-prefill keeps
       greedy outputs token-identical to the B=1 oracle
    4. hedge requests whose primary leg has not produced a first token
       within the TTFT threshold (explicit ``hedge_after_s`` or
       ``hedge_p99_mult`` x the fleet's observed p99 TTFT)
    5. place pending requests (join-shortest-queue over HEALTHY replicas,
       DEGRADED as fallback), retrying refused placements with capped
       exponential backoff + seeded jitter, and shedding as REJECTED —
       deadline-doomed first, then lowest-priority-youngest — whenever the
       bounded pending buffer overflows (graceful degradation: the router
       never queues unboundedly)

Every submitted request settles in EXACTLY ONE terminal status at fleet
level, even when both legs of a hedged request or a crashed replica's
salvage race to deliver results — ``_settle`` is the single guarded entry
to the terminal map, and the fleet chaos suite (serve/faults.py
``run_fleet_chaos``) asserts the invariant under seeded kills.

Telemetry: ``fleet.*`` counters/gauges in the (unscoped) router registry;
per-replica series carry the ``replica=`` label via each engine's scoped
Obs view.  ``clock`` is injectable so the state-machine tests drive
backoff and hedge timers on a virtual clock.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import Obs
from ..serve.scheduler import FAILED, REJECTED, TERMINAL_STATUSES
from .replica import DOWN, HEALTHY

POLICIES = ("jsq", "round_robin")


@dataclasses.dataclass
class _FleetRequest:
    """Router-side state for one in-flight fleet request."""
    order: int
    request: object
    arrival_s: float
    deadline_s: Optional[float]               # absolute on the router clock
    resume_tokens: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0
    migrations: int = 0
    hedged: bool = False
    legs: List[Tuple[object, int]] = dataclasses.field(default_factory=list)
    first_placed_s: Optional[float] = None    # hedge timer origin
    retries: int = 0
    next_try_s: float = 0.0


class Router:
    """Health-aware load balancer + failover controller over replicas.

    ``replicas`` need only the ``EngineReplica`` interface (see
    fleet/replica.py) — the state-machine tests drive the router with
    host-only fakes.  ``max_pending`` bounds the router-side buffer of
    unplaced requests (default ``32 * len(replicas)``); overflow sheds.
    """

    def __init__(self, replicas: Sequence, *, policy: str = "jsq",
                 hedge_after_s: Optional[float] = None,
                 hedge_p99_mult: float = 4.0, hedge_min_s: float = 0.05,
                 hedge_min_samples: int = 8,
                 backoff_base_s: float = 0.002, backoff_cap_s: float = 0.1,
                 max_pending: Optional[int] = None, seed: int = 0,
                 obs: Optional[Obs] = None,
                 clock: Callable[[], float] = time.perf_counter):
        if not replicas:
            raise ValueError("Router needs at least one replica")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"replica names must be unique: {names}")
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r}: expected one of {POLICIES}")
        self.replicas = list(replicas)
        self.policy = policy
        self.hedge_after_s = hedge_after_s
        self.hedge_p99_mult = float(hedge_p99_mult)
        self.hedge_min_s = float(hedge_min_s)
        self.hedge_min_samples = int(hedge_min_samples)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.max_pending = (32 * len(self.replicas) if max_pending is None
                            else int(max_pending))
        self.clock = clock
        self._t0: Optional[float] = None
        self._rng = np.random.RandomState(seed)
        self._rr = 0                           # round_robin cursor
        self.intake_closed = False
        self.obs = obs if obs is not None else Obs()
        self._order = 0
        self._states: Dict[int, _FleetRequest] = {}
        self._results: Dict[int, Dict] = {}
        self._pending: List[_FleetRequest] = []
        # (replica name, local order) -> fleet order, one entry per live leg
        self._leg_index: Dict[Tuple[str, int], int] = {}
        # legs of settled requests still owed a (discarded) result
        self._zombies: List[Tuple[object, int]] = []
        reg = self.obs.registry
        self._c_submitted = reg.counter("fleet.submitted")
        self._c_placed = reg.counter("fleet.placed")
        self._c_retries = reg.counter("fleet.place_retries")
        self._c_hedges = reg.counter("fleet.hedges")
        self._c_hedge_wins = {
            "primary": reg.counter("fleet.hedge_wins", leg="primary"),
            "hedge": reg.counter("fleet.hedge_wins", leg="hedge"),
        }
        self._c_failovers = reg.counter("fleet.failovers")
        self._c_migrated = reg.counter("fleet.migrated_requests")
        self._c_shed = {
            "deadline": reg.counter("fleet.shed", reason="deadline"),
            "overflow": reg.counter("fleet.shed", reason="overflow"),
            "no_live_replicas": reg.counter("fleet.shed",
                                            reason="no_live_replicas"),
        }
        self._c_term = {s: reg.counter("fleet.terminal", status=s)
                        for s in TERMINAL_STATUSES}
        self._h_ttft = reg.histogram("fleet.ttft_s")
        self._h_resume = reg.histogram(
            "fleet.migrated_resume_tokens",
            bounds=tuple(float(2 ** e) for e in range(11)))
        self._g_pending = reg.gauge("fleet.pending_depth")
        self._g_live = reg.gauge("fleet.replicas_live")
        self._g_live.set(len(self.replicas))

    # -- clock -------------------------------------------------------------
    def now(self) -> float:
        """Seconds on the router clock (0 at the first submit)."""
        if self._t0 is None:
            self._t0 = self.clock()
        return self.clock() - self._t0

    # -- intake ------------------------------------------------------------
    def submit(self, request, arrival_s: float = 0.0) -> int:
        """Queue one request with the fleet; returns its FLEET order (the
        key for ``result``).  Closed intake rejects immediately — like the
        engines, callers never lose a request."""
        for r in self.replicas:
            ms = r.max_seq
            if ms is not None and len(request.prompt) > ms:
                raise ValueError(f"prompt length {len(request.prompt)} "
                                 f"exceeds fleet max_seq {ms}")
        now = self.now()
        order = self._order
        self._order += 1
        self._c_submitted.inc()
        rel = getattr(request, "deadline_s", None)
        st = _FleetRequest(
            order=order, request=request, arrival_s=float(arrival_s),
            deadline_s=None if rel is None else float(arrival_s) + float(rel))
        if self.intake_closed:
            self._settle_unserved(st, REJECTED, shed_reason=None,
                                  register=False)
            return order
        self._states[order] = st
        self._pending.append(st)
        self._enforce_pending_bound(now)
        if order in self._states:       # may have been shed by the bound
            self._try_place_pending(now)
        return order

    def result(self, order: int, pop: bool = False) -> Optional[Dict]:
        """Fleet-level terminal result (None while in flight).  Results
        carry the engine schema plus ``replica`` (the winning replica, None
        for router-shed requests) and ``migrations``."""
        return (self._results.pop(order, None) if pop
                else self._results.get(order))

    def cancel(self, request_id) -> bool:
        """Cancel wherever the request lives: pending here, or on every
        replica currently holding a leg."""
        for st in list(self._states.values()):
            if st.request.id != request_id:
                continue
            if not st.legs:                     # pending at the router
                self._settle_unserved(st, "CANCELLED", shed_reason=None)
                return True
            return any(replica.cancel(request_id)
                       for replica, _ in st.legs)
        return False

    # -- control loop ------------------------------------------------------
    def step(self) -> bool:
        """One fleet control round; returns True if anything progressed."""
        now = self.now()
        progress = False
        for r in self.replicas:
            if r.state != DOWN:
                if r.step():
                    progress = True
        if self._collect(now):
            progress = True
        for r in self.replicas:
            if r.state == DOWN and not r.salvaged:
                self._failover(r, now)
                progress = True
        if self._maybe_hedge(now):
            progress = True
        self._try_place_pending(self.now())
        self._g_live.set(sum(1 for r in self.replicas if r.state != DOWN))
        self._g_pending.set(len(self._pending))
        return progress

    def generate(self, reqs: Sequence, arrival_times=None) -> List[Dict]:
        """Serve a workload to completion (the fleet mirror of
        ``ContinuousEngine.generate``); returns results in request order."""
        arr = ([0.0] * len(reqs) if arrival_times is None
               else [float(a) for a in arrival_times])
        orders = [self.submit(r, a) for r, a in zip(reqs, arr)]
        while any(o not in self._results for o in orders):
            if not self.step():
                time.sleep(5e-4)        # waiting on a simulated arrival
        return [self._results.pop(o) for o in orders]

    def drain(self) -> List[Dict]:
        """Close intake, run every in-flight request to a terminal status
        (placement and failover keep working during the drain), then drain
        the surviving replicas and close the shared obs.  Returns results
        that went terminal during the drain."""
        before = set(self._results)
        self.intake_closed = True
        idle_rounds = 0
        while self._states or self._pending:
            if self.step():
                idle_rounds = 0
            else:
                idle_rounds += 1
                if idle_rounds > 10_000:
                    raise RuntimeError(
                        f"fleet drain stall: {len(self._states)} requests "
                        f"cannot make progress")
                time.sleep(5e-4)
        for r in self.replicas:
            if r.state != DOWN:
                r.drain()
        self.obs.close()
        return [self._results[o] for o in sorted(set(self._results) - before)]

    @property
    def idle(self) -> bool:
        return not self._states and not self._pending

    # -- placement ---------------------------------------------------------
    def _candidates(self, exclude: Sequence = ()) -> List:
        """Live replicas eligible for a placement, best-first: HEALTHY
        before DEGRADED (DOWN never serves), ordered by the policy."""
        live = [r for r in self.replicas
                if r.state != DOWN and r not in exclude]
        healthy = [r for r in live if r.state == HEALTHY]
        pool = healthy if healthy else live
        if self.policy == "jsq":
            return sorted(pool, key=lambda r: (r.load, r.name))
        self._rr += 1
        n = len(pool)
        return [pool[(self._rr + i) % n] for i in range(n)] if n else []

    def _place(self, st: _FleetRequest, now: float,
               exclude: Sequence = ()) -> bool:
        """Try every eligible replica once, best-first.  A refusal
        (bounded engine queue, drain, replica died between the health check
        and the submit) moves on to the next candidate."""
        for replica in self._candidates(exclude=exclude):
            local, accepted = replica.submit(
                st.request, arrival_s=st.arrival_s,
                resume_tokens=st.resume_tokens or None,
                preemptions=st.preemptions)
            if accepted:
                st.legs.append((replica, local))
                self._leg_index[(replica.name, local)] = st.order
                if st.first_placed_s is None:
                    st.first_placed_s = now
                self._c_placed.inc()
                return True
        return False

    def _try_place_pending(self, now: float) -> None:
        if not self._pending:
            return
        if all(r.state == DOWN for r in self.replicas):
            # nothing can ever serve these — FAILED beats a silent hang
            for st in list(self._pending):
                self._settle_unserved(st, FAILED,
                                      shed_reason="no_live_replicas")
            self._pending = []
            return
        still: List[_FleetRequest] = []
        # iterate a snapshot: the deadline branch removes from _pending via
        # _settle_unserved, and mutating the live list mid-iteration would
        # skip (and thereby strand) the element after the shed one
        for st in list(self._pending):
            if st.order in self._results:
                continue                       # cancelled / shed meanwhile
            if st.deadline_s is not None and now > st.deadline_s:
                # deadline-doomed while unplaced: graceful degradation
                self._settle_unserved(st, REJECTED, shed_reason="deadline")
                continue
            if now < st.next_try_s:
                still.append(st)
                continue
            if self._place(st, now):
                continue
            st.retries += 1                    # every replica refused
            self._c_retries.inc()
            backoff = min(self.backoff_cap_s,
                          self.backoff_base_s * (2 ** min(st.retries, 10)))
            backoff *= 1.0 + self._rng.random_sample()   # jitter
            st.next_try_s = now + backoff
            still.append(st)
        self._pending = still
        self._g_pending.set(len(self._pending))

    def _enforce_pending_bound(self, now: float) -> None:
        """Shed until the pending buffer fits: deadline-doomed first, then
        fresh before migrated, lowest priority first, youngest first."""
        while len(self._pending) > self.max_pending:
            doomed = [st for st in self._pending
                      if st.deadline_s is not None and now > st.deadline_s]
            pool = doomed if doomed else self._pending
            victim = min(pool, key=lambda st: (
                bool(st.resume_tokens),
                getattr(st.request, "priority", 0),
                -st.order))
            self._pending.remove(victim)
            self._settle_unserved(victim, REJECTED, shed_reason="overflow")

    # -- completion --------------------------------------------------------
    def _collect(self, now: float) -> bool:
        progress = False
        for st in list(self._states.values()):
            for replica, local in list(st.legs):
                res = replica.result(local, pop=True)
                if res is not None:
                    self._settle(st, res, replica, now)
                    progress = True
                    break
        # hedge losers owe a (discarded) CANCELLED result; drop dead legs
        zombies: List[Tuple[object, int]] = []
        for replica, local in self._zombies:
            if replica.state == DOWN:
                continue
            if replica.result(local, pop=True) is None:
                zombies.append((replica, local))
        self._zombies = zombies
        return progress

    def _settle(self, st: _FleetRequest, res: Dict, replica, now: float
                ) -> None:
        """The single guarded entry to the fleet terminal map — exactly
        one result per fleet order, whoever delivers first."""
        if st.order in self._results:
            return
        out = dict(res)
        out["replica"] = replica.name
        out["migrations"] = st.migrations
        self._results[st.order] = out
        self._c_term[out["status"]].inc()
        if st.hedged:
            won = "primary" if (st.legs and st.legs[0][0] is replica) \
                else "hedge"
            self._c_hedge_wins[won].inc()
        q, p = out.get("queue_s"), out.get("prefill_s")
        if q is not None and p is not None:
            self._h_ttft.observe(q + p)
        self._states.pop(st.order, None)
        for other, local in st.legs:
            self._leg_index.pop((other.name, local), None)
            if other is replica:
                continue
            if other.state != DOWN:
                other.cancel(st.request.id)
                self._zombies.append((other, local))
        st.legs = []

    def _settle_unserved(self, st: _FleetRequest, status: str,
                         shed_reason: Optional[str] = "overflow",
                         register: bool = True) -> None:
        """Terminal result for a request the fleet never served (shed,
        rejected at intake, failed with no live replicas)."""
        if register and st.order in self._results:
            return
        res = {
            "id": st.request.id,
            "tokens": list(st.resume_tokens),
            "decode_len": len(st.resume_tokens),
            "status": status,
            "preemptions": st.preemptions,
            "tokens_per_s": 0.0,
            "prefill_s": None,
            "decode_s": 0.0,
            "queue_s": None,
            "latency_s": None,
            "replica": None,
            "migrations": st.migrations,
        }
        self._results[st.order] = res
        self._c_term[status].inc()
        if shed_reason is not None:
            self._c_shed[shed_reason].inc()
        self._states.pop(st.order, None)
        if st in self._pending:
            self._pending.remove(st)

    # -- hedging -----------------------------------------------------------
    def _hedge_threshold(self) -> Optional[float]:
        if self.hedge_after_s is not None:
            return self.hedge_after_s
        if self._h_ttft.count >= self.hedge_min_samples:
            p99 = self._h_ttft.percentile(99)
            if p99 is not None:
                return max(self.hedge_min_s, self.hedge_p99_mult * p99)
        return None

    def _maybe_hedge(self, now: float) -> bool:
        thr = self._hedge_threshold()
        if thr is None:
            return False
        live = sum(1 for r in self.replicas if r.state != DOWN)
        if live < 2:
            return False
        hedged_any = False
        for st in list(self._states.values()):
            if st.hedged or not st.legs or st.first_placed_s is None:
                continue
            if now - st.first_placed_s <= thr:
                continue
            replica, local = st.legs[0]
            if replica.state != DOWN and replica.first_token_seen(local):
                continue
            if self._place(st, now, exclude=[r for r, _ in st.legs]):
                st.hedged = True
                self._c_hedges.inc()
                hedged_any = True
        return hedged_any

    # -- failover ----------------------------------------------------------
    def _failover(self, replica, now: float) -> None:
        """Salvage a DOWN replica: surface its unconsumed terminal results,
        then migrate every lost in-flight request to a survivor via
        resume-token resubmission (recompute-prefill keeps greedy outputs
        token-identical)."""
        salvage = replica.salvage()
        self._c_failovers.inc()
        for local, res in sorted(salvage.results.items()):
            order = self._leg_index.get((replica.name, local))
            st = self._states.get(order) if order is not None else None
            if st is not None:
                self._settle(st, res, replica, now)
        for lost in salvage.lost:
            order = self._leg_index.pop((replica.name, lost.local_order),
                                        None)
            st = self._states.get(order) if order is not None else None
            if st is None:
                continue                # settled by another leg already
            st.legs = [(r, l) for r, l in st.legs if r is not replica]
            if st.legs:
                continue                # a live hedge leg carries on
            if len(lost.resume_tokens) > len(st.resume_tokens):
                st.resume_tokens = list(lost.resume_tokens)
                st.preemptions = lost.preemptions
            st.migrations += 1
            self._c_migrated.inc()
            self._h_resume.observe(len(st.resume_tokens))
            st.hedged = False
            st.first_placed_s = None    # hedge timer restarts on the move
            st.next_try_s = 0.0
            if st not in self._pending:
                self._pending.append(st)
        stale = [k for k in self._leg_index if k[0] == replica.name]
        for k in stale:
            del self._leg_index[k]
        self._enforce_pending_bound(now)

    # -- telemetry ---------------------------------------------------------
    def terminal_counts(self) -> Dict[str, int]:
        return {s: int(c.value) for s, c in self._c_term.items()}

    def stats(self) -> Dict:
        v = self.obs.registry.value
        return {
            "policy": self.policy,
            "replicas": [r.stats() for r in self.replicas],
            "live_replicas": sum(1 for r in self.replicas
                                 if r.state != DOWN),
            "submitted": int(v("fleet.submitted")),
            "placed": int(v("fleet.placed")),
            "place_retries": int(v("fleet.place_retries")),
            "hedges": int(v("fleet.hedges")),
            "hedge_wins": {leg: int(c.value)
                           for leg, c in self._c_hedge_wins.items()},
            "failovers": int(v("fleet.failovers")),
            "migrated_requests": int(v("fleet.migrated_requests")),
            "shed": {reason: int(c.value)
                     for reason, c in self._c_shed.items()},
            "pending_depth": len(self._pending),
            "statuses": self.terminal_counts(),
        }
