"""Distribution subsystem: GSPMD sharding rules + activation-sharding context.

``sharding`` derives PartitionSpecs from parameter path + shape (the rule
engine); ``ctx`` carries the activation policy that models consult at block
boundaries.  Nothing here touches jax device state at import time — the
dry-run must be able to set XLA_FLAGS before first init.
"""
from . import ctx, sharding  # noqa: F401
