"""Mesh-aware sharding rule engine for the 256/512-chip production meshes.

Derives ``jax.sharding.PartitionSpec``s from *parameter path + shape* (plus a
mesh and a named strategy), so models never hard-code a layout.  The engine
only needs duck-typed mesh info (``axis_names`` + ``devices.shape``), which
lets rule derivation run with zero devices (tests, planning tools).

Mesh axes (launch/mesh.py):
  ``("data", "model")`` single pod, ``("pod", "data", "model")`` multi-pod.
  DP/FSDP run over ("pod","data"); TP/EP/SP over "model".

Rule table (see docs/sharding.md for the narrative version):

  path pattern                 shape            spec (strategy="2d")
  ---------------------------  ---------------  --------------------------------
  */{q,k,v,up,gate,...}/w      (in, out)        P(None, ("model","data"))  column
  */{o,down,out}/w             (in, out)        P("model", "data")         row
  */{q,k,v,up,gate,...}/wc     (p, q, k)        P("model", None, "data")   column
  */{o,down,out}/wc            (p, q, k)        P(None, "model", "data")   row
  */experts/{up,gate,down}     (E, ...)         E over "model" (EP) when
                                                divisible, else TP inside the
                                                expert on the block dims
  embed/table                  (V, d)           P(("model","data"), None)
  norm scales / biases / 1-d   (d,)             P()  (replicated)
  stacked/scanned leading dim  (L, ...)         leading dim never sharded

Every placement is guarded by divisibility (a dim is only sharded when the
axis-size product divides it; otherwise the rule falls back down a preference
chain and ultimately replicates), and by RULE ZERO, enforced centrally in
``_derive``: a contraction dimension is NEVER sharded over a data-parallel
axis — that would turn the per-shard matmul into a partial sum over the batch
axis, silently corrupting data parallelism.  TP contractions over "model" are
fine (that is Megatron row parallelism: partial sums + one all-reduce).

Strategies:
  "2d" (alias "megatron")  TP over "model" + FSDP over "data" as above.
  "tokenpar"               weights replicate over "model" (FSDP over "data"
                           only); "model" is reserved for sequence/token
                           parallelism of activations (``batch_spec`` with
                           ``seq_shard=True``).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# Data-parallel axes in nesting order; "pod" only exists on the 512-chip mesh.
DP_AXES = ("pod", "data")
MODEL_AXIS = "model"

# pytree roots whose children carry a stacked/scanned leading dim (params are
# jnp.stack'ed over the scan axis — that dim is structural, never sharded).
STACKED_ROOTS = frozenset({"segments", "enc_blocks", "dec_blocks"})

# Linear names whose *input* dim is the TP-sharded contraction (row parallel).
ROW_LINEAR = frozenset({"o", "down", "out"})

# Leaves that always replicate regardless of shape (tiny position tables).
REPLICATED_LEAVES = frozenset({"pos"})

# Spectral serving-cache planes (serve/params.py): (p, q, kf) real planes of
# rfft(wc), living under a `*_cache` dict next to the generator they mirror —
# they shard exactly like a `wc` of the same projection.
SPECTRAL_PLANES = frozenset({"wr", "wi", "ws1", "ws2"})

# Quantization scales of those planes (repro.quant: `<plane>_s`, (p, 1) per
# block row; experts (E, p, 1)).  Scales shard LIKE THEIR PAYLOAD's sharded
# dims they actually have: the block-row dim takes "model" exactly when the
# payload's block-row dim does (column-parallel projections; row-parallel
# planes model-shard their q dim, which a scale does not have, so row scales
# replicate).  Scales are tiny and never shard over data-parallel axes.
SPECTRAL_SCALES = frozenset({"wr_s", "wi_s", "ws1_s", "ws2_s"})

# Paged-pool quantization scales (serve/kvcache.py int8 pools): one f32 per
# (page, kv-head), leaf names `k_scale`/`v_scale`, shape (..., P, Hkv).
POOL_SCALES = frozenset({"k_scale", "v_scale"})

# Canonical core ranks per leaf kind: extra leading dims are stack dims.
_CORE_RANK = {"wc": 3, "w": 2, "table": 2,
              "wr": 3, "wi": 3, "ws1": 3, "ws2": 3,
              "wr_s": 2, "wi_s": 2, "ws1_s": 2, "ws2_s": 2}

STRATEGIES = {"2d": "2d", "megatron": "2d", "tokenpar": "tokenpar"}


# ---------------------------------------------------------------------------
# Mesh introspection (duck-typed: works on jax.sharding.Mesh and on fakes)
# ---------------------------------------------------------------------------
def axis_sizes(mesh) -> Dict[str, int]:
    """``{axis_name: size}`` from anything with ``axis_names`` + ``devices``."""
    return {str(n): int(s)
            for n, s in zip(tuple(mesh.axis_names), np.shape(mesh.devices))}


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes present on this mesh, outermost first."""
    sizes = axis_sizes(mesh)
    return tuple(a for a in DP_AXES if a in sizes)


def _prod(vals) -> int:
    out = 1
    for v in vals:
        out *= int(v)
    return out


def _canon_strategy(strategy: str) -> str:
    try:
        return STRATEGIES[strategy]
    except KeyError:
        raise ValueError(f"unknown sharding strategy {strategy!r}; "
                         f"known: {sorted(set(STRATEGIES))}") from None


# ---------------------------------------------------------------------------
# Placement engine
# ---------------------------------------------------------------------------
class _Placer:
    """Greedy axis placement with divisibility + single-use enforcement.

    ``place(axis, dim_prefs)`` walks the preference list and assigns ``axis``
    to the first dim whose size is divisible by the product of the axes
    already on that dim times ``axis``'s size.  An axis is used at most once
    across the whole spec; failure to place simply replicates (the
    "replicate-on-indivisible" rule).
    """

    def __init__(self, shape: Sequence[int], sizes: Dict[str, int]):
        self.shape = tuple(int(s) for s in shape)
        self.sizes = sizes
        self.dims: List[List[str]] = [[] for _ in self.shape]
        self.used: set = set()

    def place(self, axis: str, dim_prefs: Sequence[int]) -> Optional[int]:
        if axis not in self.sizes or axis in self.used:
            return None
        for d in dim_prefs:
            if d < 0 or d >= len(self.shape):
                continue
            need = _prod(self.sizes[a] for a in self.dims[d])
            need *= self.sizes[axis]
            if self.shape[d] > 0 and self.shape[d] % need == 0:
                self.dims[d].append(axis)
                self.used.add(axis)
                return d
        return None

    def entries(self) -> List[Any]:
        out: List[Any] = []
        for axes in self.dims:
            if not axes:
                out.append(None)
            elif len(axes) == 1:
                out.append(axes[0])
            else:
                out.append(tuple(axes))
        return out


def _derive(shape, sizes, plan, contraction_dims) -> P:
    """Run a placement plan and build the spec.  RULE ZERO lives HERE: any
    data-parallel axis that a plan tried to put on a contraction dim is
    stripped before the spec is built — no individual rule can override it.
    """
    placer = _Placer(shape, sizes)
    for axis, dim_prefs in plan:
        safe = [d for d in dim_prefs
                if not (axis in DP_AXES and d in contraction_dims)]
        placer.place(axis, safe)
    for d in contraction_dims:                   # central backstop
        if 0 <= d < len(placer.dims):
            placer.dims[d] = [a for a in placer.dims[d] if a not in DP_AXES]
    return P(*placer.entries())


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------
def _linear_name(path: Tuple[str, ...]) -> str:
    leaf = path[-1]
    if leaf in ("w", "wc", "b") and len(path) >= 2:
        return path[-2]
    if (leaf in SPECTRAL_PLANES or leaf in SPECTRAL_SCALES) and len(path) >= 2:
        parent = path[-2]
        if parent == "wc_cache" and len(path) >= 3:
            return path[-3]                  # e.g. o/wc_cache/wr -> "o" (row)
        if parent.endswith("_cache"):
            return parent[:-len("_cache")]   # qkv/upgate/up/gate/down
    return leaf


def _param_core_spec(path, core, sizes, strategy) -> P:
    """Spec for the unstacked core shape of one parameter leaf."""
    leaf = path[-1]
    row = _linear_name(path) in ROW_LINEAR
    tp = strategy != "tokenpar"                  # tokenpar replicates weights
                                                 # over the model axis

    if leaf == "table":                          # embedding / tied LM head:
        plan = []                                # vocab over model (+FSDP)
        if tp:
            plan.append((MODEL_AXIS, [0]))
        plan.extend((a, [0]) for a in DP_AXES)
        return _derive(core, sizes, plan, contraction_dims=())

    # per-block-row quantization scales (p, 1) / expert (E, p, 1): the
    # block-row dim carries "model" exactly when the payload's does
    # (column TP; expert scales follow the EP-first preference); size-1
    # dims never place, and DP axes are skipped — a replicated scale is
    # free next to its k-times-larger payload.  Checked BEFORE the experts
    # branch: an (E, p, 1) scale must not be specced as a dense
    # (E, n_in, n_out) expert weight.
    if leaf in SPECTRAL_SCALES and len(core) in (2, 3):
        if len(core) == 3:                       # (E, p, 1) expert scales
            prefs = [0] + ([] if row else [1])
        else:                                    # (p, 1)
            prefs = [] if row else [0]
        plan = [(MODEL_AXIS, prefs)] if tp else []
        return _derive(core, sizes, plan, contraction_dims=())

    if "experts" in path:                        # (E, ...) per-expert stacks
        nd = len(core)
        if nd == 4:                              # circulant (E, p, q, k)
            e_dim, p_dim, q_dim, k_dim = 0, 1, 2, 3
        elif nd == 3:                            # dense (E, n_in, n_out)
            e_dim, p_dim, q_dim, k_dim = 0, 2, 1, -1
        else:                                    # router-ish oddity: replicate
            return P()
        contraction = (q_dim,)
        # EP when E divides the model axis; else TP inside the expert.
        intra = [q_dim, k_dim] if row else [p_dim, k_dim]
        plan = []
        if tp:
            plan.append((MODEL_AXIS, [e_dim] + intra))
        plan.extend((a, [k_dim, p_dim]) for a in DP_AXES)
        return _derive(core, sizes, plan, contraction_dims=contraction)

    # block-circulant generators (p, q, k) and their spectral serving planes
    # (p, q, kf) place identically: the frequency dim simply fails DP
    # divisibility more often (kf = k/2+1 is odd) and falls back to p.
    if (leaf == "wc" or leaf in SPECTRAL_PLANES) and len(core) == 3:
        contraction = (1,)                       # q = input (contraction) blocks
        model_pref = [1, 2] if row else [0, 2]
        plan = []
        if tp:
            plan.append((MODEL_AXIS, model_pref))
        plan.extend((a, [2, 0]) for a in DP_AXES)
        return _derive(core, sizes, plan, contraction_dims=contraction)

    if len(core) == 2:                           # dense (n_in, n_out)
        contraction = (0,)
        model_pref = [0, 1] if row else [1]
        plan = []
        if tp:
            plan.append((MODEL_AXIS, model_pref))
        plan.extend((a, [1]) for a in DP_AXES)
        return _derive(core, sizes, plan, contraction_dims=contraction)

    # Unclassified multi-dim leaf: replicate (correct, never wrong — the
    # hill-climb loop promotes hot ones into explicit rules).
    return P()


def param_spec(path: Sequence[Any], shape: Sequence[int], mesh,
               strategy: str = "2d") -> P:
    """PartitionSpec for one parameter from its pytree path + shape.

    ``path`` is a tuple of pytree keys (strings or indices); ``shape`` the
    leaf shape.  Stacked/scanned leading dims (params under ``segments`` /
    ``enc_blocks`` / ``dec_blocks``) are detected and never sharded.
    """
    strategy = _canon_strategy(strategy)
    path = tuple(str(c) for c in path)
    shape = tuple(int(s) for s in shape)
    sizes = axis_sizes(mesh)
    leaf = path[-1] if path else ""

    if leaf in REPLICATED_LEAVES:
        return P()

    n_stack = 1 if (path and STACKED_ROOTS.intersection(path)) else 0
    if leaf in _CORE_RANK:                       # rank-derived stack count
        rank = _CORE_RANK[leaf]
        if (leaf in SPECTRAL_PLANES or leaf in SPECTRAL_SCALES) \
                and "experts" in path:
            rank += 1            # (E, p, q, kf) expert planes / (E, p, 1)
        n_stack = max(n_stack, len(shape) - rank)
    n_stack = min(n_stack, len(shape))
    core = shape[n_stack:]

    if len(core) <= 1:                           # scalars, norms, biases
        return P()

    spec = _param_core_spec(path, core, sizes, strategy)
    if n_stack == 0:
        return spec
    return P(*([None] * n_stack), *tuple(spec))


def param_specs(params, mesh, strategy: str = "2d"):
    """``param_spec`` mapped over a parameter pytree (shapes or arrays)."""
    def one(key_path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in key_path)
        return param_spec(names, getattr(leaf, "shape", ()), mesh, strategy)
    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# Activation / batch / cache specs
# ---------------------------------------------------------------------------
def batch_spec(shape: Sequence[int], mesh, global_batch: int,
               seq_shard: bool = False) -> P:
    """Spec for a batch-leading activation or input: batch dim over the DP
    axes (as a tuple, so 256- and 512-chip meshes read uniformly), optional
    sequence dim over "model" (token parallelism), replicate-on-indivisible.
    Dim 0 is only treated as the batch dim when it equals ``global_batch``
    (pass the leaf's own leading size for microbatched slices).
    """
    shape = tuple(int(s) for s in shape)
    sizes = axis_sizes(mesh)
    dpa = dp_axes(mesh)
    entries: List[Any] = [None] * len(shape)
    if (shape and dpa and shape[0] == int(global_batch)
            and shape[0] % _prod(sizes[a] for a in dpa) == 0):
        entries[0] = tuple(dpa)
    if (seq_shard and len(shape) >= 2 and MODEL_AXIS in sizes
            and shape[1] % sizes[MODEL_AXIS] == 0):
        entries[1] = MODEL_AXIS
    return P(*entries)


def batch_specs(batch, mesh, global_batch: int, seq_shard: bool = False):
    """``batch_spec`` mapped over a batch pytree (tokens/labels/frames/...)."""
    return jax.tree.map(
        lambda leaf: batch_spec(getattr(leaf, "shape", ()), mesh,
                                global_batch, seq_shard=seq_shard),
        batch)


def cache_spec(path: Sequence[Any], shape: Sequence[int], dtype, mesh,
               global_batch: int) -> P:
    """Spec for one KV-cache / recurrent-state leaf.

    Integer leaves (ring positions, counters) replicate.  Float leaves shard
    their batch dim (first dim equal to ``global_batch``) over the DP axes;
    KV-shaped leaves ``(..., B, S, H, D)`` additionally put "model" on the
    heads dim when divisible, falling back to head_dim (GQA archs have too
    few KV heads for a 16-way model axis).  The sequence dim is NEVER sharded
    — decode writes single slots at dynamic positions.
    """
    shape = tuple(int(s) for s in shape)
    if np.issubdtype(np.dtype(dtype), np.integer) or not shape:
        return P()
    sizes = axis_sizes(mesh)
    dpa = dp_axes(mesh)
    b_idx = next((i for i, s in enumerate(shape) if s == int(global_batch)),
                 None)
    if b_idx is None:
        return P()
    entries: List[Any] = [None] * len(shape)
    if dpa and shape[b_idx] % _prod(sizes[a] for a in dpa) == 0:
        entries[b_idx] = tuple(dpa)
    m = sizes.get(MODEL_AXIS)
    if m and len(shape) >= b_idx + 3:            # (..., B, S, H, D)-like tail
        if len(shape) - 2 > b_idx and shape[-2] % m == 0:
            entries[-2] = MODEL_AXIS
        elif shape[-1] % m == 0:
            entries[-1] = MODEL_AXIS
    return P(*entries)


def cache_specs(cache, mesh, global_batch: int):
    """``cache_spec`` mapped over a cache pytree (with paths for dispatch)."""
    def one(key_path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in key_path)
        return cache_spec(names, getattr(leaf, "shape", ()),
                          getattr(leaf, "dtype", np.float32), mesh,
                          global_batch)
    return jax.tree_util.tree_map_with_path(one, cache)


def page_pool_spec(shape: Sequence[int], mesh) -> P:
    """Spec for one paged KV-pool leaf ``(..., P, page, Hkv, D)``
    (serve/kvcache.py) — pages shard like the dense cache they replace:

    * the PAGE-ID dim takes the DP axes (each DP shard owns a slice of the
      free pool, the way the dense cache's batch dim spread requests over
      DP) when divisible, else replicates;
    * heads take "model" when divisible, falling back to head_dim (GQA
      archs have too few KV heads for a 16-way model axis) — identical to
      ``cache_spec``;
    * the in-page offset dim is NEVER sharded (decode writes single slots
      at dynamic offsets, same reason the dense sequence dim never shards);
    * extra leading dims are scan-stack dims, never sharded.
    """
    shape = tuple(int(s) for s in shape)
    if len(shape) < 4:
        return P()
    sizes = axis_sizes(mesh)
    dpa = dp_axes(mesh)
    entries: List[Any] = [None] * len(shape)
    p_idx = len(shape) - 4
    if dpa and shape[p_idx] % _prod(sizes[a] for a in dpa) == 0:
        entries[p_idx] = tuple(dpa)
    m = sizes.get(MODEL_AXIS)
    if m:
        if shape[-2] % m == 0:
            entries[-2] = MODEL_AXIS
        elif shape[-1] % m == 0:
            entries[-1] = MODEL_AXIS
    return P(*entries)


def decode_head_spec(shape: Sequence[int], mesh) -> P:
    """Spec for per-slot decode-attention activations ``(B, Hq, D)`` — the
    q / output of the streamed paged-attention op (kernels/paged_attention).

    Slots take the DP axes (the dense batch dim's role), heads take "model"
    with a head-dim fallback — the SAME head placement ``page_pool_spec``
    gives the pool, so the streamed contraction shards head-aligned with
    the KV pages it reads and GSPMD inserts no resharding between them.
    Replicate-on-indivisible throughout (GQA archs with few heads).
    """
    shape = tuple(int(s) for s in shape)
    if len(shape) != 3:
        return P()
    sizes = axis_sizes(mesh)
    dpa = dp_axes(mesh)
    entries: List[Any] = [None] * 3
    if dpa and shape[0] % _prod(sizes[a] for a in dpa) == 0:
        entries[0] = tuple(dpa)
    m = sizes.get(MODEL_AXIS)
    if m:
        if shape[1] % m == 0:
            entries[1] = MODEL_AXIS
        elif shape[2] % m == 0:
            entries[2] = MODEL_AXIS
    return P(*entries)


def dp_round_up(n: int, mesh) -> int:
    """Round a page count up to a multiple of the mesh's DP-axis product.

    ``page_pool_spec`` only shards the page dim when it divides the DP
    product; an off-by-one pool (e.g. the +1 trash page) would otherwise
    silently replicate the whole pool over the data-parallel devices.
    """
    sizes = axis_sizes(mesh)
    dp = _prod(sizes[a] for a in dp_axes(mesh)) or 1
    return -(-int(n) // dp) * dp


def page_scale_spec(shape: Sequence[int], mesh) -> P:
    """Spec for a paged-pool quantization-scale leaf ``(..., P, Hkv)``
    (serve/kvcache.py int8 pools: one f32 absmax scale per (page, head)).

    Scales shard LIKE THEIR PAYLOAD: the page-id dim takes the DP axes
    exactly as ``page_pool_spec`` places the pool's, and heads take
    "model" when divisible.  A scale has no in-page-offset dim at all —
    the per-page granularity is what keeps the offset axis unsharded by
    construction — and no head_dim, so the pool's head_dim fallback
    becomes replication here (free at this size).
    """
    shape = tuple(int(s) for s in shape)
    if len(shape) < 2:
        return P()
    sizes = axis_sizes(mesh)
    dpa = dp_axes(mesh)
    entries: List[Any] = [None] * len(shape)
    p_idx = len(shape) - 2
    if dpa and shape[p_idx] % _prod(sizes[a] for a in dpa) == 0:
        entries[p_idx] = tuple(dpa)
    m = sizes.get(MODEL_AXIS)
    if m and shape[-1] % m == 0:
        entries[-1] = MODEL_AXIS
    return P(*entries)


def pool_specs(pool, mesh):
    """``page_pool_spec`` mapped over a paged-pool pytree; int8-pool scale
    leaves (``k_scale``/``v_scale``) take ``page_scale_spec``; block tables
    and other integer leaves replicate."""
    def one(key_path, leaf):
        shape = getattr(leaf, "shape", ())
        name = str(getattr(key_path[-1], "key", key_path[-1])) \
            if key_path else ""
        if name in POOL_SCALES:
            return page_scale_spec(shape, mesh)
        if name in ("k", "v"):                   # pool payloads shard by
            return page_pool_spec(shape, mesh)   # shape even when int8
        if np.issubdtype(np.dtype(getattr(leaf, "dtype", np.float32)),
                         np.integer):
            return P()
        return page_pool_spec(shape, mesh)
    return jax.tree_util.tree_map_with_path(one, pool)


def logits_spec(mesh, global_batch: int, vocab: int) -> P:
    """Spec for (B, S, V) logits: batch over DP, vocab over "model" (the
    tied LM head is vocab-sharded column TP), seq replicated."""
    sizes = axis_sizes(mesh)
    dpa = dp_axes(mesh)
    b_entry = (tuple(dpa) if dpa and
               int(global_batch) % _prod(sizes[a] for a in dpa) == 0 else None)
    m = sizes.get(MODEL_AXIS)
    v_entry = MODEL_AXIS if m and int(vocab) % m == 0 else None
    return P(b_entry, None, v_entry)


# ---------------------------------------------------------------------------
# Mesh binding
# ---------------------------------------------------------------------------
def to_shardings(specs, mesh):
    """Bind a pytree of PartitionSpecs to a concrete mesh as NamedShardings.

    Needs a real ``jax.sharding.Mesh`` (this is the only function in the
    module that does); spec derivation above never touches devices.
    """
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
