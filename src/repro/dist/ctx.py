"""Activation-sharding context: the policy models consult at block boundaries.

``activation_policy(mesh, seq_shard=...)`` installs a policy for the current
thread; ``shard_act(x)`` — called by the model backbones between blocks — pins
``(B, S, d)`` activations to the policy's layout via
``with_sharding_constraint``.  Outside any policy it is the identity, so the
backbones run unchanged on a single host device.

Why a context instead of plumbing a mesh through every forward signature: the
block stack is traversed by ``lax.scan`` / ``jax.checkpoint`` closures several
layers deep; a dynamically-scoped policy keeps the model code free of
distribution concerns (the same pattern as jax's own mesh context manager).
The policy is captured at TRACE time, so jit the step functions inside the
context (the launchers and the serve engine both do).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding

from . import sharding as sh


class _PolicyState(threading.local):
    def __init__(self):
        self.stack = []


_STATE = _PolicyState()


@contextlib.contextmanager
def activation_policy(mesh, *, seq_shard: bool = False):
    """Install an activation-sharding policy: batch over the DP axes, and —
    when ``seq_shard`` (token parallelism) — sequence over "model".
    Policies nest; the innermost wins.
    """
    _STATE.stack.append((mesh, bool(seq_shard)))
    try:
        yield
    finally:
        _STATE.stack.pop()


def current_policy() -> Optional[Tuple[object, bool]]:
    """The innermost (mesh, seq_shard) policy, or None outside any context."""
    return _STATE.stack[-1] if _STATE.stack else None


def shard_act(x):
    """Block-boundary sharding pin for a (B, S, d) activation.

    A no-op without an active policy or for non-rank-3 values.  With one, the
    constraint re-anchors GSPMD's propagation each block — without the pin the
    partitioner is free to drift layouts mid-stack (measured as spurious
    all-gathers on the 256-chip dry-run), and under token parallelism it is
    what actually holds the sequence dim on "model" between attention's
    all-to-alls.  Divisibility is re-checked against the live shape, so
    microbatched (B/accum) slices inside the accumulation scan pin correctly.
    """
    pol = current_policy()
    if pol is None or getattr(x, "ndim", None) != 3:
        return x
    mesh, seq_shard = pol
    spec = sh.batch_spec(x.shape, mesh, x.shape[0], seq_shard=seq_shard)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_heads(x):
    """Sharding pin for a (B, Hq, D) per-slot decode activation — the q and
    output of the streamed paged attention.  Pins slots over DP and heads
    over "model" (``sharding.decode_head_spec`` — the pool's own head
    placement, so the streamed contraction needs no resharding against the
    pages it reads).  Identity outside a policy or for other ranks.
    """
    pol = current_policy()
    if pol is None or getattr(x, "ndim", None) != 3:
        return x
    mesh, _ = pol
    spec = sh.decode_head_spec(x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
