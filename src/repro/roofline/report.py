"""Render EXPERIMENTS.md tables from dry-run JSON records.

  PYTHONPATH=src python -m repro.roofline.report results/dryrun_single.json
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bound | "
        "MODEL/HLO | roofline frac | bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant'].split('_')[0]} | {r['model_hlo_ratio']:.2f} | "
            f"{r['roofline_frac_overlap']:.3f} | "
            f"{r['bytes_per_device']/2**30:.1f} GiB |")
    return "\n".join(lines)


def dryrun_table(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | flops/dev | bytes/dev | "
        "AG | AR | RS | A2A | CP |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            why = r.get("why", r.get("error", ""))[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{r['status']}: {why} | | | | | | | |")
            continue
        c = r["collectives"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['flops_per_device']:.2e} | "
            f"{r['bytes_per_device']/2**30:.1f} GiB | "
            f"{c['all-gather']/2**30:.2f} | {c['all-reduce']/2**30:.2f} | "
            f"{c['reduce-scatter']/2**30:.2f} | {c['all-to-all']/2**30:.2f} | "
            f"{c['collective-permute']/2**30:.2f} |")
    return "\n".join(lines)


def main():
    for path in sys.argv[1:]:
        recs = json.load(open(path))
        print(f"### {path}\n")
        print(roofline_table(recs))
        print()


if __name__ == "__main__":
    main()
