from . import analysis  # noqa: F401
