"""Three-term roofline from the compiled dry-run artifact (no hardware).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

``cost_analysis()`` on the SPMD-partitioned executable reports the LOCAL
(per-device) program, so terms are per-chip seconds directly.  Collective
bytes are NOT in cost_analysis: we parse the optimized HLO text and sum the
result-buffer sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (async -start counted once, -done skipped).
Caveats recorded in EXPERIMENTS.md: XLA "bytes accessed" counts every
operand/result touch (an upper bound on HBM traffic when fusions keep data
in VMEM); ring-collective wire bytes are ~(n-1)/n of buffer size, so the
collective term is likewise a slight upper bound.

MODEL_FLOPS uses the compression-aware convention: a dense projection costs
2·n_in·n_out per token, a block-circulant one costs its FFT-pipeline FLOPs
(the paper's O(n log n) accounting) — so the MODEL/HLO ratio measures how
much compiled compute is useful *relative to the compressed algorithm*, and
catches remat/replication waste rather than crediting compression twice.
MoE expert projections count top_k active experts per token.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any, Dict, Tuple

import jax
import numpy as np

from ..configs.base import ArchConfig, ShapeSpec
from ..core import circulant as cc


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Peak rates of one device — the denominators of every roofline
    question.  The static dry-run cells and the live dispatch profiler
    (``repro.obs.prof``) both divide by these, so "fraction of roofline"
    means the same thing whether the cell was compiled dry or dispatched
    hot.  ``ridge_flops_per_byte`` is the arithmetic intensity at which a
    kernel stops being memory-bound on this part."""
    name: str
    peak_flops: float            # FLOP/s per chip
    hbm_bw: float                # HBM bytes/s per chip
    link_bw: float = 0.0         # bytes/s per interconnect link

    @property
    def ridge_flops_per_byte(self) -> float:
        return self.peak_flops / self.hbm_bw


# TPU v5e-class hardware constants (assignment-specified)
TPU_V5E = HardwareSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                       link_bw=50e9)
# TPU v4 (the dist rule engine's 256/512-chip mesh target)
TPU_V4 = HardwareSpec("tpu-v4", peak_flops=275e12, hbm_bw=1.2e12,
                      link_bw=50e9)
# One modern server-CPU socket, order of magnitude: tens of f32 GFLOP/s per
# core x a few dozen cores, ~50 GB/s effective DRAM stream.  Deliberately
# round numbers — on the host backend the profiler's roofline fraction is a
# sanity scale, not a calibrated claim (docs/observability.md).
HOST_CPU = HardwareSpec("host-cpu", peak_flops=2e11, hbm_bw=5e10)
# Generic data-center GPU placeholder until a real part is measured.
GPU_GENERIC = HardwareSpec("gpu-generic", peak_flops=1e14, hbm_bw=2e12,
                           link_bw=25e9)

HARDWARE_PRESETS = {s.name: s for s in (TPU_V5E, TPU_V4, HOST_CPU,
                                        GPU_GENERIC)}


def detect_hardware() -> HardwareSpec:
    """Preset for the active jax backend (host-CPU default)."""
    backend = jax.default_backend()
    if backend == "tpu":
        return TPU_V5E
    if backend == "gpu":
        return GPU_GENERIC
    return HOST_CPU


# Legacy module constants (EXPERIMENTS.md numbers were computed from these);
# the dry-run report still defaults to the TPU v5e spec.
PEAK_FLOPS = TPU_V5E.peak_flops      # bf16 FLOP/s per chip
HBM_BW = TPU_V5E.hbm_bw              # bytes/s per chip
LINK_BW = TPU_V5E.link_bw            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

def xla_cost_analysis(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` as a flat dict across jax versions.

    Older jax returns a one-element list of per-program dicts (multi-program
    executables return several — summed here, matching the newer flat-dict
    semantics); newer jax returns the dict directly.  Idempotent.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        merged: Dict[str, float] = {}
        for prog in ca:
            for k, v in (prog or {}).items():
                merged[k] = merged.get(k, 0.0) + v
        return merged
    return dict(ca or {})


class CompiledCompat:
    """Delegating view of a compiled executable whose ``cost_analysis()`` is
    normalized via ``xla_cost_analysis`` — so downstream report code (and
    EXPERIMENTS.md numbers) can always index ``["flops"]``."""

    def __init__(self, compiled):
        self._compiled = compiled

    def __getattr__(self, name):
        return getattr(self._compiled, name)

    def cost_analysis(self) -> Dict[str, float]:
        return xla_cost_analysis(self._compiled)


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z0-9-]+)")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes (per device) from optimized HLO."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        typestr, op = m.groups()
        base = op[:-6] if op.endswith("-start") else op
        if base.endswith("-done"):
            continue
        if base in out:
            out[base] += _shape_bytes(typestr)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


# ---------------------------------------------------------------------------
# MODEL_FLOPS: compression-aware useful-work accounting
# ---------------------------------------------------------------------------
def model_flops_per_token(params_shapes: Any, cfg: ArchConfig) -> float:
    """Projection FLOPs per processed token (fwd only, 6N·D convention:
    attention score/AV FLOPs excluded, embedding gather excluded)."""
    topk = max(cfg.moe.top_k, 1)
    total = 0.0

    def one(path, leaf):
        nonlocal total
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        leaf_name = names[-1]
        is_expert = "experts" in names
        shape = leaf.shape
        if leaf_name == "table":                      # tied LM head matmul
            total += 2.0 * shape[0] * shape[1]
            return
        if leaf_name == "wc" or (is_expert and len(shape) >= 4 and
                                 leaf_name in ("up", "gate", "down")
                                 and shape[-1] <= 512):
            p_, q_, k_ = shape[-3], shape[-2], shape[-1]
            stack = math.prod(shape[:-3]) if len(shape) > 3 else 1
            if is_expert:                             # (stack, E, p, q, k)
                stack = stack // shape[-4] if len(shape) >= 4 else stack
                stack = math.prod(shape[:-4]) * topk
            flops = cc.bc_flops(1, q_ * k_, p_ * k_, k_)
            total += float(stack) * flops
            return
        if len(shape) >= 2 and leaf_name in (
                "w", "up", "gate", "down", "router", "wh", "ifg"):
            n_in, n_out = shape[-2], shape[-1]
            stack = math.prod(shape[:-2]) if len(shape) > 2 else 1
            if is_expert:                             # (stack, E, in, out)
                stack = (math.prod(shape[:-3]) if len(shape) > 3 else 1) * topk
            total += float(stack) * 2.0 * n_in * n_out

    jax.tree_util.tree_map_with_path(one, params_shapes)
    return total


def count_params(params_shapes: Any) -> int:
    return int(sum(math.prod(l.shape) for l in jax.tree.leaves(params_shapes)))


def seq_mixer_flops_per_token(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Algorithmic FLOPs/token of the sequence mixers (attention scores+AV,
    recurrent state updates) — the PaLM-style MFU convention extended to the
    assigned families.  With 128x-compressed projections these dominate the
    useful work, so the MODEL/HLO ratio must include them."""
    from ..models.transformer import segments_for
    S = shape.seq_len
    a = cfg.attention
    hd = a.num_heads * a.head_dim

    def ctx(kind: str) -> float:
        w = a.sliding_window
        avg = S if shape.is_decode else S / 2          # causal average
        if kind in ("attn_local", "moe_swa") and w:
            return min(w, avg)
        return avg

    total = 0.0
    if cfg.is_encoder_decoder:
        # decoder self (causal) + cross to encoder_seq; encoder counted on
        # its own tokens (approximated onto decoder tokens by ratio).
        total += cfg.num_layers * 4.0 * hd * (S if shape.is_decode else S / 2)
        total += cfg.num_layers * 4.0 * hd * cfg.encoder_seq
        enc_tokens_ratio = (cfg.encoder_seq / max(S, 1)
                            if not shape.is_decode else cfg.encoder_seq)
        total += (cfg.encoder_layers * 4.0 * hd * cfg.encoder_seq *
                  (enc_tokens_ratio if shape.is_decode else
                   cfg.encoder_seq / max(S, 1)))
        return total
    for pattern, n in segments_for(cfg):
        for kind in pattern:
            if kind in ("attn", "attn_local", "moe", "moe_swa"):
                total += n * 4.0 * hd * ctx(kind)
            elif kind == "rec":
                total += n * 20.0 * (cfg.recurrent.lru_width or cfg.d_model)
            elif kind == "mlstm":
                d_in = int(cfg.d_model * cfg.recurrent.proj_factor)
                c = min(cfg.mlstm_chunk if not cfg.unroll_scan else 256, S)
                total += n * (2.0 * d_in * c + 8.0 * d_in *
                              (d_in // max(cfg.recurrent.mlstm_heads, 1)))
            elif kind == "slstm":
                total += n * (8.0 * cfg.d_model ** 2 + 64.0 * cfg.d_model)
    return total


def slstm_scan_correction(cfg: ArchConfig, shape: ShapeSpec,
                          dp_size: int) -> float:
    """Per-device FLOPs of the sLSTM time-recurrence beyond the once-costed
    scan body.  The strictly-sequential sLSTM scan cannot be unrolled at
    S=4k-500k, so its (S-1) extra body costs are added analytically:
    body = h@W_h matmul (2·b·d·4d) + ~16·4d·b gate elementwise per layer."""
    pattern = cfg.recurrent.pattern or ()
    if "slstm" not in pattern or shape.is_decode:
        return 0.0
    groups = cfg.num_layers // max(len(pattern), 1)
    n_slstm = sum(k == "slstm" for k in pattern) * groups
    b_local = max(shape.global_batch // dp_size, 1)
    d = cfg.d_model
    body = 2.0 * b_local * d * 4 * d + 16.0 * b_local * 4 * d
    factor = 3.0 if shape.kind == "train" else 1.0
    return n_slstm * (shape.seq_len - 1) * body * factor


# ---------------------------------------------------------------------------
def cell_report(lowered, compiled, cfg: ArchConfig, shape: ShapeSpec,
                mesh, spec: HardwareSpec = TPU_V5E) -> Dict:
    """All roofline quantities for one compiled cell (``spec`` picks the
    hardware denominators; the dry run keeps the TPU v5e default)."""
    chips = int(np.prod(mesh.devices.shape))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_size = sizes.get("pod", 1) * sizes.get("data", 1)
    ca = xla_cost_analysis(compiled)
    slstm_extra = (slstm_scan_correction(cfg, shape, dp_size)
                   if cfg.unroll_scan else 0.0)
    flops = float(ca.get("flops", 0.0)) + slstm_extra
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
        "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        "code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
    }
    bytes_per_device = (mem["argument_bytes"] + mem["output_bytes"] +
                        mem["temp_bytes"] - mem["alias_bytes"])
    coll = collective_bytes(compiled.as_text())

    t_compute = flops / spec.peak_flops
    t_memory = bytes_acc / spec.hbm_bw
    t_coll = coll["total"] / (spec.link_bw or LINK_BW)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    from ..models.registry import build_model
    model = build_model(cfg)
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    fwd_per_tok = (model_flops_per_token(params_shapes, cfg) +
                   seq_mixer_flops_per_token(cfg, shape))
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 3.0 * fwd_per_tok * tokens          # fwd + 2x bwd
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = fwd_per_tok * tokens
    else:
        tokens = shape.global_batch                        # one token per seq
        model_flops = fwd_per_tok * tokens

    hlo_global = flops * chips
    t_model = model_flops / chips / spec.peak_flops
    bound = max(terms.values())
    return {
        "hardware": spec.name,
        "chips": chips,
        "slstm_correction_flops": slstm_extra,
        "flops_per_device": flops,
        "bytes_accessed_per_device": bytes_acc,
        "bytes_per_device": bytes_per_device,
        "memory": mem,
        "collectives": coll,
        **{k: v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": model_flops,
        "params": count_params(params_shapes),
        "model_hlo_ratio": model_flops / hlo_global if hlo_global else 0.0,
        "roofline_frac_overlap": t_model / bound if bound else 0.0,
        "roofline_frac_serial": (t_model / sum(terms.values())
                                 if sum(terms.values()) else 0.0),
    }
