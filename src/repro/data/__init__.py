from . import pipeline  # noqa: F401
