"""Deterministic, sharded, stateless-resumable data pipeline.

The batch for step ``i`` is a pure function of ``(seed, i)`` — no iterator
state to checkpoint, no host coordination for stragglers, and any host can
recompute any shard after preemption (DESIGN.md §7).  Two sources:

* ``SyntheticLM`` — PRNG token streams with a learnable bigram structure
  (so loss visibly decreases in the examples);
* ``FileTokens``  — memory-mapped flat token file, deterministic strided
  window addressing, padded circularly.

Both yield {tokens, labels} with next-token labels; frontends add stub
frames/patches per the assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig


@dataclasses.dataclass
class SyntheticLM:
    cfg: ArchConfig
    batch: int
    seq: int
    seed: int = 0
    vocab_cap: int = 0              # sample ids < cap (default: vocab_size)

    def __post_init__(self):
        cap = self.vocab_cap or self.cfg.vocab_size
        rng = np.random.RandomState(self.seed)
        # fixed random bigram successor table — gives the model signal
        self._succ = rng.randint(0, cap, size=(cap,)).astype(np.int32)
        self._cap = cap

    def __call__(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        ks = jax.random.split(key, 3)
        first = jax.random.randint(ks[0], (self.batch, 1), 0, self._cap)
        succ = jnp.asarray(self._succ)
        noise = jax.random.bernoulli(ks[1], 0.1, (self.batch, self.seq))
        rand = jax.random.randint(ks[2], (self.batch, self.seq), 0, self._cap)

        def step_fn(tok, xs):
            nz, rnd = xs
            nxt = jnp.where(nz, rnd, succ[tok])
            return nxt, nxt
        _, seq = jax.lax.scan(
            step_fn, first[:, 0],
            (noise.swapaxes(0, 1), rand.swapaxes(0, 1)))
        toks = jnp.concatenate([first, seq.swapaxes(0, 1)[:, :-1]], axis=1)
        labels = seq.swapaxes(0, 1)
        batch = {"tokens": toks.astype(jnp.int32),
                 "labels": labels.astype(jnp.int32)}
        return _add_frontend(batch, self.cfg, key)


@dataclasses.dataclass
class FileTokens:
    cfg: ArchConfig
    path: str
    batch: int
    seq: int
    seed: int = 0
    dtype: str = "uint16"

    def __post_init__(self):
        self._mm = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n = len(self._mm)

    def __call__(self, step: int) -> Dict[str, jax.Array]:
        # deterministic strided windows; wraps circularly over the file
        span = self.seq + 1
        starts = ((step * self.batch + np.arange(self.batch)) * span +
                  self.seed) % max(self._n - span, 1)
        rows = np.stack([np.asarray(self._mm[s:s + span]) for s in starts])
        rows = rows.astype(np.int32) % self.cfg.vocab_size
        batch = {"tokens": jnp.asarray(rows[:, :-1]),
                 "labels": jnp.asarray(rows[:, 1:])}
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        return _add_frontend(batch, self.cfg, key)


def _add_frontend(batch: Dict, cfg: ArchConfig, key) -> Dict:
    B = batch["tokens"].shape[0]
    if cfg.frontend == "audio_stub":
        batch["frames"] = 0.02 * jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model))
    elif cfg.frontend == "vision_stub":
        batch["patches"] = 0.02 * jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model))
    return batch


def shard_for_host(batch: Dict, host_index: int, num_hosts: int) -> Dict:
    """Slice the per-host portion of a global batch (multi-host launch)."""
    def one(x):
        per = x.shape[0] // num_hosts
        return x[host_index * per:(host_index + 1) * per]
    return jax.tree.map(one, batch)
