"""Continuous-batching request scheduler: admission, preemption, and the
request lifecycle over decode slots, between device dispatches.

Pure host logic (no jax): the ContinuousEngine consults it between
dispatches of the scanned decode loop.  The hierarchy mirrors the paper's
hardware control stack — a tiny control plane (queue + slot states + block
tables) steering a large data plane (the paged pool + the device loop):

* requests queue FIFO; admission happens only between device dispatches,
  into slots whose previous request retired (no batch-drain barrier),
* under the default OPTIMISTIC admission policy only the prefill's page
  footprint is reserved at admit; decode-time page growth can fail, and on
  exhaustion the scheduler PREEMPTS the youngest running slot — its pages
  go back to the pool and the request re-queues at the head for
  recompute-prefill (prompt + generated-so-far), bounded per request by
  ``max_preemptions``.  ``admission="reserve"`` keeps the legacy
  worst-case up-front reservation (a running request then never stalls),
* every request ends in EXACTLY ONE terminal status (the ``FINISHED_EOS``
  … ``FAILED`` constants below); deadlines are enforced both in-queue
  (``expire_queue``) and in-flight (the engine retires expired slots),
  ``cancel`` removes a request wherever it lives, and a bounded submit
  queue rejects with backpressure instead of growing unboundedly.

Admission is strictly FIFO (no head-of-line skipping): a large request at
the head blocks later small ones, trading a little throughput for no
starvation.  Preempted requests re-queue AT THE HEAD (oldest first), so
FIFO order is preserved across preemption — the queue is always sorted by
submission order.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from ..obs.metrics import Registry
from .kvcache import BlockTable, pages_for

# Terminal request statuses — every submitted request reaches exactly one
# (the chaos suite in serve/faults.py asserts this).  The strings are the
# trace/emitter schema (obs/emit.py validates against the same literals).
FINISHED_EOS = "FINISHED_EOS"          # emitted eos_id within budget
FINISHED_BUDGET = "FINISHED_BUDGET"    # decode budget exhausted
TIMEOUT = "TIMEOUT"                    # deadline expired (queued or running)
CANCELLED = "CANCELLED"                # cancel(request_id)
REJECTED = "REJECTED"                  # bounded-queue backpressure / drain
FAILED = "FAILED"                      # anomaly (NaN/Inf) or page starvation

TERMINAL_STATUSES = (FINISHED_EOS, FINISHED_BUDGET, TIMEOUT, CANCELLED,
                     REJECTED, FAILED)
FINISHED_STATUSES = (FINISHED_EOS, FINISHED_BUDGET)


@dataclasses.dataclass
class QueueEntry:
    """One queued request.  ``resume_tokens`` is non-empty iff the entry is
    a preempted request waiting for recompute-prefill (the generated tokens
    are appended to the prompt and teacher-forced through prefill)."""
    order: int                         # submission index (result ordering)
    request: object                    # engine-level Request
    arrival_s: float = 0.0
    deadline_s: Optional[float] = None  # ABSOLUTE (arrival + request budget)
    resume_tokens: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0


@dataclasses.dataclass
class SlotState:
    """One decode slot's in-flight request (None = free)."""
    index: int
    request: object = None            # engine-level Request
    order: int = -1                   # submission index (result ordering)
    pos: int = 0                      # next cache position (= tokens seen)
    budget: int = 0                   # decode steps allowed THIS life
    tokens: List[int] = dataclasses.field(default_factory=list)
    arrival_s: float = 0.0
    admit_s: float = 0.0
    deadline_s: Optional[float] = None
    preemptions: int = 0              # times this request was preempted
    resume_len: int = 0               # tokens recomputed via prefill
    total_budget: int = 0             # resume_len + budget (whole request)
    tif: int = 0                      # tokens charged to the in-flight budget

    @property
    def free(self) -> bool:
        return self.request is None


@dataclasses.dataclass
class PrepareDecode:
    """Outcome of pre-dispatch page growth (``Scheduler.prepare_decode``)."""
    runnable: List[SlotState]                 # pages cover the next chunk
    stalled: List[SlotState]                  # no pages, no victim: skip
    preempted: List[Tuple[int, QueueEntry]]   # (slot index, re-queued entry)


class Scheduler:
    """FIFO admission + slot lifecycle over a BlockTable.

    Lifecycle counters live in a ``repro.obs`` Registry (one is created
    internally when none is passed): ``sched.submitted`` / ``.admitted`` /
    ``.retired`` / ``.preempted`` / ``.stalled`` counters,
    ``sched.deferred{reason=...}`` for admission attempts that parked,
    ``sched.terminal{status=...}`` counting every terminal transition,
    the ``sched.recompute_tokens`` histogram (tokens re-prefilled per
    preemption), and ``sched.queue_depth`` / ``sched.tokens_in_flight``
    gauges (peaks via the gauge high-water marks).  ``stats()`` is a view
    over that registry plus the allocator's page accounting.
    """

    def __init__(self, table: BlockTable, *, max_seq: int,
                 max_tokens_in_flight: int,
                 registry: Optional[Registry] = None,
                 admission: str = "optimistic",
                 max_queue: Optional[int] = None,
                 max_preemptions: int = 4):
        if admission not in ("optimistic", "reserve"):
            raise ValueError(f"admission {admission!r}: expected "
                             f"'optimistic' or 'reserve'")
        self.table = table
        self.max_seq = int(max_seq)
        self.max_tokens_in_flight = int(max_tokens_in_flight)
        self.admission = admission
        self.max_queue = None if max_queue is None else int(max_queue)
        self.max_preemptions = int(max_preemptions)
        self.slots = [SlotState(i) for i in range(table.table.shape[0])]
        self.queue: Deque[QueueEntry] = deque()
        self._doomed: List[QueueEntry] = []
        self.tokens_in_flight = 0
        self.intake_closed = False
        self.registry = registry if registry is not None else Registry()
        reg = self.registry
        self._c_submitted = reg.counter("sched.submitted")
        self._c_admitted = reg.counter("sched.admitted")
        self._c_retired = reg.counter("sched.retired")
        self._c_preempted = reg.counter("sched.preempted")
        self._c_stalled = reg.counter("sched.stalled")
        self._c_defer_budget = reg.counter("sched.deferred",
                                           reason="token_budget")
        self._c_defer_pages = reg.counter("sched.deferred", reason="pages")
        self._c_term = {s: reg.counter("sched.terminal", status=s)
                        for s in TERMINAL_STATUSES}
        self._h_recompute = reg.histogram(
            "sched.recompute_tokens",
            bounds=tuple(float(2 ** e) for e in range(11)))
        self._g_queue = reg.gauge("sched.queue_depth")
        self._g_inflight = reg.gauge("sched.tokens_in_flight")
        self._g_pages = reg.gauge("sched.pages_in_use")

    # registry-backed lifecycle counts (legacy attribute names preserved)
    @property
    def submitted(self) -> int:
        return int(self._c_submitted.value)

    @property
    def admitted(self) -> int:
        return int(self._c_admitted.value)

    @property
    def retired(self) -> int:
        return int(self._c_retired.value)

    @property
    def preempted(self) -> int:
        return int(self._c_preempted.value)

    @property
    def peak_tokens_in_flight(self) -> int:
        return int(self._g_inflight.max_seen)

    @property
    def peak_pages_in_use(self) -> int:
        return int(self._g_pages.max_seen)

    def terminal_counts(self) -> Dict[str, int]:
        """Terminal transitions per status (exactly one per request)."""
        return {s: int(c.value) for s, c in self._c_term.items()}

    # -- queue ------------------------------------------------------------
    def submit(self, request, arrival_s: float = 0.0,
               resume_tokens: Optional[List[int]] = None,
               preemptions: int = 0) -> Tuple[int, bool]:
        """Queue a request; returns ``(order, accepted)``.

        ``accepted`` is False when intake is closed (drain) or the bounded
        queue is full — the caller owns surfacing the REJECTED terminal
        (the counter is bumped here; orders stay unique either way).
        Deadlines are absolute: ``arrival_s + request.deadline_s``.

        ``resume_tokens`` submits the request as a RESUME entry — tokens it
        already generated elsewhere are teacher-forced through prefill
        exactly like a local preemption's recompute, so greedy decode
        continues token-identically.  This is the cross-replica failover
        migration seam (repro.fleet): a request salvaged from a crashed
        replica re-enters a survivor mid-stream.  Resume entries survive
        ``flush_queue`` (they are in-flight work, not fresh queue).
        """
        order = self.submitted
        self._c_submitted.inc()
        if self.intake_closed or (self.max_queue is not None
                                  and len(self.queue) >= self.max_queue):
            self._c_term[REJECTED].inc()
            return order, False
        rel = getattr(request, "deadline_s", None)
        self.queue.append(QueueEntry(
            order=order, request=request, arrival_s=arrival_s,
            deadline_s=None if rel is None else arrival_s + float(rel),
            resume_tokens=list(resume_tokens) if resume_tokens else [],
            preemptions=int(preemptions)))
        self._g_queue.set(len(self.queue))
        return order, True

    def close_intake(self) -> None:
        """Stop accepting new submissions (drain step 1)."""
        self.intake_closed = True

    def expire_queue(self, now_s: float) -> List[QueueEntry]:
        """Remove queued entries whose deadline has passed; returns them.
        The caller owns surfacing the TIMEOUT results/traces."""
        expired = [e for e in self.queue
                   if e.deadline_s is not None and now_s > e.deadline_s]
        if expired:
            gone = {e.order for e in expired}
            self.queue = deque(e for e in self.queue if e.order not in gone)
            for _ in expired:
                self._c_term[TIMEOUT].inc()
            self._g_queue.set(len(self.queue))
        return expired

    def cancel(self, request_id) -> Optional[Tuple[str, object]]:
        """Find ``request_id`` wherever it lives.  Returns
        ``("queued", QueueEntry)`` (already removed; CANCELLED counted) or
        ``("running", SlotState)`` (the caller retires the slot at the next
        step boundary) or None when unknown / already terminal."""
        for entry in self.queue:
            if entry.request.id == request_id:
                self.queue.remove(entry)
                self._c_term[CANCELLED].inc()
                self._g_queue.set(len(self.queue))
                return ("queued", entry)
        for slot in self.running:
            if slot.request.id == request_id:
                return ("running", slot)
        return None

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    @property
    def running(self) -> List[SlotState]:
        return [s for s in self.slots if not s.free]

    @property
    def idle(self) -> bool:
        return not self.queue and all(s.free for s in self.slots)

    # -- admission --------------------------------------------------------
    def _plan(self, entry: QueueEntry) -> Tuple[int, int, int, int]:
        """(effective prompt len, clamped decode steps, prefill positions,
        worst-case positions) for an entry.  A resumed entry's effective
        prompt is prompt + generated-so-far; its remaining budget shrinks
        by what it already produced, so the worst-case footprint is
        identical to the fresh request's — recompute never inflates it."""
        req = entry.request
        s = len(req.prompt) + len(entry.resume_tokens)
        rem_new = req.max_new_tokens - len(entry.resume_tokens)
        steps = max(1, min(rem_new, self.max_seq - s + 1))
        page = self.table.page_size
        spad = pages_for(s, page) * page          # right-pad prefill bucket
        return s, steps, spad, max(spad, s + steps - 1)

    def try_admit(self, now_s: float = 0.0,
                  arrived_before: Optional[float] = None):
        """Admit queued requests FIFO into free slots; yields filled slots.

        Stops at the first request that does not fit (budget or pages) —
        order is preserved, nothing is skipped.  ``arrived_before`` gates
        admission on simulated arrival times (benchmarks).

        The token budget always charges the worst case (prompt + clamped
        budget).  Pages: ``admission="reserve"`` reserves the worst-case
        position footprint up front; ``"optimistic"`` reserves only the
        prefill bucket — decode growth happens in ``prepare_decode`` and
        can preempt.
        """
        out: List[SlotState] = []
        free = deque(s for s in self.slots if s.free)
        while self.queue and free:
            entry = self.queue[0]
            if (arrived_before is not None
                    and entry.arrival_s > arrived_before):
                break
            s, steps, spad, worst = self._plan(entry)
            if len(entry.request.prompt) > self.max_seq:
                raise ValueError(
                    f"prompt length {len(entry.request.prompt)} exceeds "
                    f"max_seq {self.max_seq}")
            tokens = s + steps
            # liveness: an entry whose worst case exceeds the WHOLE pool
            # (possible after preemption grows a resume prompt, or with an
            # undersized pool) would defer forever — fail it instead.
            cap = min(self.table.allocator.num_pages - 1,
                      self.table.max_pages_per_slot)
            if (pages_for(worst, self.table.page_size) > cap
                    or tokens > self.max_tokens_in_flight):
                self.queue.popleft()
                self._c_term[FAILED].inc()
                self._doomed.append(entry)
                self._g_queue.set(len(self.queue))
                continue
            if self.tokens_in_flight + tokens > self.max_tokens_in_flight:
                self._c_defer_budget.inc()
                break
            slot = free[0]
            positions = spad if self.admission == "optimistic" else worst
            if not self.table.reserve(slot.index, positions):
                self._c_defer_pages.inc()
                break                              # pool exhausted: wait
            free.popleft()
            self.queue.popleft()
            slot.request = entry.request
            slot.order = entry.order
            slot.pos = s
            slot.budget = steps
            slot.tokens = list(entry.resume_tokens)
            slot.arrival_s = entry.arrival_s
            slot.admit_s = now_s
            slot.deadline_s = entry.deadline_s
            slot.preemptions = entry.preemptions
            slot.resume_len = len(entry.resume_tokens)
            slot.total_budget = slot.resume_len + steps
            slot.tif = tokens
            self.tokens_in_flight += tokens
            self._c_admitted.inc()
            out.append(slot)
        self._g_queue.set(len(self.queue))
        self._g_inflight.set(self.tokens_in_flight)
        self._g_pages.set(self.table.allocator.in_use)
        return out

    def drain_doomed(self) -> List[QueueEntry]:
        """Entries ``try_admit`` failed as unadmittable (already counted
        FAILED); the caller surfaces their results/traces."""
        out, self._doomed = self._doomed, []
        return out

    # -- preemption -------------------------------------------------------
    def _victim(self) -> Optional[SlotState]:
        """Youngest running slot still under its preemption bound."""
        cands = [s for s in self.running
                 if s.preemptions < self.max_preemptions]
        return max(cands, key=lambda s: s.order) if cands else None

    def preempt(self, slot: SlotState) -> QueueEntry:
        """Evict a running slot: free its pages, re-queue it AT THE HEAD
        for recompute-prefill with its generated tokens as resume state.
        The engine owns clearing its device-side mirrors for the slot."""
        assert not slot.free, f"preempting free slot {slot.index}"
        self.tokens_in_flight -= slot.tif
        self.table.release(slot.index)
        entry = QueueEntry(
            order=slot.order, request=slot.request,
            arrival_s=slot.arrival_s, deadline_s=slot.deadline_s,
            resume_tokens=list(slot.tokens),
            preemptions=slot.preemptions + 1)
        self.queue.appendleft(entry)
        self._clear(slot)
        self._c_preempted.inc()
        self._h_recompute.observe(len(entry.resume_tokens))
        self._g_queue.set(len(self.queue))
        self._g_inflight.set(self.tokens_in_flight)
        self._g_pages.set(self.table.allocator.in_use)
        return entry

    def prepare_decode(self, chunk: int) -> PrepareDecode:
        """Grow every running slot's pages to cover the next ``chunk``
        decode steps (oldest slot first).  On allocation failure the
        YOUNGEST preemptible running slot is evicted and the reserve is
        retried; a slot with no victim available stalls for this dispatch
        (the engine masks it out).  Under ``admission="reserve"`` the
        worst case is already reserved, so this never allocates.
        """
        runnable: List[SlotState] = []
        stalled: List[SlotState] = []
        preempted: List[Tuple[int, QueueEntry]] = []
        for slot in sorted(self.running, key=lambda s: s.order):
            if slot.free:
                continue                  # preempted as a victim this round
            steps = min(chunk, slot.total_budget - len(slot.tokens))
            if steps <= 0:
                continue                  # nothing left; engine retires it
            need = slot.pos + steps       # positions written so far + next
            ok = self.table.reserve(slot.index, need)
            while not ok and not slot.free:
                victim = self._victim()
                if victim is None:
                    stalled.append(slot)
                    self._c_stalled.inc()
                    break
                preempted.append((victim.index, self.preempt(victim)))
                if victim is slot:
                    break                 # evicted itself: re-queued
                ok = self.table.reserve(slot.index, need)
            if ok and not slot.free:
                runnable.append(slot)
        self._g_pages.set(self.table.allocator.in_use)
        return PrepareDecode(runnable, stalled, preempted)

    # -- retirement -------------------------------------------------------
    def retire(self, slot: SlotState, status: str = FINISHED_BUDGET) -> Dict:
        """Free the slot + its pages; returns the per-request result core.
        ``status`` is the request's terminal state (counted here — the one
        place a slot-resident request goes terminal)."""
        assert not slot.free, f"retiring free slot {slot.index}"
        if status not in TERMINAL_STATUSES:
            raise ValueError(f"unknown terminal status {status!r}")
        self.tokens_in_flight -= slot.tif
        self.table.release(slot.index)
        result = {
            "id": slot.request.id,
            "order": slot.order,
            "tokens": list(slot.tokens),
            "decode_len": len(slot.tokens),
            "status": status,
            "preemptions": slot.preemptions,
        }
        self._clear(slot)
        self._c_retired.inc()
        self._c_term[status].inc()
        self._g_inflight.set(self.tokens_in_flight)
        self._g_pages.set(self.table.allocator.in_use)
        return result

    def _clear(self, slot: SlotState) -> None:
        slot.request = None
        slot.order = -1
        slot.tokens = []
        slot.pos = 0
        slot.budget = 0
        slot.deadline_s = None
        slot.preemptions = 0
        slot.resume_len = 0
        slot.total_budget = 0
        slot.tif = 0

    # -- drain ------------------------------------------------------------
    def flush_queue(self) -> List[QueueEntry]:
        """Drop FRESH queued entries (drain: admitted work finishes, queued
        work is shed as REJECTED).  Preempted entries — in-flight work that
        happens to be queued for recompute — survive and run to completion.
        Returns the dropped entries; the caller surfaces their results."""
        keep: Deque[QueueEntry] = deque()
        dropped: List[QueueEntry] = []
        for entry in self.queue:
            if entry.resume_tokens:
                keep.append(entry)
            else:
                dropped.append(entry)
        self.queue = keep
        for _ in dropped:
            self._c_term[REJECTED].inc()
        self._g_queue.set(len(self.queue))
        return dropped

    # -- telemetry --------------------------------------------------------
    def stats(self) -> Dict:
        return {
            "queue_depth": self.queue_depth,
            "running": len(self.running),
            "tokens_in_flight": self.tokens_in_flight,
            "peak_tokens_in_flight": self.peak_tokens_in_flight,
            "pages_in_use": self.table.allocator.in_use,
            "peak_pages_in_use": self.peak_pages_in_use,
            "page_utilization": self.table.utilization(),
            "submitted": self.submitted,
            "admitted": self.admitted,
            "retired": self.retired,
            "preempted": self.preempted,
            "stalled": int(self._c_stalled.value),
            "recompute_tokens": self._h_recompute.sum,
            "admission": self.admission,
            "max_queue": self.max_queue,
            "max_preemptions": self.max_preemptions,
            "statuses": self.terminal_counts(),
            "deferred_token_budget": int(self._c_defer_budget.value),
            "deferred_pages": int(self._c_defer_pages.value),
        }
