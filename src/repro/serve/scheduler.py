"""Continuous-batching request scheduler: token-budget admission over decode
slots, between device dispatches.

Pure host logic (no jax): the ContinuousEngine consults it between
dispatches of the scanned decode loop.  The hierarchy mirrors the paper's
hardware control stack — a tiny control plane (queue + slot states + block
tables) steering a large data plane (the paged pool + the device loop):

* requests queue FIFO; admission happens only between device dispatches,
  into slots whose previous request retired (no batch-drain barrier),
* a request is admitted when (a) a slot is free, (b) the in-flight token
  budget ``max_tokens_in_flight`` covers its worst case (prompt + budget),
  and (c) the page pool can RESERVE its worst-case footprint up front —
  so a running request can never stall waiting for a page,
* retirement (EOS / budget / cache bound) releases the slot AND its pages
  immediately; the rest of the batch never waits.

Admission is strictly FIFO (no head-of-line skipping): a large request at
the head blocks later small ones, trading a little throughput for no
starvation.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from ..obs.metrics import Registry
from .kvcache import BlockTable, pages_for


@dataclasses.dataclass
class SlotState:
    """One decode slot's in-flight request (None = free)."""
    index: int
    request: object = None            # engine-level Request
    order: int = -1                   # submission index (result ordering)
    pos: int = 0                      # next cache position (= tokens seen)
    budget: int = 0                   # decode steps still allowed
    tokens: List[int] = dataclasses.field(default_factory=list)
    arrival_s: float = 0.0
    admit_s: float = 0.0

    @property
    def free(self) -> bool:
        return self.request is None


class Scheduler:
    """FIFO token-budget admission + slot lifecycle over a BlockTable.

    Lifecycle counters live in a ``repro.obs`` Registry (one is created
    internally when none is passed): ``sched.submitted`` / ``.admitted`` /
    ``.retired`` counters, ``sched.deferred{reason=...}`` counters for
    admission attempts that parked at the token budget or an exhausted
    page pool, and ``sched.queue_depth`` / ``sched.tokens_in_flight``
    gauges (peaks via the gauge high-water marks).  ``stats()`` is a view
    over that registry plus the allocator's page accounting.
    """

    def __init__(self, table: BlockTable, *, max_seq: int,
                 max_tokens_in_flight: int,
                 registry: Optional[Registry] = None):
        self.table = table
        self.max_seq = int(max_seq)
        self.max_tokens_in_flight = int(max_tokens_in_flight)
        self.slots = [SlotState(i) for i in range(table.table.shape[0])]
        self.queue: Deque[Tuple[int, object, float]] = deque()
        self.tokens_in_flight = 0
        self.registry = registry if registry is not None else Registry()
        reg = self.registry
        self._c_submitted = reg.counter("sched.submitted")
        self._c_admitted = reg.counter("sched.admitted")
        self._c_retired = reg.counter("sched.retired")
        self._c_defer_budget = reg.counter("sched.deferred",
                                           reason="token_budget")
        self._c_defer_pages = reg.counter("sched.deferred", reason="pages")
        self._g_queue = reg.gauge("sched.queue_depth")
        self._g_inflight = reg.gauge("sched.tokens_in_flight")
        self._g_pages = reg.gauge("sched.pages_in_use")

    # registry-backed lifecycle counts (legacy attribute names preserved)
    @property
    def submitted(self) -> int:
        return int(self._c_submitted.value)

    @property
    def admitted(self) -> int:
        return int(self._c_admitted.value)

    @property
    def retired(self) -> int:
        return int(self._c_retired.value)

    @property
    def peak_tokens_in_flight(self) -> int:
        return int(self._g_inflight.max_seen)

    @property
    def peak_pages_in_use(self) -> int:
        return int(self._g_pages.max_seen)

    # -- queue ------------------------------------------------------------
    def submit(self, request, arrival_s: float = 0.0) -> int:
        """Queue a request; returns its submission order index."""
        order = self.submitted
        self.queue.append((order, request, arrival_s))
        self._c_submitted.inc()
        self._g_queue.set(len(self.queue))
        return order

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    @property
    def running(self) -> List[SlotState]:
        return [s for s in self.slots if not s.free]

    @property
    def idle(self) -> bool:
        return not self.queue and all(s.free for s in self.slots)

    # -- admission --------------------------------------------------------
    def _clamped_budget(self, request) -> int:
        """Decode budget clamped against the cache bound exactly like the
        batch engine: step j writes position S + j - 1, so at most
        ``max_seq - S + 1`` steps fit."""
        s = len(request.prompt)
        return max(1, min(request.max_new_tokens, self.max_seq - s + 1))

    def _footprint(self, request) -> Tuple[int, int]:
        """(worst-case tokens, worst-case cache positions) for a request."""
        s = len(request.prompt)
        steps = self._clamped_budget(request)
        page = self.table.page_size
        spad = pages_for(s, page) * page          # right-pad prefill bucket
        return s + steps, max(spad, s + steps - 1)

    def try_admit(self, now_s: float = 0.0,
                  arrived_before: Optional[float] = None):
        """Admit queued requests FIFO into free slots; yields filled slots.

        Stops at the first request that does not fit (budget or pages) —
        order is preserved, nothing is skipped.  ``arrived_before`` gates
        admission on simulated arrival times (benchmarks).
        """
        out: List[SlotState] = []
        free = deque(s for s in self.slots if s.free)
        while self.queue and free:
            order, req, arrival = self.queue[0]
            if arrived_before is not None and arrival > arrived_before:
                break
            tokens, positions = self._footprint(req)
            if len(req.prompt) > self.max_seq:
                raise ValueError(f"prompt length {len(req.prompt)} exceeds "
                                 f"max_seq {self.max_seq}")
            if self.tokens_in_flight + tokens > self.max_tokens_in_flight:
                self._c_defer_budget.inc()
                break
            slot = free[0]
            if not self.table.reserve(slot.index, positions):
                self._c_defer_pages.inc()
                break                              # pool exhausted: wait
            free.popleft()
            self.queue.popleft()
            slot.request = req
            slot.order = order
            slot.pos = len(req.prompt)
            slot.budget = self._clamped_budget(req)
            slot.tokens = []
            slot.arrival_s = arrival
            slot.admit_s = now_s
            self.tokens_in_flight += tokens
            self._c_admitted.inc()
            out.append(slot)
        self._g_queue.set(len(self.queue))
        self._g_inflight.set(self.tokens_in_flight)
        self._g_pages.set(self.table.allocator.in_use)
        return out

    # -- retirement -------------------------------------------------------
    def retire(self, slot: SlotState) -> Dict:
        """Free the slot + its pages; returns the per-request result core."""
        assert not slot.free, f"retiring free slot {slot.index}"
        tokens, _ = self._footprint(slot.request)
        self.tokens_in_flight -= tokens
        self.table.release(slot.index)
        result = {
            "id": slot.request.id,
            "order": slot.order,
            "tokens": list(slot.tokens),
            "decode_len": len(slot.tokens),
        }
        slot.request = None
        slot.order = -1
        slot.tokens = []
        slot.pos = 0
        slot.budget = 0
        self._c_retired.inc()
        self._g_inflight.set(self.tokens_in_flight)
        self._g_pages.set(self.table.allocator.in_use)
        return result

    # -- telemetry --------------------------------------------------------
    def stats(self) -> Dict:
        return {
            "queue_depth": self.queue_depth,
            "running": len(self.running),
            "tokens_in_flight": self.tokens_in_flight,
            "peak_tokens_in_flight": self.peak_tokens_in_flight,
            "pages_in_use": self.table.allocator.in_use,
            "peak_pages_in_use": self.peak_pages_in_use,
            "page_utilization": self.table.utilization(),
            "submitted": self.submitted,
            "admitted": self.admitted,
            "retired": self.retired,
            "deferred_token_budget": int(self._c_defer_budget.value),
            "deferred_pages": int(self._c_defer_pages.value),
        }
