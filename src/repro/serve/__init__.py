from . import decode, engine  # noqa: F401
