from . import decode, engine, params  # noqa: F401
from .params import precompute_serving_params, strip_serving_params  # noqa: F401
