from . import decode, engine, faults, kvcache, params, scheduler  # noqa: F401
from .params import precompute_serving_params, strip_serving_params  # noqa: F401
