"""Offline spectral-weight precomputation for serving.

The paper's hardware story FFTs the block-circulant weights ONCE, offline,
and keeps only the spectral planes on-chip; the serve hot path then runs
input-DFT -> spectral MAC -> iDFT with no weight transform in the loop.
``precompute_serving_params`` is that offline pass as a parameter-tree
transform: it walks the params once and bakes

* ``wc_cache``       next to every block-circulant generator ``wc`` that the
                     serve lowering resolves to the spectral path (rfft real
                     planes + Gauss combos, see ``core.circulant``),
* ``qkv_cache``      at attention-params level (q/k/v planes concatenated on
                     the output-block axis) when projection fusion is on, so
                     the fused QKV projection is one cached contraction —
                     the per-projection q/k/v planes it shadows are dropped
                     (single-copy footprint; cross-attention never fuses and
                     keeps them),
* ``upgate_cache``   likewise for gated-MLP up/gate pairs,
* ``{up,gate,down}_cache`` inside per-expert MoE stacks.

``apply_linear`` / ``bc_matmul_fused`` / ``_expert_ffn`` consult these only
outside train mode, so the same tree remains valid for training (the caches
are simply dead weight there — strip with ``strip_serving_params``).
"""
from __future__ import annotations

from typing import Any

import numpy as np

from ..configs.base import ArchConfig
from ..core import circulant as cc

_CACHE_KEYS = ("wc_cache", "qkv_cache", "upgate_cache",
               "up_cache", "gate_cache", "down_cache")


def _spectral_at_serve(comp, k: int) -> bool:
    """Whether a block-size-k projection serves through the spectral path
    (same dispatch `apply_linear` runs, so the bake never changes a layer's
    resolved lowering)."""
    if not k:
        return False
    spec = cc.LinearSpec("block_circulant", k, comp.path, comp.gauss_trick)
    return spec.resolve_path("serve") == "spectral"


def _is_bc(node: Any) -> bool:
    return isinstance(node, dict) and "wc" in node and not isinstance(
        node["wc"], dict)


def _same_block(nodes) -> bool:
    shapes = [n["wc"].shape for n in nodes]
    return all(s[-2:] == shapes[0][-2:] and len(s) == len(shapes[0])
               for s in shapes)


def precompute_serving_params(params, cfg: ArchConfig, policy=None):
    """Bake spectral serving caches into a parameter tree (pure transform).

    Returns a new tree with the same original leaves plus the cache entries;
    idempotent (already-baked subtrees are left alone).  Works under
    ``jax.eval_shape`` (the dry-run bakes shape-structs, no allocation).

    With a ``repro.quant.QuantPolicy`` whose ``quant_weights`` is set, the
    baked planes are additionally quantized to int8 (or int4-packed) with
    per-block-row scales — the fixed-point serving weights of the paper's
    hardware half (see docs/quantization.md).
    """
    comp = cfg.compression
    if not comp.enabled:
        return params
    gauss = comp.gauss_trick
    fuse = getattr(comp, "fuse_projections", False)
    k_exp = comp.block_for("expert")

    def fusable(node, names, name):
        """Will the fused serve path shadow these projections' planes?
        ("o" excludes the look-alike mLSTM cell dict, which does not fuse;
        cross-attention never fuses either, so its subtree keeps only the
        per-projection planes.)"""
        return (fuse and name != "cross"
                and ("o" in node if "q" in names else True)
                and all(_is_bc(node.get(n)) for n in names)
                and _same_block([node[n] for n in names])
                and _spectral_at_serve(comp,
                                       int(node[names[0]]["wc"].shape[-1])))

    def bake(node, name="", shadowed=False):
        if isinstance(node, dict):
            fuse_qkv = fusable(node, ("q", "k", "v"), name)
            fuse_upgate = fusable(node, ("up", "gate"), name)
            shadow = (({"q", "k", "v"} if fuse_qkv else set())
                      | ({"up", "gate"} if fuse_upgate else set()))
            out = {key: bake(v, key, key in shadow)
                   for key, v in node.items()}
            # per-projection planes (the generic case: o/down/out/…) —
            # skipped when a fused cache below will shadow them, keeping the
            # serving-cache footprint single-copy
            if _is_bc(node) and "wc_cache" not in node and not shadowed:
                k = int(node["wc"].shape[-1])
                if _spectral_at_serve(comp, k):
                    out["wc_cache"] = cc.spectral_cache(node["wc"], gauss)
            if fuse_qkv and "qkv_cache" not in node:
                out["qkv_cache"] = cc.fused_spectral_cache(
                    [node[n]["wc"] for n in ("q", "k", "v")], gauss)
            if fuse_upgate and "upgate_cache" not in node:
                out["upgate_cache"] = cc.fused_spectral_cache(
                    [node[n]["wc"] for n in ("up", "gate")], gauss)
            # per-expert stacks: (E, p, q, k) arrays, not LinearSpec dicts
            if (k_exp and _spectral_at_serve(comp, k_exp)
                    and all(not isinstance(node.get(n), dict)
                            and getattr(node.get(n), "ndim", 0) >= 4
                            and node[n].shape[-1] == k_exp
                            for n in ("up", "gate", "down"))):
                for n in ("up", "gate", "down"):
                    if f"{n}_cache" not in node:
                        out[f"{n}_cache"] = cc.spectral_cache(node[n], gauss)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(bake(v, name, shadowed) for v in node)
        return node

    baked = bake(params)
    if policy is not None and getattr(policy, "quant_weights", False):
        from ..quant.codec import quantize_serving_params
        baked = quantize_serving_params(baked, policy.weight_bits)
    return baked


def strip_serving_params(params):
    """Remove every baked serving cache (inverse of the precompute pass)."""
    def strip(node):
        if isinstance(node, dict):
            return {k: strip(v) for k, v in node.items()
                    if k not in _CACHE_KEYS}
        if isinstance(node, (list, tuple)):
            return type(node)(strip(v) for v in node)
        return node
    return strip(params)


def serving_cache_bytes(params) -> int:
    """Total bytes of baked spectral planes (reporting/benchmarks)."""
    total = 0

    def walk(path, node):
        nonlocal total
        if isinstance(node, dict):
            for k, v in node.items():
                walk(path + (k,), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(path + (str(i),), v)
        elif any(c in path for c in _CACHE_KEYS):
            total += int(node.size) * np.dtype(node.dtype).itemsize

    walk((), params)
    return total
