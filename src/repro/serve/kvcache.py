"""Paged KV-cache pool: fixed-size blocks, per-request block tables, and a
free-list allocator.

The paper's accelerator wins its throughput by keeping the compute units fed
— batch processing + resource re-use under a hierarchical controller.  The
dense serving cache breaks that on the memory side: every request owns a
``(max_seq, Hkv, D)`` slab per layer until the *slowest* request in its
batch finishes.  This module replaces the slab with vLLM-style paging:

* the pool is one ``(num_pages, page_size, Hkv, D)`` tensor per attention
  layer (stacked over scan groups like the dense cache it replaces),
* a request owns an ordered list of page ids; position ``i`` lives at page
  ``table[i // page_size]``, offset ``i % page_size``,
* pages come from a host-side free list, are RESERVED up front for a
  request's worst case (prompt + budget — admission can never deadlock
  mid-decode), and go back to the free list the moment the request
  retires (EOS / budget), not when its batch drains.

Page id 0 is the TRASH page: never allocated, it absorbs the masked writes
of idle/frozen decode slots (see layers/attention.py paged branch).

Host bookkeeping (``PageAllocator`` / ``BlockTable``) is pure python so the
scheduler invariants are hypothesis-testable without a device; the device
pool is a plain pytree built by ``build_pool`` and threaded through the
decode loop like the dense cache.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models.registry import build_model
from ..quant.codec import QuantPolicy, quantize_page_block

TRASH_PAGE = 0


def pages_for(n_positions: int, page_size: int) -> int:
    """Pages needed to hold ``n_positions`` cache slots."""
    return max(1, -(-int(n_positions) // page_size))


class PageAllocator:
    """LIFO free-list over ``num_pages`` pages; page 0 (trash) is reserved.

    ``alloc`` returns None instead of raising when the pool is exhausted —
    the scheduler treats that as "request stays queued" (or, under
    optimistic admission, as a preemption trigger).  ``fault`` is an
    optional hook (``fault(n) -> bool``; see serve/faults.py): when it
    returns True an alloc is forced to fail as if the pool were empty —
    the chaos suite drives the preemption/stall paths with it.

    ``free`` raises on a double free, on a page the allocator never
    handed out, and on the reserved trash page — all three silently
    corrupt the free list otherwise (a page ends up owned by two slots).

    With a metrics ``registry`` (repro.obs) the allocator keeps the
    ``pool.free_pages`` gauge and the ``pool.pages_alloc`` /
    ``pool.pages_freed`` churn counters current on every alloc/free — the
    over-time view of what ``in_use`` reports point-in-time.
    """

    def __init__(self, num_pages: int, registry=None, fault=None):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is the trash)")
        self.num_pages = int(num_pages)
        self._free = list(range(self.num_pages - 1, 0, -1))
        self._held: set = set()
        self.fault = fault
        self._free_gauge = self._alloc_ctr = self._freed_ctr = None
        if registry is not None:
            self._free_gauge = registry.gauge("pool.free_pages")
            self._free_gauge.set(len(self._free))
            self._alloc_ctr = registry.counter("pool.pages_alloc")
            self._freed_ctr = registry.counter("pool.pages_freed")

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._held)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        if self.fault is not None and self.fault(n):
            return None                    # injected failure: as-if empty
        pages = [self._free.pop() for _ in range(n)]
        self._held.update(pages)
        if self._alloc_ctr is not None:
            self._alloc_ctr.inc(n)
            self._free_gauge.set(len(self._free))
        return pages

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p == TRASH_PAGE:
                raise ValueError("freeing the reserved trash page "
                                 f"{TRASH_PAGE}")
            if p not in self._held:
                if 0 < p < self.num_pages:
                    raise ValueError(f"double free of page {p}")
                raise ValueError(f"foreign page {p} (allocator holds "
                                 f"1..{self.num_pages - 1})")
            self._held.discard(p)
            self._free.append(p)
        if self._freed_ctr is not None:
            self._freed_ctr.inc(len(pages))
            self._free_gauge.set(len(self._free))


class BlockTable:
    """Per-slot page ownership over a shared allocator.

    Rows are dense ``(max_slots, max_pages_per_slot)`` int32 (device-ready);
    unowned entries hold TRASH_PAGE.  ``reserve`` grows a slot's mapping to
    cover ``n_positions`` cache slots (False = pool exhausted, nothing
    changes); ``release`` returns every page of a slot to the free list and
    is IDEMPOTENT (releasing an already-released slot is a no-op — the
    engine's cancel/timeout/preempt paths may race a natural retire).

    ``version`` increments on every mutation that changes the dense table
    (page growth, release) — the engine re-uploads its device copy only
    when the version moved, instead of hand-invalidating a cached array.
    """

    def __init__(self, allocator: PageAllocator, max_slots: int,
                 page_size: int, max_pages_per_slot: int):
        self.allocator = allocator
        self.page_size = int(page_size)
        self.max_pages_per_slot = int(max_pages_per_slot)
        self.table = np.full((max_slots, max_pages_per_slot), TRASH_PAGE,
                             np.int32)
        self.owned: List[List[int]] = [[] for _ in range(max_slots)]
        self.version = 0

    def reserve(self, slot: int, n_positions: int) -> bool:
        need = pages_for(n_positions, self.page_size)
        if need > self.max_pages_per_slot:
            raise ValueError(
                f"request needs {need} pages > max_pages_per_slot "
                f"{self.max_pages_per_slot} (raise max_seq/page budget)")
        extra = need - len(self.owned[slot])
        if extra <= 0:
            return True
        pages = self.allocator.alloc(extra)
        if pages is None:
            return False
        start = len(self.owned[slot])
        self.owned[slot].extend(pages)
        self.table[slot, start:start + extra] = pages
        self.version += 1
        return True

    def release(self, slot: int) -> None:
        if not self.owned[slot]:
            return                          # idempotent: already released
        self.allocator.free(self.owned[slot])
        self.owned[slot] = []
        self.table[slot, :] = TRASH_PAGE
        self.version += 1

    def pages(self, slot: int) -> List[int]:
        return list(self.owned[slot])

    def device_table(self) -> jax.Array:
        return jnp.asarray(self.table)

    def utilization(self) -> float:
        usable = self.allocator.num_pages - 1
        return self.allocator.in_use / max(usable, 1)


# ---------------------------------------------------------------------------
# Device pool construction + prefill packing
# ---------------------------------------------------------------------------
def _is_kv_leaf(node: Any) -> bool:
    return isinstance(node, dict) and "k" in node and "v" in node


def servable_reasons(cfg: ArchConfig) -> List[str]:
    """Why a config can NOT be served by the paged continuous engine.

    Paged serving needs per-slot positions and linear KV caches: sliding
    windows (ring buffers), recurrent state (position-free but prefill is
    not right-pad safe), learned positions, and encoder-decoder stacks stay
    on the batch engine.  Empty list = servable.
    """
    from ..models import transformer as tfm
    reasons = []
    if cfg.is_encoder_decoder:
        reasons.append("encoder-decoder (cross-attention cache)")
    if cfg.attention.learned_pos or cfg.max_position:
        reasons.append("learned positions (scalar-position table lookup)")
    kinds = {k for pattern, _ in tfm.segments_for(cfg) for k in pattern}
    bad = kinds - {"attn", "moe"}
    if bad:
        reasons.append(f"block kinds {sorted(bad)} (sliding-window ring "
                       f"buffers / recurrent state)")
    return reasons


def build_pool(cfg: ArchConfig, num_pages: int, page_size: int,
               policy: Optional[QuantPolicy] = None):
    """Paged pool pytree mirroring ``model.init_cache``'s structure.

    Every attention cache leaf ``{"k": (n, B, S, Hkv, D), "v": ..., "pos"}``
    becomes ``{"k": (n, num_pages, page_size, Hkv, D), "v": ...}`` — one
    shared pool per layer, indexed by the same block table at every layer
    (a logical page id is valid for the whole stack).  The "pos" leaf is
    dropped: validity is carried by the per-slot position vector.

    The storage dtype is a first-class ``QuantPolicy`` field
    (``policy.kv_dtype``: "f32" default | "bf16" | "int8").  An int8 pool
    additionally carries per-(page, head) absmax scales next to each leaf
    (``{"k", "v", "k_scale", "v_scale"}`` — scales are f32
    ``(n, num_pages, Hkv)``, written by the prefill pack and the decode
    page-scatter, read by the quantized paged-attention lane).
    """
    policy = policy or QuantPolicy()
    if servable_reasons(cfg):
        raise ValueError(f"{cfg.name}: not paged-servable: "
                         f"{'; '.join(servable_reasons(cfg))}")
    dtype = policy.pool_dtype
    struct = jax.eval_shape(
        lambda: build_model(cfg).init_cache(1, page_size, dtype=jnp.float32))

    def transform(node):
        if _is_kv_leaf(node):
            n, _, _, hkv, d = node["k"].shape
            shape = (n, num_pages, page_size, hkv, d)
            out = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
            if policy.kv_quantized:
                sshape = (n, num_pages, hkv)
                out["k_scale"] = jnp.zeros(sshape, jnp.float32)
                out["v_scale"] = jnp.zeros(sshape, jnp.float32)
            return out
        if isinstance(node, dict):
            return {k: transform(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(transform(v) for v in node)
        raise ValueError(f"unexpected cache leaf {node!r} in paged pool")

    return transform(struct)


def pack_prefill_cache(pool, dense_cache, pages: jax.Array, page_size: int,
                       true_len=None, with_stats: bool = False):
    """Scatter a B=1 dense prefill cache into a slot's reserved pages.

    ``dense_cache`` leaves are (n, 1, Spad, Hkv, D) with Spad a multiple of
    ``page_size``; ``pages`` is (Spad // page_size,) int32.  Pure function
    (jit with the pool donated); returns the updated pool tree.

    An int8 pool (``k_scale`` present) quantizes each prefill page whole:
    one absmax scale per (page, head) over the page's Spad slice.  With
    ``true_len`` (the unpadded prompt length, traced scalar) the right-pad
    tail is ZEROED before the scale derivation — pad positions hold real
    K/V activations whose magnitude would otherwise inflate the last
    page's scale and with it the quantization error of every real token
    sharing that page (the tail itself stays position-masked on read and
    is overwritten by decode either way).  Unquantized pools ignore
    ``true_len`` (garbage tail values are free when no scale reads them).

    With ``with_stats`` the return becomes ``(pool, clipped, total)`` —
    device scalar counts of page-write values saturating the int8 rail
    (|q| == qmax) and of values written, both restricted to VALID
    (non-pad) positions.  With absmax scaling the block-max element sits
    at the rail by construction, so the clip rate is a saturation-
    pressure signal, not an overflow count (docs/quantization.md); f32
    pools report zeros.
    """
    acc = {"clipped": jnp.float32(0.0), "total": jnp.float32(0.0)}

    def pack(pnode, dnode):
        if _is_kv_leaf(pnode):
            out = {}
            for key in ("k", "v"):
                leaf = dnode[key]                       # (n, 1, Spad, H, D)
                n, _, spad, hkv, d = leaf.shape
                npg = spad // page_size
                vals = leaf.reshape(n, npg, page_size, hkv, d)
                if key + "_scale" in pnode:             # int8 pool
                    valid = None
                    if true_len is not None:
                        valid = (jnp.arange(spad) < true_len).reshape(
                            npg, page_size)
                        vals = jnp.where(
                            valid[None, :, :, None, None], vals, 0.0)
                    qvals, scales = quantize_page_block(vals)
                    if with_stats:
                        sat = jnp.abs(qvals.astype(jnp.int32)) >= 127
                        if valid is not None:
                            mask = valid[None, :, :, None, None]
                            sat = sat & mask
                            nvalid = (jnp.sum(valid).astype(jnp.float32)
                                      * n * hkv * d)
                        else:
                            nvalid = jnp.float32(qvals.size)
                        acc["clipped"] += jnp.sum(sat).astype(jnp.float32)
                        acc["total"] += nvalid
                    out[key] = pnode[key].at[:, pages].set(qvals)
                    out[key + "_scale"] = pnode[
                        key + "_scale"].at[:, pages].set(scales)
                else:
                    vals = vals.astype(pnode[key].dtype)
                    out[key] = pnode[key].at[:, pages].set(vals)
            return out
        if isinstance(pnode, dict):
            return {k: pack(v, dnode[k]) for k, v in pnode.items()}
        if isinstance(pnode, (list, tuple)):
            return type(pnode)(pack(v, d) for v, d in zip(pnode, dnode))
        raise ValueError(f"unexpected pool node {pnode!r}")

    packed = pack(pool, dense_cache)
    if with_stats:
        return packed, acc["clipped"], acc["total"]
    return packed


def pool_bytes(pool) -> int:
    """Total bytes of the device pool (telemetry; includes quantization
    scales when the pool is int8 — works on ShapeDtypeStructs too)."""
    return sum(int(leaf.size) * np.dtype(leaf.dtype).itemsize
               for leaf in jax.tree.leaves(pool))


def page_bytes(cfg: ArchConfig, page_size: int,
               policy: Optional[QuantPolicy] = None) -> int:
    """Bytes one page costs across every layer of the stack (scales
    included for int8).  Zero allocation (eval_shape); the equal-KV-memory
    benchmarks use this to size pools of different dtypes to one byte
    budget: ``num_pages = budget // page_bytes(...)``."""
    return pool_bytes(jax.eval_shape(
        lambda: build_pool(cfg, 1, page_size, policy)))


def attention_bytes_per_position(pool) -> Dict[str, int]:
    """Per-position attention byte terms of a pool tree.

    ``per_pos`` — HBM bytes one live cache position costs a decode-step
    attention read (K+V over every layer/group, in the pool's storage
    dtype); ``widest`` — K+V bytes of one position in the widest single
    layer (the unit of a transient gathered/streamed buffer).  Shared by
    the worst-case estimate below and the engine's per-dispatch
    ``attn.bytes_per_token`` histogram (which multiplies ``per_pos`` by
    the LIVE slot lengths instead of the worst case).
    """
    per_pos, widest = 0, 0

    def walk(node):
        nonlocal per_pos, widest
        if _is_kv_leaf(node):
            n = node["k"].shape[0]
            hkv, d = node["k"].shape[-2:]
            item = np.dtype(node["k"].dtype).itemsize
            per_pos += 2 * n * hkv * d * item          # k + v, all groups
            widest = max(widest, 2 * hkv * d * item)
        elif isinstance(node, dict):
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(pool)
    return {"per_pos": per_pos, "widest": widest}


def pool_scales(pool) -> Optional[np.ndarray]:
    """Flat host copy of every quantization-scale leaf (``k_scale`` /
    ``v_scale``), or None for an unquantized pool.  The engine diffs two
    of these around a decode dispatch to count ``quant.scale_growths``
    (page-scatter requantize-on-grow events — codec.page_scatter scales
    only ever grow in place, so ``new > old`` identifies them); the
    transfer is a few KB and runs only when obs tracing is enabled."""
    leaves = []

    def walk(node):
        if _is_kv_leaf(node):
            for key in ("k_scale", "v_scale"):
                if key in node:
                    leaves.append(np.asarray(node[key]).ravel())
        elif isinstance(node, dict):
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(pool)
    if not leaves:
        return None
    return np.concatenate(leaves)


def pool_scale_map(pool) -> Optional[Dict[str, np.ndarray]]:
    """Like ``pool_scales`` but split per plane:
    ``{"k_scale": flat, "v_scale": flat}`` host copies (or None for an
    unquantized pool).  The engine's scale-shadow diff uses this to
    attribute requantize-on-grow events and the saturation histograms to
    the K vs V plane separately (``quant.k_scale`` / ``quant.v_scale``,
    docs/observability.md "Numerics & quality health")."""
    leaves: Dict[str, list] = {"k_scale": [], "v_scale": []}

    def walk(node):
        if _is_kv_leaf(node):
            for key in ("k_scale", "v_scale"):
                if key in node:
                    leaves[key].append(np.asarray(node[key]).ravel())
        elif isinstance(node, dict):
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(pool)
    if not any(leaves.values()):
        return None
    return {k: np.concatenate(v) for k, v in leaves.items() if v}


def attention_memory_est(pool, max_slots: int, max_pages_per_slot: int,
                         page_size: int, impl: str = "stream") -> Dict:
    """Analytic decode-attention memory estimates over a pool tree.

    Worst case (every slot serving a full ``max_pages_per_slot * page_size``
    history), for the telemetry the serving benchmarks record:

    * ``attention_bytes_per_token`` — HBM bytes attention touches to emit
      ONE token for one slot, summed over every attention layer.  The
      streamed flash-decode reads each live position's K+V once; the legacy
      gather path additionally writes and re-reads the dense gathered view
      (3x the traffic).
    * ``peak_attention_bytes`` — the largest transient attention buffer of
      one decode step: gather materializes ``(B, maxp * page, Hkv, D)`` k+v
      views of the widest layer, the streamed path holds one
      ``BLOCK_PAGES``-page chunk per slot (the 'off' scan streams that many
      pages per step — kernels/paged_attention.py).

    Byte terms follow the pool leaf dtype, so an int8 pool's traffic is
    counted in int8 bytes (the per-(page, head) scale reads are < 1% of
    the K/V bytes and excluded).
    """
    from ..kernels.paged_attention import BLOCK_PAGES
    terms = attention_bytes_per_position(pool)
    per_pos, widest = terms["per_pos"], terms["widest"]
    max_len = max_pages_per_slot * page_size
    if impl == "gather":
        return {"attention_bytes_per_token": 3 * per_pos * max_len,
                "peak_attention_bytes": max_slots * max_len * widest}
    chunk = min(BLOCK_PAGES, max_pages_per_slot) * page_size
    return {"attention_bytes_per_token": per_pos * max_len,
            "peak_attention_bytes": max_slots * chunk * widest}
