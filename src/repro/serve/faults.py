"""Deterministic seeded fault injection + the chaos invariant suite.

The paper's hardware half survives contention by construction (reconfig-
urable fabric, hierarchical control); the serving stack has to EARN the
same property, so this module makes failure a first-class, reproducible
input.  ``FaultInjector`` hooks three seams of the continuous engine:

* **allocator failure** (``alloc_fail_p``): ``PageAllocator.alloc``
  consults the injector and fails as if the pool were empty — driving the
  optimistic-admission preemption/stall paths far harder than organic
  page pressure would;
* **dispatch delay** (``dispatch_delay_p`` / ``dispatch_delay_s``): a
  host-side sleep before a decode dispatch, widening the windows in which
  deadlines expire and cancels land mid-flight;
* **slot corruption** (``corrupt_p``): NaN-poisons the first owned page
  of a running slot before a dispatch — the decode loop's device-side
  NaN/Inf guard must freeze the slot and the engine must retire it
  FAILED (never streaming garbage tokens).

Everything is keyed by one ``numpy.random.RandomState(seed)``, so a chaos
run is a pure function of (arch, seed, workload) — CI replays the same
three seeds forever.

``run_chaos`` is the invariant suite (CI `chaos` step;
``python -m repro.serve.faults --seed N``): it drives the engine through
the low-level submit/step/cancel API with randomized deadlines, cancels,
and injected faults, then asserts the lifecycle invariants:

1. every submitted request reaches EXACTLY ONE terminal status,
2. the free-page count returns to its initial value (no leaks), the
   block table is all-trash, and no tokens remain in flight,
3. non-faulted finished requests are token-identical to the B=1 batch
   oracle (greedy; preemption-and-recompute must be invisible), and
   partially-served terminals (cancel/timeout) are a PREFIX of the
   oracle's tokens,
4. the numerics health plane (obs/health.py) surfaces every NaN-guard
   trip (``health.nonfinite_dispatches >= anomalies``) and, when any
   anomaly fired, the stock SLO watchdog emitted at least one
   ``anomaly-burst`` alert record (validated in the JSONL output).

Poisoned pages are safe to recycle: prefill packs whole pages before any
position becomes valid, decode overwrites a position before its validity
flips, and the attention mask is ``where``-based (masked lanes drop NaN
instead of multiplying by it).  Int8 pools carry the poison in the page
scales instead; the chaos suite itself runs the f32 pool, where the
oracle comparison is exact.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from . import scheduler as sched_mod


@dataclasses.dataclass
class FaultConfig:
    """Knobs for one seeded injector (all probabilities per-event)."""
    seed: int = 0
    alloc_fail_p: float = 0.0          # per PageAllocator.alloc call
    dispatch_delay_p: float = 0.0      # per decode dispatch
    dispatch_delay_s: float = 0.0      # injected sleep when it fires
    corrupt_p: float = 0.0             # per decode dispatch
    # replica-level faults (consulted by fleet.EngineReplica.step)
    crash_p: float = 0.0               # per replica step: hard crash (DOWN)
    hang_p: float = 0.0                # per replica step: wedge the step...
    hang_s: float = 0.0                # ...for this long (heartbeat stalls)


class FaultInjector:
    """Seeded fault source the engine consults at its three seams.

    Wire it with ``ContinuousEngine(..., faults=FaultInjector(cfg))`` —
    the engine installs ``alloc_fault`` as the allocator's fault hook and
    calls ``dispatch_delay`` / ``pick_corruption`` before each decode
    dispatch.  ``corrupted_ids`` records which request ids were poisoned
    (the chaos suite excludes exactly those from oracle parity).
    """

    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self.rng = np.random.RandomState(cfg.seed)
        self.alloc_failures = 0
        self.delays = 0
        self.corruptions = 0
        self.crashes = 0
        self.hangs = 0
        self.corrupted_ids: set = set()

    def alloc_fault(self, n: int) -> bool:
        """PageAllocator hook: True forces this alloc to fail."""
        if self.cfg.alloc_fail_p <= 0.0:
            return False
        if self.rng.random_sample() < self.cfg.alloc_fail_p:
            self.alloc_failures += 1
            return True
        return False

    def dispatch_delay(self) -> float:
        """Seconds to sleep before the next decode dispatch (0 = none)."""
        if (self.cfg.dispatch_delay_p <= 0.0
                or self.cfg.dispatch_delay_s <= 0.0):
            return 0.0
        if self.rng.random_sample() < self.cfg.dispatch_delay_p:
            self.delays += 1
            return self.cfg.dispatch_delay_s
        return 0.0

    def pick_corruption(self, running: Sequence) -> Optional[object]:
        """A running slot to NaN-poison before this dispatch, or None.
        Each request is poisoned at most once (the guard retires it on the
        very next dispatch, so a second draw would be wasted)."""
        if self.cfg.corrupt_p <= 0.0 or not running:
            return None
        if self.rng.random_sample() >= self.cfg.corrupt_p:
            return None
        slot = running[int(self.rng.randint(len(running)))]
        if slot.request.id in self.corrupted_ids:
            return None
        self.corrupted_ids.add(slot.request.id)
        self.corruptions += 1
        return slot

    def maybe_crash(self) -> bool:
        """Replica hook: True crashes the replica on this step (DOWN)."""
        if self.cfg.crash_p <= 0.0:
            return False
        if self.rng.random_sample() < self.cfg.crash_p:
            self.crashes += 1
            return True
        return False

    def hang_delay(self) -> float:
        """Replica hook: seconds this step wedges for (0 = no hang).  The
        replica's heartbeat stalls, feeding its step-timeout machinery."""
        if self.cfg.hang_p <= 0.0 or self.cfg.hang_s <= 0.0:
            return 0.0
        if self.rng.random_sample() < self.cfg.hang_p:
            self.hangs += 1
            return self.cfg.hang_s
        return 0.0

    def stats(self) -> Dict:
        return {
            "seed": self.cfg.seed,
            "alloc_failures": self.alloc_failures,
            "delays": self.delays,
            "corruptions": self.corruptions,
            "crashes": self.crashes,
            "hangs": self.hangs,
            "corrupted_ids": sorted(self.corrupted_ids),
        }


def poison_slot_pages(pool, page: int):
    """NaN-poison one pool page across every layer (both scan-group dims).

    Float pools poison the K values; int8 pools poison the K scales (the
    int8 payload cannot hold a NaN).  The next attention read over a live
    position of this page produces NaN logits, which the decode loop's
    device-side guard converts into a frozen slot + ``anom`` flag.
    """
    import jax
    import jax.numpy as jnp

    from . import kvcache as kvc

    def poison(node):
        if not kvc._is_kv_leaf(node):
            return node
        out = dict(node)
        if "k_scale" in node:
            out["k_scale"] = node["k_scale"].at[:, page].set(jnp.nan)
        else:
            out["k"] = node["k"].at[:, page].set(jnp.nan)
        return out

    return jax.tree_util.tree_map(poison, pool, is_leaf=kvc._is_kv_leaf)


# ---------------------------------------------------------------------------
# Chaos invariant suite (CI `chaos` step; tests/test_faults.py wraps it)
# ---------------------------------------------------------------------------
def make_chaos_workload(n: int, *, vocab: int, seed: int,
                        prompt_lens=(6, 10, 16), budgets=(2, 5, 9, 16),
                        deadline_frac: float = 0.3,
                        deadline_choices=(0.05, 0.4, 5.0)):
    """``n`` requests with randomized prompts/budgets and a ``deadline_frac``
    fraction carrying (sometimes very tight) deadlines.  Lengths/budgets
    draw from small sets so the oracle's per-shape compiles stay bounded."""
    from .engine import Request
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        s = int(rng.choice(prompt_lens))
        prompt = rng.randint(1, vocab, size=s).astype(np.int32)
        dl = (float(rng.choice(deadline_choices))
              if rng.random_sample() < deadline_frac else None)
        reqs.append(Request(prompt=prompt, id=i,
                            max_new_tokens=int(rng.choice(budgets)),
                            deadline_s=dl))
    arrivals = np.cumsum(rng.exponential(0.01, size=n)).tolist()
    return reqs, arrivals


def run_chaos(arch: str = "tinyllama-1.1b", seed: int = 0,
              requests: int = 24, cancel_p: float = 0.08,
              metrics_out: Optional[str] = None,
              verbose: bool = True) -> Dict:
    """Drive the continuous engine through randomized lifecycle chaos and
    assert the invariants.  Returns a summary dict (raises AssertionError
    on any violation).  Deterministic given (arch, seed, requests)."""
    import jax

    from ..configs import registry as config_registry
    from ..models.registry import build_model
    from ..obs import Obs, SloWatchdog
    from .engine import ContinuousEngine, Engine

    cfg = config_registry.get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = 64
    reqs, arrivals = make_chaos_workload(requests, vocab=cfg.vocab_size,
                                         seed=seed)

    # B=1 greedy oracle per request (no deadline pressure, no faults)
    oracle_eng = Engine(cfg, params, max_batch=1, max_seq=max_seq)
    oracle = {r.id: oracle_eng.generate(
        [dataclasses.replace(r, deadline_s=None)])[0]["tokens"]
        for r in reqs}

    faults = FaultInjector(FaultConfig(
        seed=seed, alloc_fail_p=0.05, dispatch_delay_p=0.1,
        dispatch_delay_s=0.002, corrupt_p=0.08))
    # the stock SLO watchdog rides the snapshot cadence: injected NaN
    # poison must surface as anomaly-burst alert records
    watchdog = SloWatchdog()
    obs = (Obs(emit_path=metrics_out, emit_every=5, slo=watchdog)
           if metrics_out else Obs(slo=watchdog))
    # a small pool (half the slots' full-grown footprint) forces organic
    # page pressure on top of the injected allocator failures
    eng = ContinuousEngine(
        cfg, params, max_slots=4, max_seq=max_seq, page_size=8,
        num_pages=9, decode_chunk=4, obs=obs,
        admission="optimistic", max_queue=requests, max_preemptions=4,
        faults=faults)
    allocator = eng.block_table.allocator
    free0 = allocator.available

    rng = np.random.RandomState(seed + 1)
    orders = {}
    events = 0
    for r, a in zip(reqs, arrivals):
        orders[r.id] = eng.submit(r, a)
        events += 1
    live = set(orders)
    steps = 0
    while not eng.scheduler.idle:
        steps += 1
        if not eng.step():
            time.sleep(0.001)          # head of queue hasn't arrived yet
        events += 1
        # randomized cancels against whatever is still live
        live = {i for i in live if eng.result(orders[i]) is None}
        if live and rng.random_sample() < cancel_p:
            target = int(rng.choice(sorted(live)))
            if eng.cancel(target):
                events += 1
        if steps > 50_000:
            raise AssertionError("chaos run did not converge")
    eng.drain()

    # -- invariant 1: exactly one terminal state per request --------------
    results = {i: eng.result(o) for i, o in orders.items()}
    missing = [i for i, res in results.items() if res is None]
    assert not missing, f"requests with no terminal result: {missing}"
    statuses = {i: res["status"] for i, res in results.items()}
    bad = {i: s for i, s in statuses.items()
           if s not in sched_mod.TERMINAL_STATUSES}
    assert not bad, f"non-terminal statuses: {bad}"
    term_counts = eng.scheduler.terminal_counts()
    assert sum(term_counts.values()) == len(reqs), (
        f"terminal transitions {term_counts} != {len(reqs)} requests "
        f"(a request went terminal twice or never)")

    # -- invariant 2: no page leaks ---------------------------------------
    assert allocator.available == free0, (
        f"page leak: {free0 - allocator.available} pages missing")
    assert allocator.in_use == 0
    assert (eng.block_table.table == 0).all(), "block table not all-trash"
    assert eng.scheduler.tokens_in_flight == 0

    # -- invariant 3: oracle parity for non-faulted requests --------------
    corrupted = faults.corrupted_ids
    mismatches = []
    for r in reqs:
        res = results[r.id]
        if r.id in corrupted:
            if res["status"] in sched_mod.FINISHED_STATUSES:
                mismatches.append((r.id, "corrupted request FINISHED"))
            continue
        want = oracle[r.id]
        got = res["tokens"]
        if res["status"] in sched_mod.FINISHED_STATUSES:
            if got != want:
                mismatches.append((r.id, f"tokens {got} != oracle {want}"))
        elif got and got != want[:len(got)]:
            # cancelled/timed-out mid-flight: whatever was produced must
            # still be an oracle prefix (recompute never forks the stream)
            mismatches.append((r.id, f"prefix {got} != oracle {want}"))
    assert not mismatches, f"oracle divergence: {mismatches}"

    # -- invariant 4: the numerics health plane saw every guard trip ------
    # a guard retirement and its health.nonfinite_* bump land in the SAME
    # fenced dispatch, so the plane surfaces the anomaly at or before the
    # NaN guard does (one poisoned dispatch can trip several slots' rows,
    # hence >=)
    st = eng.stats()
    anomalies = st["anomalies"]
    health = st.get("health") or {}
    assert health.get("nonfinite_dispatches", 0) >= anomalies, (
        f"health plane missed guard trips: nonfinite_dispatches="
        f"{health.get('nonfinite_dispatches')} < anomalies={anomalies}")
    if anomalies > 0:
        assert watchdog.stats()["by_rule"].get("anomaly-burst", 0) >= 1, (
            f"{anomalies} anomalies but no anomaly-burst alert fired "
            f"(watchdog={watchdog.stats()})")

    if metrics_out:
        from ..obs.emit import validate_jsonl
        counts = validate_jsonl(metrics_out)
        if anomalies > 0:
            assert counts["alert"] >= 1, (
                f"{anomalies} anomalies but no alert record in "
                f"{metrics_out}: {counts}")

    summary = {
        "arch": arch,
        "seed": seed,
        "requests": len(reqs),
        "events": events,
        "steps": steps,
        "statuses": term_counts,
        "preemptions": eng.scheduler.preempted,
        "anomalies": anomalies,
        "health": health,
        "alerts": watchdog.stats(),
        "faults": faults.stats(),
    }
    if verbose:
        print(f"[chaos] seed={seed} arch={arch}: OK — "
              f"{len(reqs)} requests, {events} events, "
              f"statuses={term_counts}, "
              f"preemptions={summary['preemptions']}, "
              f"anomalies={summary['anomalies']}, "
              f"alerts={watchdog.stats()['alerts']}, "
              f"faults={faults.stats()}")
    return summary


# ---------------------------------------------------------------------------
# Fleet chaos: replica crash mid-serving, failover via recompute migration
# ---------------------------------------------------------------------------
def run_fleet_chaos(arch: str = "tinyllama-1.1b", seed: int = 0,
                    requests: int = 16, replicas: int = 2,
                    cancel_p: float = 0.04,
                    metrics_out: Optional[str] = None,
                    verbose: bool = True) -> Dict:
    """Serve a chaos workload through a replicated fleet, kill one replica
    mid-serving, and assert the fleet-level invariants:

    1. every fleet request reaches EXACTLY ONE terminal status (hedged
       legs, salvaged results, and migrated resubmissions never
       double-settle or drop a request);
    2. zero lost requests — the dead replica's queue entries and running
       slots all resurface as fleet terminals on a survivor;
    3. every SURVIVOR's page pool is fully restored (no leaks; all-trash
       block table; no tokens in flight) — the victim's pool is abandoned
       by design;
    4. FINISHED requests are token-identical to the B=1 oracle — including
       requests that migrated across the crash (recompute-prefill on the
       survivor must be invisible) — and partial terminals are an oracle
       prefix.  The suite additionally requires that migration actually
       happened and that at least one MIGRATED request finished.

    The kill is deterministic-by-construction: once the victim has a
    running slot with generated tokens and the fleet has settled at least
    one request, the victim's ``crash_p`` is armed to 1.0 and its next
    step crashes (exercising the injected-crash path, mid-serving).  One
    survivor carries a seeded hang fault sized above its step timeout, so
    the DEGRADED/recovery health transitions run under load too.
    """
    import jax

    from ..configs import registry as config_registry
    from ..fleet import DOWN, EngineReplica, Router
    from ..models.registry import build_model
    from ..obs import Obs
    from .engine import ContinuousEngine, Engine

    if replicas < 2:
        raise ValueError("fleet chaos needs >= 2 replicas (one dies)")
    cfg = config_registry.get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = 64
    # looser deadlines than single-engine chaos: migrated requests must
    # have room to finish on the survivor, or parity has nothing to bite on
    reqs, arrivals = make_chaos_workload(
        requests, vocab=cfg.vocab_size, seed=seed,
        deadline_frac=0.2, deadline_choices=(0.4, 5.0))

    oracle_eng = Engine(cfg, params, max_batch=1, max_seq=max_seq)
    oracle = {r.id: oracle_eng.generate(
        [dataclasses.replace(r, deadline_s=None)])[0]["tokens"]
        for r in reqs}

    obs = (Obs(emit_path=metrics_out, emit_every=5)
           if metrics_out else Obs())
    pool: List[EngineReplica] = []
    free0: Dict[str, int] = {}
    for i in range(replicas):
        name = f"r{i}"
        # alloc faults keep preemption/recompute hot on every replica;
        # replica 1 also hangs occasionally (hang_s > its step timeout)
        # to drive the DEGRADED <-> HEALTHY transitions under load
        fcfg = FaultConfig(seed=seed * 101 + i, alloc_fail_p=0.05,
                           hang_p=0.03 if i == 1 else 0.0, hang_s=0.004)
        inj = FaultInjector(fcfg)
        eng = ContinuousEngine(
            cfg, params, max_slots=4, max_seq=max_seq, page_size=8,
            num_pages=9, decode_chunk=4, obs=obs.scoped(replica=name),
            admission="optimistic", max_queue=requests, max_preemptions=4,
            faults=inj)
        rep = EngineReplica(
            name, eng, faults=inj,
            step_timeout_s=0.003 if i == 1 else 5.0,
            down_after=10 ** 9 if i == 1 else 3, recover_after=2)
        pool.append(rep)
        free0[name] = eng.block_table.allocator.available
    router = Router(pool, policy="jsq", seed=seed, obs=obs)
    victim = pool[0]

    rng = np.random.RandomState(seed + 1)
    orders = {}
    for r, a in zip(reqs, arrivals):
        orders[r.id] = router.submit(r, a)
    live = set(orders)
    killed = False
    steps = 0
    while any(router.result(o) is None for o in orders.values()):
        steps += 1
        if not router.step():
            time.sleep(0.001)
        if not killed and victim.state != DOWN:
            mid_serving = any(s.tokens
                              for s in victim.engine.scheduler.running)
            settled = sum(1 for o in orders.values()
                          if router.result(o) is not None)
            if mid_serving and settled >= 1:
                # arm the injected crash: the victim's next step dies with
                # requests running and tokens already generated
                victim.faults.cfg.crash_p = 1.0
                killed = True
        live = {i for i in live if router.result(orders[i]) is None}
        if live and rng.random_sample() < cancel_p:
            router.cancel(int(rng.choice(sorted(live))))
        if steps > 100_000:
            raise AssertionError("fleet chaos did not converge")
    router.drain()
    assert killed, ("kill never armed: the victim finished its share "
                    "before serving mid-flight (grow the workload)")
    assert victim.state == DOWN and victim.salvaged, (
        f"victim {victim.name} state={victim.state} "
        f"salvaged={victim.salvaged}")
    survivors = [rep for rep in pool if rep is not victim]
    assert all(rep.state != DOWN for rep in survivors), (
        f"survivor died: {[rep.stats() for rep in survivors]}")

    # -- invariant 1: exactly one terminal per fleet request --------------
    results = {i: router.result(o) for i, o in orders.items()}
    missing = [i for i, res in results.items() if res is None]
    assert not missing, f"lost requests (no terminal): {missing}"
    bad = {i: res["status"] for i, res in results.items()
           if res["status"] not in sched_mod.TERMINAL_STATUSES}
    assert not bad, f"non-terminal statuses: {bad}"
    term_counts = router.terminal_counts()
    assert sum(term_counts.values()) == len(reqs), (
        f"fleet terminal transitions {term_counts} != {len(reqs)} "
        f"requests (double-settle or drop)")

    # -- invariant 2: survivors' pools fully restored ---------------------
    for rep in survivors:
        alloc = rep.engine.block_table.allocator
        assert alloc.available == free0[rep.name], (
            f"{rep.name}: page leak "
            f"({free0[rep.name] - alloc.available} pages missing)")
        assert alloc.in_use == 0, rep.name
        assert (rep.engine.block_table.table == 0).all(), (
            f"{rep.name}: block table not all-trash")
        assert rep.engine.scheduler.tokens_in_flight == 0, rep.name

    # -- invariant 3: migration happened and finished ---------------------
    migrated = {i for i, res in results.items() if res["migrations"] > 0}
    assert migrated, "replica died mid-serving but nothing migrated"
    migrated_finished = {
        i for i in migrated
        if results[i]["status"] in sched_mod.FINISHED_STATUSES}
    assert migrated_finished, (
        f"no migrated request finished (migrated={sorted(migrated)}, "
        f"statuses={ {i: results[i]['status'] for i in migrated} })")

    # -- invariant 4: oracle parity, including across the migration -------
    corrupted = set()
    for rep in pool:
        corrupted |= rep.engine.faults.corrupted_ids if rep.engine.faults \
            else set()
    mismatches = []
    for r in reqs:
        if r.id in corrupted:
            continue
        res = results[r.id]
        want = oracle[r.id]
        got = res["tokens"]
        if res["status"] in sched_mod.FINISHED_STATUSES:
            if got != want:
                mismatches.append(
                    (r.id, res["migrations"],
                     f"tokens {got} != oracle {want}"))
        elif got and got != want[:len(got)]:
            mismatches.append(
                (r.id, res["migrations"], f"prefix {got} != oracle {want}"))
    assert not mismatches, f"oracle divergence: {mismatches}"

    if metrics_out:
        from ..obs.emit import validate_jsonl
        validate_jsonl(metrics_out)

    summary = {
        "arch": arch,
        "seed": seed,
        "requests": len(reqs),
        "replicas": replicas,
        "steps": steps,
        "statuses": term_counts,
        "migrated": sorted(migrated),
        "migrated_finished": sorted(migrated_finished),
        "router": router.stats(),
    }
    if verbose:
        rs = summary["router"]
        print(f"[fleet-chaos] seed={seed} arch={arch}: OK — "
              f"{len(reqs)} requests over {replicas} replicas, "
              f"victim={victim.name} down ({victim.down_reason}), "
              f"statuses={term_counts}, migrated={sorted(migrated)}, "
              f"hedges={rs['hedges']}, shed={rs['shed']}")
    return summary


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Chaos invariant suites (seeded fault injection; CI "
                    "`chaos` and `fleet` steps).")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=None,
                    help="workload size (default: 24 single-engine, "
                         "16 fleet)")
    ap.add_argument("--fleet", action="store_true",
                    help="run the replicated-fleet chaos suite (replica "
                         "crash + failover migration) instead of the "
                         "single-engine suite")
    ap.add_argument("--replicas", type=int, default=2,
                    help="fleet size for --fleet (one replica is killed)")
    ap.add_argument("--metrics-out", default=None,
                    help="also emit obs JSONL and validate it")
    args = ap.parse_args(argv)
    try:
        if args.fleet:
            run_fleet_chaos(arch=args.arch, seed=args.seed,
                            requests=(16 if args.requests is None
                                      else args.requests),
                            replicas=args.replicas,
                            metrics_out=args.metrics_out)
        else:
            run_chaos(arch=args.arch, seed=args.seed,
                      requests=(24 if args.requests is None
                                else args.requests),
                      metrics_out=args.metrics_out)
    except AssertionError as e:
        print(f"[chaos] FAILED: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
