"""Batched serving engine: continuous-batching-lite over prefill + decode.

Requests are gathered into fixed-size batches (padding short prompts),
prefilled once, then decoded step-by-step with a shared ring/linear KV cache.
The decode step is jit'd once per (batch, cache) shape and donates the cache.
This is the host-scale counterpart of the production serve path the dry-run
lowers for the ``decode_*`` cells.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..dist import ctx as dist_ctx
from ..launch import mesh as mesh_lib
from ..models.registry import build_model
from . import decode as dec


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    id: int = 0


class Engine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256, sample: bool = False, mesh=None):
        self.cfg = cfg
        self.params = params
        self.model = build_model(cfg)
        self.max_batch = max_batch
        self.max_seq = max_seq
        # Activations are pinned through the same policy the production
        # dry-run uses; default is this host's (n, 1) data-parallel mesh.
        self.mesh = mesh if mesh is not None else mesh_lib.make_host_mesh()
        self._prefill = jax.jit(dec.make_prefill_step(cfg))
        self._decode = jax.jit(dec.make_decode_step(cfg, sample=sample),
                               donate_argnums=(2,))

    def _make_batch(self, reqs: Sequence[Request]) -> Dict:
        B = len(reqs)
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt     # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "audio_stub":
            batch["frames"] = jnp.zeros(
                (B, self.cfg.encoder_seq, self.cfg.d_model), jnp.float32)
        elif self.cfg.frontend == "vision_stub":
            batch["patches"] = jnp.zeros(
                (B, self.cfg.num_patches, self.cfg.d_model), jnp.float32)
        return batch

    def generate(self, reqs: Sequence[Request]) -> List[Dict]:
        """Serve a batch of requests; returns per-request token lists."""
        out: List[Dict] = []
        for i in range(0, len(reqs), self.max_batch):
            out.extend(self._generate_batch(reqs[i:i + self.max_batch]))
        return out

    def _generate_batch(self, reqs: Sequence[Request]) -> List[Dict]:
        with dist_ctx.activation_policy(self.mesh):
            return self._generate_batch_inner(reqs)

    def _generate_batch_inner(self, reqs: Sequence[Request]) -> List[Dict]:
        t0 = time.time()
        batch = self._make_batch(reqs)
        B, S = batch["tokens"].shape
        steps = max(r.max_new_tokens for r in reqs)
        cache = self.model.init_cache(B, min(S + steps, self.max_seq),
                                      dtype=jnp.float32)
        logits, cache = self._prefill(self.params, batch, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        toks = [nxt]
        pos = S
        for _ in range(steps - 1):
            _, nxt, cache = self._decode(self.params, nxt[:, None], cache,
                                         jnp.int32(pos))
            toks.append(nxt)
            pos += 1
        gen = np.asarray(jnp.stack(toks, 1))           # (B, steps)
        dt = time.time() - t0
        return [{"id": r.id, "tokens": gen[i, :r.max_new_tokens].tolist(),
                 "latency_s": dt} for i, r in enumerate(reqs)]
