"""Serving engines: the batch-synchronous engine (oracle) and the
continuous-batching engine over the paged KV pool.

``Engine`` gathers fixed-size batches (padding short prompts), prefills
once, then decodes with the device-resident loop in serve/decode.py.  It is
the bit-exact ORACLE: under a single-admission schedule (one request, B=1)
its greedy tokens define what the continuous engine must emit.  Prompt
bucketing (``bucket_prompts``) sorts requests by prompt length before
chunking into batches, so a chunk of short prompts is no longer left-padded
to an unrelated long prompt's length; results come back in request order.

``ContinuousEngine`` is the paper's batch-processing + resource-re-use +
hierarchical-control story as a serving control plane (see docs/serving.md):

* KV state lives in a PAGED POOL (serve/kvcache.py) — fixed-size blocks,
  per-request block tables, a free-list allocator; pages go back to the
  pool the moment a request retires, not when its batch drains;
* a request SCHEDULER (serve/scheduler.py) admits queued requests into
  free decode slots under a token budget, BETWEEN device dispatches of the
  scanned decode loop: prefill of waiting requests interleaves with decode
  of running ones;
* decode runs ``decode_chunk`` tokens per dispatch with per-slot positions
  (serve/decode.py: make_paged_decode_loop); finished slots freeze
  on-device and retire between dispatches without stalling the rest.

Params run through the offline spectral precompute pass (serve/params.py)
in both engines, so no weight FFT executes inside any serve program.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..dist import ctx as dist_ctx
from ..dist import sharding as dist_sharding
from ..launch import mesh as mesh_lib
from ..models import transformer as tfm
from ..models.registry import build_model
from ..obs import BYTES_BUCKETS, RATIO_BUCKETS, Obs
from ..quant.codec import QuantPolicy
from . import decode as dec
from . import kvcache as kvc
from .params import precompute_serving_params
from .scheduler import Scheduler

# Counters both engines keep in their obs registry under the SAME names and
# units — the unified stats() schema (docs/observability.md).  ``*_s``
# counters accumulate seconds; the rest are token/request counts.
ENGINE_COUNTERS = ("requests", "tokens", "prompt_tokens",
                   "padded_prompt_tokens", "prefill_s", "decode_s",
                   "dispatches")


def _engine_stats_view(obs: Obs, engine: str) -> Dict:
    """The shared half of Engine.stats()/ContinuousEngine.stats(): a view
    over the registry counters plus the derived fields both engines define
    identically (tokens_per_s over end-to-end serve time, pad waste)."""
    v = obs.registry.value
    st = {"engine": engine}
    for name in ENGINE_COUNTERS:
        val = v(name)
        st[name] = val if name.endswith("_s") else int(val)
    st["prompt_pad_waste"] = (st["padded_prompt_tokens"]
                              - st["prompt_tokens"])
    st["tokens_per_s"] = st["tokens"] / max(
        st["prefill_s"] + st["decode_s"], 1e-9)
    return st


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    id: int = 0


class Engine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256, sample: bool = False, mesh=None,
                 precompute: bool = True, decode_mode: str = "scan",
                 eos_id: Optional[int] = None, temperature: float = 1.0,
                 seed: int = 0, bucket_prompts: bool = True,
                 quant: Optional[QuantPolicy] = None,
                 obs: Optional[Obs] = None):
        assert decode_mode in ("scan", "per_token"), decode_mode
        self.cfg = cfg
        self.quant = quant or QuantPolicy()
        # the batch engine's dense cache stays float32 (it is the f32
        # parity ORACLE); only the weight half of the policy applies here
        self.params = (precompute_serving_params(params, cfg, self.quant)
                       if precompute else params)
        self.model = build_model(cfg)
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sample = sample
        self.decode_mode = decode_mode
        self.eos_id = eos_id
        self.temperature = temperature
        self.seed = seed
        self.bucket_prompts = bucket_prompts
        # Largest sliding window any block uses: the ring-buffer prefill
        # keeps the window tail, so batch prompts must cover it (validated
        # per batch below instead of failing as a trace-time assert).
        self._swa_window = 0 if cfg.is_encoder_decoder else max(
            [tfm._window_for(kind, cfg)
             for pattern, _ in tfm.segments_for(cfg)
             for kind in pattern], default=0)
        # Activations are pinned through the same policy the production
        # dry-run uses; default is this host's (n, 1) data-parallel mesh.
        self.mesh = mesh if mesh is not None else mesh_lib.make_host_mesh()
        self._prefill = jax.jit(dec.make_prefill_step(cfg))
        self._decode = jax.jit(
            dec.make_decode_step(cfg, sample=sample, temperature=temperature,
                                 seed=seed),
            donate_argnums=(2,))
        self._loops: Dict[int, object] = {}
        # telemetry (repro.obs): the registry IS the stats() backing store;
        # counters are held directly so the hot path is one float add
        self.obs = obs if obs is not None else Obs()
        reg = self.obs.registry
        self._ctr = {n: reg.counter(n) for n in ENGINE_COUNTERS}
        self._h_prefill = reg.histogram("engine.prefill_dispatch_s")
        self._h_decode = reg.histogram("engine.decode_dispatch_s")
        self._order = 0                     # trace submission order

    def _loop_fn(self, steps: int):
        """jit'd decode loop for a step budget (cached per budget)."""
        fn = self._loops.get(steps)
        if fn is None:
            fn = jax.jit(dec.make_decode_loop(
                self.cfg, steps, sample=self.sample,
                temperature=self.temperature, eos_id=self.eos_id,
                seed=self.seed),
                donate_argnums=(2,))
            self._loops[steps] = fn
        return fn

    def _make_batch(self, reqs: Sequence[Request]) -> Dict:
        B = len(reqs)
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt     # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "audio_stub":
            batch["frames"] = jnp.zeros(
                (B, self.cfg.encoder_seq, self.cfg.d_model), jnp.float32)
        elif self.cfg.frontend == "vision_stub":
            batch["patches"] = jnp.zeros(
                (B, self.cfg.num_patches, self.cfg.d_model), jnp.float32)
        return batch

    def generate(self, reqs: Sequence[Request]) -> List[Dict]:
        """Serve a batch of requests; returns per-request token lists in
        request order.  With ``bucket_prompts`` (default), requests are
        grouped into batches by (prompt length, decode budget) first, so a
        chunk of short prompts is not left-padded to an unrelated long
        prompt's length — and short decodes are not held hostage by a
        batch-mate's long budget (the decode loop runs to the chunk max)."""
        if self.bucket_prompts:
            order = sorted(range(len(reqs)),
                           key=lambda i: (len(reqs[i].prompt),
                                          reqs[i].max_new_tokens))
        else:
            order = list(range(len(reqs)))
        # every request enqueues NOW; later batches' traces carry the queue
        # wait their bucket imposed (admit - enqueue)
        t_enq = self.obs.now()
        traces = [None] * len(reqs)
        if self.obs.enabled:
            for i, r in enumerate(reqs):
                traces[i] = self.obs.trace_start(r.id, self._order,
                                                 len(r.prompt), t_enq)
                self._order += 1
        out: List[Optional[Dict]] = [None] * len(reqs)
        for i in range(0, len(order), self.max_batch):
            idxs = order[i:i + self.max_batch]
            batch_out = self._generate_batch([reqs[j] for j in idxs],
                                             [traces[j] for j in idxs])
            for j, r in zip(idxs, batch_out):
                out[j] = r
        return out

    def _generate_batch(self, reqs: Sequence[Request],
                        traces: Optional[Sequence] = None) -> List[Dict]:
        with dist_ctx.activation_policy(self.mesh):
            return self._generate_batch_inner(
                reqs, traces if traces is not None else [None] * len(reqs))

    def _generate_batch_inner(self, reqs: Sequence[Request],
                              traces: Sequence) -> List[Dict]:
        t0 = time.perf_counter()
        batch = self._make_batch(reqs)
        B, S = batch["tokens"].shape
        if S > self.max_seq:
            raise ValueError(f"prompt length {S} exceeds max_seq "
                             f"{self.max_seq}")
        # Decode step j writes cache position S+j-1 (j=1..steps-1), so the
        # cache needs S+steps-1 slots; clamp the step budget instead of
        # letting dynamic_update_slice silently clobber the last slot
        # (regression-tested in test_decode_loop.py).
        steps = max(r.max_new_tokens for r in reqs)
        steps = max(1, min(steps, self.max_seq - S + 1))
        need = min(self._swa_window, S + steps - 1)
        if self._swa_window and S < need:
            raise ValueError(
                f"batch prompt length {S} does not cover the sliding-window "
                f"ring buffer ({need}): SWA prefill keeps the window tail, "
                f"so prompts must be >= min(window, cache length)")
        cache = self.model.init_cache(B, S + steps - 1, dtype=jnp.float32)
        logits, cache = self._prefill(self.params, batch, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        # fence BEFORE every span boundary: the t1/t2 marks (and the trace
        # spans derived from them) measure device work, not dispatch
        jax.block_until_ready(nxt)
        t1 = time.perf_counter()

        if self.decode_mode == "per_token":
            gen = self._decode_per_token(nxt, cache, S, steps)
        else:
            lengths = jnp.asarray([min(r.max_new_tokens, steps)
                                   for r in reqs], jnp.int32)
            gen, _ = self._loop_fn(steps)(self.params, nxt, cache,
                                          jnp.int32(S), lengths)
        jax.block_until_ready(gen)
        gen = np.asarray(gen)                          # (B, steps)
        t2 = time.perf_counter()
        prefill_s, decode_s = t1 - t0, t2 - t1

        out = []
        for i, r in enumerate(reqs):
            toks = gen[i, :min(r.max_new_tokens, steps)].tolist()
            if self.eos_id is not None and self.eos_id in toks:
                toks = toks[:toks.index(self.eos_id) + 1]
            out.append({
                "id": r.id,
                "tokens": toks,
                "decode_len": len(toks),
                "tokens_per_s": len(toks) / max(decode_s, 1e-9),
                "prefill_s": prefill_s,
                "decode_s": decode_s,
                "latency_s": prefill_s + decode_s,
            })
        c = self._ctr
        c["requests"].inc(len(reqs))
        c["dispatches"].inc()
        c["tokens"].inc(sum(r["decode_len"] for r in out))
        c["prompt_tokens"].inc(sum(len(r.prompt) for r in reqs))
        c["padded_prompt_tokens"].inc(B * S)
        c["prefill_s"].inc(prefill_s)
        c["decode_s"].inc(decode_s)
        if self.obs.enabled:
            self._h_prefill.observe(prefill_s)
            self._h_decode.observe(decode_s)
            for tr, res in zip(traces, out):
                if tr is None:
                    continue
                tr.mark_admit(self.obs.rebase(t0))
                tr.mark_first_token(self.obs.rebase(t1))
                if res["decode_len"] > 1:
                    tr.mark_chunk(self.obs.rebase(t2),
                                  res["decode_len"] - 1)
                tr.mark_retire(self.obs.rebase(t2))
                self.obs.trace_finish(tr)
        self.obs.tick()
        return out

    def _decode_per_token(self, nxt, cache, S: int, steps: int) -> np.ndarray:
        """Seed host loop: one dispatch per token (baseline/oracle path)."""
        toks = [nxt]
        for pos in range(S, S + steps - 1):
            _, nxt, cache = self._decode(self.params, nxt[:, None], cache,
                                         jnp.int32(pos))
            toks.append(nxt)
        return np.asarray(jnp.stack(toks, 1))          # (B, steps)

    def stats(self) -> Dict:
        """Cumulative engine telemetry as a view over the obs registry —
        one schema shared with ContinuousEngine.stats()
        (docs/observability.md).  ``batches`` is the legacy alias for the
        unified ``dispatches`` counter (one decode dispatch per batch)."""
        st = _engine_stats_view(self.obs, "batch")
        st["batches"] = st["dispatches"]     # legacy alias (one release)
        return st


# ---------------------------------------------------------------------------
# Continuous batching over the paged pool
# ---------------------------------------------------------------------------
class ContinuousEngine:
    """Continuous-batching engine: paged KV pool + token-budget scheduler.

    Serves decoder-LM archs with linear (global-attention) caches — see
    ``kvcache.servable_reasons``; SWA/recurrent/enc-dec archs stay on the
    batch engine.  Greedy outputs are token-identical to the batch engine
    run per-request (B=1): prefill is exact-position (right-pad bucketed),
    decode runs every slot at its own absolute position.

    ``generate(reqs, arrival_times=...)`` simulates an online arrival
    process against wall-clock time (benchmarks); without arrival times the
    whole list queues at t=0 and drains under the admission policy.
    """

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_seq: int = 256, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 max_tokens_in_flight: Optional[int] = None,
                 decode_chunk: int = 8, sample: bool = False,
                 temperature: float = 1.0, seed: int = 0,
                 eos_id: Optional[int] = None, mesh=None,
                 precompute: bool = True, paged_attn: str = "stream",
                 quant: Optional[QuantPolicy] = None,
                 obs: Optional[Obs] = None):
        if paged_attn not in ("stream", "gather"):
            raise ValueError(f"paged_attn {paged_attn!r}: "
                             f"expected 'stream' or 'gather'")
        reasons = kvc.servable_reasons(cfg)
        if reasons:
            raise ValueError(f"{cfg.name} is not continuous-servable: "
                             f"{'; '.join(reasons)} — use Engine")
        self.cfg = cfg
        self.quant = quant or QuantPolicy()
        self.params = (precompute_serving_params(params, cfg, self.quant)
                       if precompute else params)
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.decode_chunk = decode_chunk
        self.sample = sample
        self.eos_id = eos_id
        self.paged_attn = paged_attn
        self.max_pages_per_slot = kvc.pages_for(max_seq, page_size)
        if num_pages is None:
            num_pages = max_slots * self.max_pages_per_slot + 1
        if num_pages < self.max_pages_per_slot + 1:
            raise ValueError(f"num_pages {num_pages} cannot hold one "
                             f"max_seq request (+trash page)")
        if max_tokens_in_flight is None:
            # Streamed paged attention (the default) never materializes the
            # (B, maxp*page, Hkv, D) gathered KV view, so peak decode memory
            # no longer scales with slots x max_seq — the default admission
            # budget fills every slot.  The gather oracle's default is NEWLY
            # halved here (PR 3 defaulted to the ceiling): every token it
            # has in flight pays an O(max_seq) gather per decode step, so
            # its memory-honest budget is conservative.  Pass
            # max_tokens_in_flight explicitly to A/B the attention paths
            # under identical admission.
            ceiling = max_slots * (max_seq + 1)
            max_tokens_in_flight = (ceiling if paged_attn == "stream"
                                    else max(max_seq + 1, ceiling // 2))
        if max_tokens_in_flight < max_seq + 1:
            raise ValueError(f"max_tokens_in_flight {max_tokens_in_flight} "
                             f"cannot admit one max_seq request")
        self.mesh = mesh if mesh is not None else mesh_lib.make_host_mesh()
        # keep the page dim DP-divisible, else page_pool_spec's fallback
        # would replicate the whole pool over the data-parallel devices
        num_pages = dist_sharding.dp_round_up(num_pages, self.mesh)
        self.num_pages = num_pages
        self.pool = kvc.build_pool(cfg, num_pages, page_size, self.quant)
        # pin the pool to its derived layout (pages over DP, heads over
        # "model" — the dense cache's placement, see dist/sharding.py);
        # trivial on the 1-device host mesh, load-bearing on real meshes
        self.pool = jax.device_put(self.pool, dist_sharding.to_shardings(
            dist_sharding.pool_specs(self.pool, self.mesh), self.mesh))
        # telemetry (repro.obs): the registry backs stats(); the allocator
        # and scheduler write their own gauges/counters into it
        self.obs = obs if obs is not None else Obs()
        reg = self.obs.registry
        self.block_table = kvc.BlockTable(
            kvc.PageAllocator(num_pages, registry=reg), max_slots,
            page_size, self.max_pages_per_slot)
        self.scheduler = Scheduler(self.block_table, max_seq=max_seq,
                                   max_tokens_in_flight=max_tokens_in_flight,
                                   registry=reg)
        # ONE fixed-size decode program: chunk size never varies, so the
        # loop compiles exactly once — adaptive sizing would dodge some
        # frozen-slot steps but risks multi-second mid-serving compiles the
        # first time an unseen size comes up (disastrous for tail latency)
        self._loop = jax.jit(dec.make_paged_decode_loop(
            cfg, decode_chunk, sample=sample, temperature=temperature,
            eos_id=eos_id, seed=seed, paged_impl=paged_attn),
            donate_argnums=(2,))
        self._prefills: Dict[int, object] = {}
        self._cur = np.zeros(max_slots, np.int32)
        self._pos = np.zeros(max_slots, np.int32)
        self._rem = np.zeros(max_slots, np.int32)
        self._dev_table = None              # device copy; None = stale
        self._ctr = {n: reg.counter(n) for n in ENGINE_COUNTERS}
        self._h_prefill = reg.histogram("engine.prefill_dispatch_s")
        self._h_chunk = reg.histogram("engine.decode_chunk_s")
        self._h_occup = reg.histogram("sched.slot_occupancy",
                                      bounds=RATIO_BUCKETS)
        self._h_attn_bytes = reg.histogram("attn.bytes_per_token",
                                           bounds=BYTES_BUCKETS)
        self._c_growths = reg.counter("quant.scale_growths")
        # per-position attention byte term for the live bytes/token series
        self._attn_per_pos = kvc.attention_bytes_per_position(
            self.pool)["per_pos"]
        # host shadow of the int8 pool's scales: decode-dispatch diffs
        # count page-scatter requantize-on-grow events (scales only GROW)
        self._scales_host = (kvc.pool_scales(self.pool)
                             if self.obs.enabled and self.quant.kv_quantized
                             else None)
        self._traces: Dict[int, object] = {}     # submission order -> trace
        self._t0_perf = None                # generate()'s t_start (perf)

    # -- jit caches -------------------------------------------------------
    def _prefill_fn(self, n_pages: int):
        fn = self._prefills.get(n_pages)
        if fn is None:
            fn = jax.jit(dec.make_prefill_pack_step(
                self.cfg, n_pages, self.page_size), donate_argnums=(2,))
            self._prefills[n_pages] = fn
        return fn

    # -- serving loop -----------------------------------------------------
    def generate(self, reqs: Sequence[Request],
                 arrival_times: Optional[Sequence[float]] = None
                 ) -> List[Dict]:
        for r in reqs:                      # validate BEFORE admitting any:
            if len(r.prompt) > self.max_seq:   # a mid-loop raise would leak
                raise ValueError(              # running slots' pages
                    f"prompt length {len(r.prompt)} exceeds max_seq "
                    f"{self.max_seq}")
        t_start = time.perf_counter()
        self._t0_perf = t_start
        arr = ([0.0] * len(reqs) if arrival_times is None
               else [float(a) for a in arrival_times])
        orders = [self.scheduler.submit(r, a) for r, a in zip(reqs, arr)]
        if self.obs.enabled:
            # a request ENQUEUES at its (possibly simulated) arrival — the
            # trace timeline starts there so queue_s covers admission wait
            for r, o, a in zip(reqs, orders, arr):
                self._traces[o] = self.obs.trace_start(
                    r.id, o, len(r.prompt), self.obs.rebase(t_start) + a)
        results: Dict[int, Dict] = {}
        gate = arrival_times is not None
        with dist_ctx.activation_policy(self.mesh):
            while not self.scheduler.idle:
                now = time.perf_counter() - t_start
                if gate and not self.scheduler.running:
                    # engine idle: sleep until the HEAD's arrival (admission
                    # is strictly FIFO, so the head's arrival is the binding
                    # one even when arrival times are unsorted)
                    next_arr = self.scheduler.queue[0][2]
                    if next_arr > now:
                        time.sleep(next_arr - now)
                        now = time.perf_counter() - t_start
                admitted = self.scheduler.try_admit(
                    now, arrived_before=now if gate else None)
                for slot in admitted:
                    self._prefill_slot(slot, results, t_start)
                if self.scheduler.running:
                    self._dispatch_decode(results, t_start)
                elif self.scheduler.queue and not admitted:
                    raise RuntimeError(
                        "scheduler stall: queued request cannot be admitted "
                        "into an idle engine (budget/pool too small)")
                self.obs.tick()             # emitter rides the dispatch cadence
        return [results[o] for o in orders]

    def _prefill_slot(self, slot, results: Dict, t_start: float) -> None:
        t0 = time.perf_counter()
        self._dev_table = None              # admission reserved pages
        req = slot.request
        S = len(req.prompt)
        n_pages = kvc.pages_for(S, self.page_size)
        spad = n_pages * self.page_size
        toks = np.zeros(spad, np.int32)
        toks[:S] = req.prompt                          # right-pad
        batch = {"tokens": jnp.asarray(toks[None])}
        if self.cfg.frontend == "vision_stub":
            batch["patches"] = jnp.zeros(
                (1, self.cfg.num_patches, self.cfg.d_model), jnp.float32)
        pages = jnp.asarray(self.block_table.pages(slot.index)[:n_pages],
                            jnp.int32)
        nxt, self.pool = self._prefill_fn(n_pages)(
            self.params, batch, self.pool, pages, jnp.int32(S))
        # fence the whole dispatch (token AND page scatter) so the prefill
        # span — and the trace's first-token mark — measure device work
        jax.block_until_ready((nxt, self.pool))
        first = int(nxt)
        slot.tokens.append(first)
        slot.pos = S                       # position of the token in flight
        slot.budget -= 1
        self._cur[slot.index] = first
        self._pos[slot.index] = S
        self._rem[slot.index] = slot.budget
        t1 = time.perf_counter()
        dt = t1 - t0
        self._ctr["prefill_s"].inc(dt)
        self._ctr["prompt_tokens"].inc(S)
        self._ctr["padded_prompt_tokens"].inc(spad)
        slot.prefill_s = dt
        if self.obs.enabled:
            self._h_prefill.observe(dt)
            tr = self._traces.get(slot.order)
            if tr is not None:
                tr.mark_admit(self.obs.rebase(t_start) + slot.admit_s)
                tr.mark_first_token(self.obs.rebase(t1))
            if self._scales_host is not None:
                # prefill packs fresh pages (new scales, not grow events):
                # refresh the shadow so the next decode diff is clean
                self._scales_host = kvc.pool_scales(self.pool)
        if slot.budget <= 0 or (self.eos_id is not None
                                and first == self.eos_id):
            self._rem[slot.index] = 0
            self._finish(slot, results, t_start)

    def _dispatch_decode(self, results: Dict, t_start: float) -> None:
        t0 = time.perf_counter()
        running = list(self.scheduler.running)
        rem_before = self._rem.copy()
        if self._dev_table is None:         # tables change only on
            self._dev_table = self.block_table.device_table()   # admit/retire
        buf, cur, self.pool, pos, rem, done = self._loop(
            self.params, jnp.asarray(self._cur), self.pool,
            self._dev_table, jnp.asarray(self._pos),
            jnp.asarray(self._rem))
        # fence before the span boundary: the decode_chunk wall time (and
        # the per-chunk trace marks) measure the device program
        jax.block_until_ready(buf)
        t1 = time.perf_counter()
        buf = np.asarray(buf)
        self._cur = np.array(cur)
        self._pos = np.array(pos)
        self._rem = np.array(rem)
        done = np.asarray(done)
        dt = t1 - t0
        self._ctr["decode_s"].inc(dt)
        self._ctr["dispatches"].inc()
        if self.obs.enabled:
            self._h_chunk.observe(dt)
            self._h_occup.observe(len(running) / max(self.max_slots, 1))
            if self._scales_host is not None:
                scales = kvc.pool_scales(self.pool)
                self._c_growths.inc(
                    int((scales > self._scales_host).sum()))
                self._scales_host = scales
        t_chunk = self.obs.rebase(t1)
        for slot in running:
            b = slot.index
            n = int(rem_before[b] - self._rem[b])
            if n:
                slot.tokens.extend(buf[b, :n].tolist())
                slot.pos = int(self._pos[b])
                self._ctr["tokens"].inc(n)
                if self.obs.enabled:
                    # live-length bytes/token: what attention actually
                    # streamed for this slot (worst case is in stats())
                    self._h_attn_bytes.observe(
                        self._attn_per_pos * int(self._pos[b]))
                    tr = self._traces.get(slot.order)
                    if tr is not None:
                        tr.mark_chunk(t_chunk, n)
            if done[b]:
                self._finish(slot, results, t_start)

    def _finish(self, slot, results: Dict, t_start: float) -> None:
        now = time.perf_counter() - t_start
        prefill_s = getattr(slot, "prefill_s", 0.0)
        arrival, admit = slot.arrival_s, slot.admit_s
        order = slot.order
        res = self.scheduler.retire(slot)   # releases the slot's pages
        self._dev_table = None
        tr = self._traces.pop(order, None)
        if tr is not None:
            # one timeline: the result's latency fields come FROM the trace,
            # so bench percentiles over results and over traces are the same
            # numbers by construction
            tr.mark_retire(self.obs.rebase(t_start) + now)
            self.obs.trace_finish(tr)
            decode_s = tr.decode_s
            res.update({
                "tokens_per_s": res["decode_len"] / max(decode_s, 1e-9),
                "prefill_s": tr.prefill_s,
                "decode_s": decode_s,
                "queue_s": tr.queue_s,
                "latency_s": tr.latency_s,
            })
        else:
            decode_s = max(now - admit - prefill_s, 0.0)
            res.update({
                "tokens_per_s": res["decode_len"] / max(decode_s, 1e-9),
                "prefill_s": prefill_s,
                "decode_s": decode_s,
                "queue_s": max(admit - arrival, 0.0),
                "latency_s": max(now - arrival, 0.0),
            })
        self._ctr["requests"].inc()
        self._ctr["tokens"].inc()           # the prefill-emitted first token
        results[res.pop("order")] = res

    # -- telemetry --------------------------------------------------------
    def stats(self) -> Dict:
        """Engine + scheduler telemetry as a view over the obs registry —
        one schema shared with Engine.stats() (docs/observability.md):
        queue depth, in-flight tokens, page-pool utilization,
        prefill/decode split, pool footprint, and the decode-attention
        memory estimates (worst case: every slot at full length) the
        serving benchmarks record.  ``decode_dispatches`` is the legacy
        alias for the unified ``dispatches`` counter."""
        st = _engine_stats_view(self.obs, "continuous")
        st["decode_dispatches"] = st["dispatches"]  # legacy alias
        st.update(self.scheduler.stats())
        v = self.obs.registry.value
        st["free_pages"] = int(v("pool.free_pages"))
        st["pages_alloc"] = int(v("pool.pages_alloc"))
        st["pages_freed"] = int(v("pool.pages_freed"))
        st["scale_growths"] = int(v("quant.scale_growths"))
        st["pool_bytes"] = kvc.pool_bytes(self.pool)
        st["kv_pool_bytes"] = st["pool_bytes"]     # quant-satellite alias
        st["quant_policy"] = self.quant.describe()
        st["prefill_buckets"] = sorted(self._prefills)
        st["attention_impl"] = self.paged_attn
        st.update(kvc.attention_memory_est(
            self.pool, self.max_slots, self.max_pages_per_slot,
            self.page_size, self.paged_attn))
        st["decode_peak_bytes_est"] = (st["pool_bytes"]
                                       + st["peak_attention_bytes"])
        return st
