"""Serving engines: the batch-synchronous engine (oracle) and the
continuous-batching engine over the paged KV pool.

``Engine`` gathers fixed-size batches (padding short prompts), prefills
once, then decodes with the device-resident loop in serve/decode.py.  It is
the bit-exact ORACLE: under a single-admission schedule (one request, B=1)
its greedy tokens define what the continuous engine must emit.  Prompt
bucketing (``bucket_prompts``) sorts requests by prompt length before
chunking into batches, so a chunk of short prompts is no longer left-padded
to an unrelated long prompt's length; results come back in request order.

``ContinuousEngine`` is the paper's batch-processing + resource-re-use +
hierarchical-control story as a serving control plane (see docs/serving.md):

* KV state lives in a PAGED POOL (serve/kvcache.py) — fixed-size blocks,
  per-request block tables, a free-list allocator; pages go back to the
  pool the moment a request retires, not when its batch drains;
* a request SCHEDULER (serve/scheduler.py) admits queued requests into
  free decode slots under a token budget, BETWEEN device dispatches of the
  scanned decode loop: prefill of waiting requests interleaves with decode
  of running ones;
* decode runs ``decode_chunk`` tokens per dispatch with per-slot positions
  (serve/decode.py: make_paged_decode_loop); finished slots freeze
  on-device and retire between dispatches without stalling the rest.

Params run through the offline spectral precompute pass (serve/params.py)
in both engines, so no weight FFT executes inside any serve program.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..dist import ctx as dist_ctx
from ..dist import sharding as dist_sharding
from ..launch import mesh as mesh_lib
from ..models import transformer as tfm
from ..models.registry import build_model
from ..obs import BYTES_BUCKETS, RATIO_BUCKETS, Obs, aot_compile
from ..obs.health import SCALE_BUCKETS, HealthPlane, ShadowOracle
from ..quant.codec import QuantPolicy, plane_clip_report
from . import decode as dec
from . import kvcache as kvc
from .params import precompute_serving_params
from .scheduler import (CANCELLED, FAILED, FINISHED_BUDGET, FINISHED_EOS,
                        REJECTED, TIMEOUT, Scheduler)

# Counters both engines keep in their obs registry under the SAME names and
# units — the unified stats() schema (docs/observability.md).  ``*_s``
# counters accumulate seconds; the rest are token/request counts.
ENGINE_COUNTERS = ("requests", "tokens", "prompt_tokens",
                   "padded_prompt_tokens", "prefill_s", "decode_s",
                   "dispatches")


def _engine_stats_view(obs: Obs, engine: str) -> Dict:
    """The shared half of Engine.stats()/ContinuousEngine.stats(): a view
    over the registry counters plus the derived fields both engines define
    identically (tokens_per_s over end-to-end serve time, pad waste)."""
    v = obs.registry.value
    st = {"engine": engine}
    for name in ENGINE_COUNTERS:
        val = v(name)
        st[name] = val if name.endswith("_s") else int(val)
    st["prompt_pad_waste"] = (st["padded_prompt_tokens"]
                              - st["prompt_tokens"])
    st["tokens_per_s"] = st["tokens"] / max(
        st["prefill_s"] + st["decode_s"], 1e-9)
    return st


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    id: int = 0
    # relative deadline (seconds after arrival; None = none).  Enforced by
    # the continuous engine both in-queue and in-flight — the batch engine
    # ignores it (its whole batch is one dispatch; see docs/serving.md).
    deadline_s: Optional[float] = None
    # shedding priority (repro.fleet): lower sheds first when the fleet is
    # saturated.  Engines ignore it — admission stays strictly FIFO.
    priority: int = 0


class Engine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256, sample: bool = False, mesh=None,
                 precompute: bool = True, decode_mode: str = "scan",
                 eos_id: Optional[int] = None, temperature: float = 1.0,
                 seed: int = 0, bucket_prompts: bool = True,
                 quant: Optional[QuantPolicy] = None,
                 obs: Optional[Obs] = None):
        assert decode_mode in ("scan", "per_token"), decode_mode
        self.cfg = cfg
        self.quant = quant or QuantPolicy()
        # the batch engine's dense cache stays float32 (it is the f32
        # parity ORACLE); only the weight half of the policy applies here
        self.params = (precompute_serving_params(params, cfg, self.quant)
                       if precompute else params)
        self.model = build_model(cfg)
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sample = sample
        self.decode_mode = decode_mode
        self.eos_id = eos_id
        self.temperature = temperature
        self.seed = seed
        self.bucket_prompts = bucket_prompts
        # Largest sliding window any block uses: the ring-buffer prefill
        # keeps the window tail, so batch prompts must cover it (validated
        # per batch below instead of failing as a trace-time assert).
        self._swa_window = 0 if cfg.is_encoder_decoder else max(
            [tfm._window_for(kind, cfg)
             for pattern, _ in tfm.segments_for(cfg)
             for kind in pattern], default=0)
        # Activations are pinned through the same policy the production
        # dry-run uses; default is this host's (n, 1) data-parallel mesh.
        self.mesh = mesh if mesh is not None else mesh_lib.make_host_mesh()
        self._prefill = jax.jit(dec.make_prefill_step(cfg))
        self._decode = jax.jit(
            dec.make_decode_step(cfg, sample=sample, temperature=temperature,
                                 seed=seed),
            donate_argnums=(2,))
        self._loops: Dict[int, object] = {}
        # AOT-compiled executables per concrete shape: (callable, cost).
        # Compiling via .lower().compile() instead of letting the jit
        # wrapper trace on first call costs nothing extra (one compile
        # either way) and hands the profiler the executable whose
        # cost_analysis() prices every later dispatch of that shape.
        self._aot: Dict[tuple, tuple] = {}
        # telemetry (repro.obs): the registry IS the stats() backing store;
        # counters are held directly so the hot path is one float add
        self.obs = obs if obs is not None else Obs()
        reg = self.obs.registry
        self._ctr = {n: reg.counter(n) for n in ENGINE_COUNTERS}
        self._h_prefill = reg.histogram("engine.prefill_dispatch_s")
        self._h_decode = reg.histogram("engine.decode_dispatch_s")
        self._order = 0                     # trace submission order

    def _loop_fn(self, steps: int):
        """jit'd decode loop for a step budget (cached per budget)."""
        fn = self._loops.get(steps)
        if fn is None:
            fn = jax.jit(dec.make_decode_loop(
                self.cfg, steps, sample=self.sample,
                temperature=self.temperature, eos_id=self.eos_id,
                seed=self.seed),
                donate_argnums=(2,))
            self._loops[steps] = fn
        return fn

    def _make_batch(self, reqs: Sequence[Request]) -> Dict:
        B = len(reqs)
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt     # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "audio_stub":
            batch["frames"] = jnp.zeros(
                (B, self.cfg.encoder_seq, self.cfg.d_model), jnp.float32)
        elif self.cfg.frontend == "vision_stub":
            batch["patches"] = jnp.zeros(
                (B, self.cfg.num_patches, self.cfg.d_model), jnp.float32)
        return batch

    def generate(self, reqs: Sequence[Request]) -> List[Dict]:
        """Serve a batch of requests; returns per-request token lists in
        request order.  With ``bucket_prompts`` (default), requests are
        grouped into batches by (prompt length, decode budget) first, so a
        chunk of short prompts is not left-padded to an unrelated long
        prompt's length — and short decodes are not held hostage by a
        batch-mate's long budget (the decode loop runs to the chunk max)."""
        if self.bucket_prompts:
            order = sorted(range(len(reqs)),
                           key=lambda i: (len(reqs[i].prompt),
                                          reqs[i].max_new_tokens))
        else:
            order = list(range(len(reqs)))
        # every request enqueues NOW; later batches' traces carry the queue
        # wait their bucket imposed (admit - enqueue)
        t_enq = self.obs.now()
        traces = [None] * len(reqs)
        if self.obs.enabled:
            for i, r in enumerate(reqs):
                traces[i] = self.obs.trace_start(r.id, self._order,
                                                 len(r.prompt), t_enq)
                self._order += 1
        out: List[Optional[Dict]] = [None] * len(reqs)
        for i in range(0, len(order), self.max_batch):
            idxs = order[i:i + self.max_batch]
            batch_out = self._generate_batch([reqs[j] for j in idxs],
                                             [traces[j] for j in idxs])
            for j, r in zip(idxs, batch_out):
                out[j] = r
        return out

    def _generate_batch(self, reqs: Sequence[Request],
                        traces: Optional[Sequence] = None) -> List[Dict]:
        with dist_ctx.activation_policy(self.mesh):
            return self._generate_batch_inner(
                reqs, traces if traces is not None else [None] * len(reqs))

    def _generate_batch_inner(self, reqs: Sequence[Request],
                              traces: Sequence) -> List[Dict]:
        t0 = time.perf_counter()
        batch = self._make_batch(reqs)
        B, S = batch["tokens"].shape
        if S > self.max_seq:
            raise ValueError(f"prompt length {S} exceeds max_seq "
                             f"{self.max_seq}")
        # Decode step j writes cache position S+j-1 (j=1..steps-1), so the
        # cache needs S+steps-1 slots; clamp the step budget instead of
        # letting dynamic_update_slice silently clobber the last slot
        # (regression-tested in test_decode_loop.py).
        steps = max(r.max_new_tokens for r in reqs)
        steps = max(1, min(steps, self.max_seq - S + 1))
        need = min(self._swa_window, S + steps - 1)
        if self._swa_window and S < need:
            raise ValueError(
                f"batch prompt length {S} does not cover the sliding-window "
                f"ring buffer ({need}): SWA prefill keeps the window tail, "
                f"so prompts must be >= min(window, cache length)")
        cache = self.model.init_cache(B, S + steps - 1, dtype=jnp.float32)
        prof = self.obs.profiler
        key = ("prefill", B, S, steps)
        if key not in self._aot:
            self._aot[key] = aot_compile(
                self._prefill, (self.params, batch, cache), prof,
                dec.batch_prefill_kind(B, S))
        pf, pf_cost = self._aot[key]
        logits, cache = pf(self.params, batch, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        # fence BEFORE every span boundary: the t1/t2 marks (and the trace
        # spans derived from them) measure device work, not dispatch
        jax.block_until_ready(nxt)
        t1 = time.perf_counter()
        loop_cost = None

        if self.decode_mode == "per_token":
            gen = self._decode_per_token(nxt, cache, S, steps)
        else:
            lengths = jnp.asarray([min(r.max_new_tokens, steps)
                                   for r in reqs], jnp.int32)
            lkey = ("loop", steps, B, S)
            if lkey not in self._aot:
                self._aot[lkey] = aot_compile(
                    self._loop_fn(steps),
                    (self.params, nxt, cache, jnp.int32(S), lengths),
                    prof, dec.batch_decode_kind(steps, B))
            loop, loop_cost = self._aot[lkey]
            gen, _ = loop(self.params, nxt, cache, jnp.int32(S), lengths)
        jax.block_until_ready(gen)
        gen = np.asarray(gen)                          # (B, steps)
        t2 = time.perf_counter()
        prefill_s, decode_s = t1 - t0, t2 - t1
        prof.on_dispatch(pf_cost, self.obs.rebase(t0), self.obs.rebase(t1))
        if self.decode_mode != "per_token":
            prof.on_dispatch(loop_cost, self.obs.rebase(t1),
                             self.obs.rebase(t2))

        out = []
        for i, r in enumerate(reqs):
            toks = gen[i, :min(r.max_new_tokens, steps)].tolist()
            if self.eos_id is not None and self.eos_id in toks:
                toks = toks[:toks.index(self.eos_id) + 1]
            status = (FINISHED_EOS if (self.eos_id is not None and toks
                                       and toks[-1] == self.eos_id)
                      else FINISHED_BUDGET)
            out.append({
                "id": r.id,
                "tokens": toks,
                "decode_len": len(toks),
                "status": status,
                "preemptions": 0,
                "tokens_per_s": len(toks) / max(decode_s, 1e-9),
                "prefill_s": prefill_s,
                "decode_s": decode_s,
                "latency_s": prefill_s + decode_s,
            })
        c = self._ctr
        c["requests"].inc(len(reqs))
        c["dispatches"].inc()
        c["tokens"].inc(sum(r["decode_len"] for r in out))
        c["prompt_tokens"].inc(sum(len(r.prompt) for r in reqs))
        c["padded_prompt_tokens"].inc(B * S)
        c["prefill_s"].inc(prefill_s)
        c["decode_s"].inc(decode_s)
        if self.obs.enabled:
            self._h_prefill.observe(prefill_s)
            self._h_decode.observe(decode_s)
            for tr, res in zip(traces, out):
                if tr is None:
                    continue
                tr.status = res["status"]
                tr.mark_admit(self.obs.rebase(t0))
                tr.mark_first_token(self.obs.rebase(t1))
                if res["decode_len"] > 1:
                    tr.mark_chunk(self.obs.rebase(t2),
                                  res["decode_len"] - 1)
                tr.mark_retire(self.obs.rebase(t2))
                self.obs.trace_finish(tr)
        self.obs.tick()
        return out

    def _decode_per_token(self, nxt, cache, S: int, steps: int) -> np.ndarray:
        """Seed host loop: one dispatch per token (baseline/oracle path)."""
        toks = [nxt]
        for pos in range(S, S + steps - 1):
            _, nxt, cache = self._decode(self.params, nxt[:, None], cache,
                                         jnp.int32(pos))
            toks.append(nxt)
        return np.asarray(jnp.stack(toks, 1))          # (B, steps)

    def stats(self) -> Dict:
        """Cumulative engine telemetry as a view over the obs registry —
        one schema shared with ContinuousEngine.stats()
        (docs/observability.md).  ``batches`` is the legacy alias for the
        unified ``dispatches`` counter (one decode dispatch per batch)."""
        st = _engine_stats_view(self.obs, "batch")
        st["batches"] = st["dispatches"]     # legacy alias (one release)
        st["hardware"] = self.obs.profiler.spec.name
        st["roofline"] = self.obs.profiler.summary()
        return st


# ---------------------------------------------------------------------------
# Continuous batching over the paged pool
# ---------------------------------------------------------------------------
class ContinuousEngine:
    """Continuous-batching engine: paged KV pool + token-budget scheduler.

    Serves decoder-LM archs with linear (global-attention) caches — see
    ``kvcache.servable_reasons``; SWA/recurrent/enc-dec archs stay on the
    batch engine.  Greedy outputs are token-identical to the batch engine
    run per-request (B=1): prefill is exact-position (right-pad bucketed),
    decode runs every slot at its own absolute position.

    ``generate(reqs, arrival_times=...)`` simulates an online arrival
    process against wall-clock time (benchmarks); without arrival times the
    whole list queues at t=0 and drains under the admission policy.

    Request lifecycle (docs/serving.md): every submitted request reaches
    exactly one terminal status.  ``admission="optimistic"`` (default)
    reserves only the prefill pages at admit and grows pages before each
    decode dispatch — on pool exhaustion the youngest running slot is
    PREEMPTED (pages freed, request re-queued for recompute-prefill with
    its generated tokens teacher-forced through the prompt), bounded by
    ``max_preemptions`` per request; greedy outputs stay token-identical
    to the oracle across preemption.  Deadlines (``Request.deadline_s``,
    relative to arrival) are enforced in-queue and in-flight (TIMEOUT);
    ``cancel(request_id)`` works in both places (CANCELLED); ``max_queue``
    bounds the submit queue (REJECTED backpressure); ``drain()`` stops
    intake, sheds fresh queued work, finishes in-flight requests, and
    flushes the obs emitter.  A ``faults`` injector (serve/faults.py)
    hooks allocator failures, dispatch delays, and slot corruption — the
    NaN/Inf guard (``nan_guard``) retires poisoned slots FAILED.
    """

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_seq: int = 256, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 max_tokens_in_flight: Optional[int] = None,
                 decode_chunk: int = 8, sample: bool = False,
                 temperature: float = 1.0, seed: int = 0,
                 eos_id: Optional[int] = None, mesh=None,
                 precompute: bool = True, paged_attn: str = "stream",
                 quant: Optional[QuantPolicy] = None,
                 obs: Optional[Obs] = None,
                 admission: str = "optimistic",
                 max_queue: Optional[int] = None,
                 max_preemptions: int = 4,
                 nan_guard: bool = True,
                 faults=None,
                 shadow_sample: float = 0.0,
                 capture: Optional[bool] = None):
        if paged_attn not in ("stream", "gather"):
            raise ValueError(f"paged_attn {paged_attn!r}: "
                             f"expected 'stream' or 'gather'")
        reasons = kvc.servable_reasons(cfg)
        if reasons:
            raise ValueError(f"{cfg.name} is not continuous-servable: "
                             f"{'; '.join(reasons)} — use Engine")
        self.cfg = cfg
        self.quant = quant or QuantPolicy()
        raw_params = params                 # pre-precompute tree (shadow
        self.params = (precompute_serving_params(params, cfg, self.quant)
                       if precompute else params)  # oracle replays from it)
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.decode_chunk = decode_chunk
        self.sample = sample
        self.eos_id = eos_id
        self.paged_attn = paged_attn
        self.max_pages_per_slot = kvc.pages_for(max_seq, page_size)
        if num_pages is None:
            num_pages = max_slots * self.max_pages_per_slot + 1
        if num_pages < self.max_pages_per_slot + 1:
            raise ValueError(f"num_pages {num_pages} cannot hold one "
                             f"max_seq request (+trash page)")
        if max_tokens_in_flight is None:
            # Streamed paged attention (the default) never materializes the
            # (B, maxp*page, Hkv, D) gathered KV view, so peak decode memory
            # no longer scales with slots x max_seq — the default admission
            # budget fills every slot.  The gather oracle's default is NEWLY
            # halved here (PR 3 defaulted to the ceiling): every token it
            # has in flight pays an O(max_seq) gather per decode step, so
            # its memory-honest budget is conservative.  Pass
            # max_tokens_in_flight explicitly to A/B the attention paths
            # under identical admission.
            ceiling = max_slots * (max_seq + 1)
            max_tokens_in_flight = (ceiling if paged_attn == "stream"
                                    else max(max_seq + 1, ceiling // 2))
        if max_tokens_in_flight < max_seq + 1:
            raise ValueError(f"max_tokens_in_flight {max_tokens_in_flight} "
                             f"cannot admit one max_seq request")
        self.mesh = mesh if mesh is not None else mesh_lib.make_host_mesh()
        # keep the page dim DP-divisible, else page_pool_spec's fallback
        # would replicate the whole pool over the data-parallel devices
        num_pages = dist_sharding.dp_round_up(num_pages, self.mesh)
        self.num_pages = num_pages
        self.pool = kvc.build_pool(cfg, num_pages, page_size, self.quant)
        # pin the pool to its derived layout (pages over DP, heads over
        # "model" — the dense cache's placement, see dist/sharding.py);
        # trivial on the 1-device host mesh, load-bearing on real meshes
        self.pool = jax.device_put(self.pool, dist_sharding.to_shardings(
            dist_sharding.pool_specs(self.pool, self.mesh), self.mesh))
        # telemetry (repro.obs): the registry backs stats(); the allocator
        # and scheduler write their own gauges/counters into it
        self.obs = obs if obs is not None else Obs()
        reg = self.obs.registry
        # numerics capture rides obs.enabled: disabled obs compiles the
        # exact pre-health device programs (stats leaves are None pytree
        # leaves, not zero-filled buffers), so the obs_overhead bench's
        # disabled arm stays an honest baseline.  ``capture=False`` opts
        # an enabled-obs engine out of the health plane — the bench's
        # middle arm, which isolates the capture's incremental price from
        # the rest of the telemetry stack.
        self._capture = (self.obs.enabled if capture is None
                         else bool(capture) and self.obs.enabled)
        self.faults = faults
        self.block_table = kvc.BlockTable(
            kvc.PageAllocator(num_pages, registry=reg,
                              fault=(faults.alloc_fault
                                     if faults is not None else None)),
            max_slots, page_size, self.max_pages_per_slot)
        self.scheduler = Scheduler(self.block_table, max_seq=max_seq,
                                   max_tokens_in_flight=max_tokens_in_flight,
                                   registry=reg, admission=admission,
                                   max_queue=max_queue,
                                   max_preemptions=max_preemptions)
        # sample the control-plane gauges at every dispatch end — the
        # Chrome-trace counter tracks (obs/chrometrace.py)
        for gname in ("pool.free_pages", "sched.queue_depth",
                      "sched.tokens_in_flight"):
            self.obs.profiler.watch(gname)
        # ONE fixed-size decode program: chunk size never varies, so the
        # loop compiles exactly once — adaptive sizing would dodge some
        # frozen-slot steps but risks multi-second mid-serving compiles the
        # first time an unseen size comes up (disastrous for tail latency)
        self._loop = jax.jit(dec.make_paged_decode_loop(
            cfg, decode_chunk, sample=sample, temperature=temperature,
            eos_id=eos_id, seed=seed, paged_impl=paged_attn,
            nan_guard=nan_guard, capture_stats=self._capture),
            donate_argnums=(2,))
        # AOT executable + DispatchCost for the one decode program,
        # captured at the first dispatch (obs/prof.py); prefill buckets
        # cache theirs in self._prefills
        self._loop_exec = None
        self.nan_guard = nan_guard
        self._prefills: Dict[int, tuple] = {}
        self._cur = np.zeros(max_slots, np.int32)
        self._pos = np.zeros(max_slots, np.int32)
        self._rem = np.zeros(max_slots, np.int32)
        self._dev_table = None              # device copy of the block table
        self._table_version = -1            # BlockTable.version it mirrors
        self._ctr = {n: reg.counter(n) for n in ENGINE_COUNTERS}
        self._c_anom = reg.counter("engine.anomalies")
        self._h_prefill = reg.histogram("engine.prefill_dispatch_s")
        self._h_chunk = reg.histogram("engine.decode_chunk_s")
        self._h_occup = reg.histogram("sched.slot_occupancy",
                                      bounds=RATIO_BUCKETS)
        self._h_attn_bytes = reg.histogram("attn.bytes_per_token",
                                           bounds=BYTES_BUCKETS)
        self._c_growths = reg.counter("quant.scale_growths")
        # per-position attention byte term for the live bytes/token series
        self._attn_per_pos = kvc.attention_bytes_per_position(
            self.pool)["per_pos"]
        # numerics health plane (obs/health.py): folds the fixed-shape
        # stats side-outputs the captured device programs return, so the
        # binary NaN guard above becomes the degenerate case of labelled
        # absmax/entropy/margin histograms + non-finite counters
        self._health = HealthPlane(reg) if self._capture else None
        # quant clip telemetry: saturation pressure, not overflow — with
        # absmax scaling the block max sits AT the rail by construction,
        # so plane_clip_rate/kv_clip_rate read as "fraction of values at
        # the quantization rail" (docs/quantization.md)
        self._c_kv_clip = reg.counter("quant.clip.kv_clipped")
        self._c_kv_total = reg.counter("quant.clip.kv_total")
        self._g_kv_clip = reg.gauge("quant.kv_clip_rate")
        if self._capture and self.quant.quant_weights:
            prep = plane_clip_report(self.params)
            reg.counter("quant.clip.plane_clipped").inc(prep["clipped"])
            reg.counter("quant.clip.plane_total").inc(prep["total"])
            reg.gauge("quant.plane_clip_rate").set(
                prep["clipped"] / max(prep["total"], 1))
        # host shadow of the int8 pool's k/v scales: decode-dispatch diffs
        # count page-scatter requantize-on-grow events (scales only GROW)
        # and feed the scale histograms + requant-error accounting
        self._scales_host = (kvc.pool_scale_map(self.pool)
                             if self._capture and self.quant.kv_quantized
                             else None)
        self._h_scale = {}
        if self._scales_host is not None:
            for k in ("k_scale", "v_scale"):
                self._h_scale[k] = reg.histogram("quant." + k,
                                                 bounds=SCALE_BUCKETS)
            self._h_grow = reg.histogram("quant.scale_grow_ratio",
                                         bounds=RATIO_BUCKETS)
            # running bound on requantize error: a grown page rescales its
            # resident int8 values; per element the round-off is at most
            # new_scale/2, accumulated here per grown (page, head) group
            self._c_requant = reg.counter("quant.requant_error_bound")
        # shadow-oracle sampling (obs/health.py): replay a fraction of
        # FINISHED requests through the f32 dense-cache oracle between
        # dispatches — online greedy_agreement/logit_drift on the same
        # teacher-forced harness quant/calibrate.py runs offline
        self._shadow = None
        if shadow_sample > 0.0:
            if not precompute:
                raise ValueError("shadow_sample needs precompute=True: the "
                                 "oracle precomputes f32 serving params "
                                 "from the raw tree")
            self._shadow = ShadowOracle(cfg, raw_params, policy=self.quant,
                                        registry=reg, sample=shadow_sample,
                                        seed=seed, page_size=page_size)
        self._traces: Dict[int, object] = {}     # submission order -> trace
        self._t0_perf = None                # serve-clock origin (perf)
        self._results: Dict[int, Dict] = {}      # order -> terminal result
        self._cancels: set = set()          # request ids pending cancel
        self._stall_streak = 0              # consecutive all-stalled rounds
        self._stall_limit = 3               # then FAIL the youngest stalled
        # birth snapshot: every counter above now exists at its true zero,
        # so SLO rate windows cover the whole serve — a guard trip before
        # the first emit_every tick still lands in a visible delta
        # (obs/slo.py rate rules skip the baseline-less first snapshot)
        self.obs.baseline()

    # -- jit caches -------------------------------------------------------
    def _prefill_exec(self, n_pages: int, args) -> tuple:
        """(compiled callable, DispatchCost|None) for a page bucket —
        compiled AOT on first use with the bucket's concrete ``args`` so
        the profiler prices every later dispatch of the bucket."""
        ent = self._prefills.get(n_pages)
        if ent is None:
            jitfn = jax.jit(dec.make_prefill_pack_step(
                self.cfg, n_pages, self.page_size,
                capture_stats=self._capture), donate_argnums=(2,))
            ent = aot_compile(jitfn, args, self.obs.profiler,
                              dec.prefill_kind(n_pages))
            self._prefills[n_pages] = ent
        return ent

    # -- public lifecycle API ---------------------------------------------
    def _now(self) -> float:
        """Seconds on the serve clock (0 at the first submit)."""
        if self._t0_perf is None:
            self._t0_perf = time.perf_counter()
        return time.perf_counter() - self._t0_perf

    def reset_serve_clock(self) -> None:
        """Re-anchor the serve clock at the next submit/step.  A fleet
        replica calls this when adopting a (possibly warmed) engine:
        arrival and deadline stamps are router-relative, and an engine
        whose clock still counts from a warmup ``generate`` would see
        every stamp seconds in the past and expire fresh deadlines on
        arrival.  Only legal while idle — in-flight work carries absolute
        stamps on the current clock."""
        if not self.scheduler.idle:
            raise RuntimeError("reset_serve_clock with work in flight")
        self._t0_perf = None

    def submit(self, request: Request, arrival_s: float = 0.0, *,
               resume_tokens: Optional[Sequence[int]] = None,
               preemptions: int = 0) -> int:
        """Queue one request; returns its order (the key for results).

        A rejected submission (queue bound hit / draining) still gets an
        order and an immediate REJECTED terminal result — callers never
        lose a request.

        ``resume_tokens`` re-enters a request mid-stream (cross-replica
        failover migration, repro.fleet): the tokens are teacher-forced
        through recompute-prefill exactly like a local preemption's
        resume, so greedy decode stays token-identical to the B=1 oracle.
        ``preemptions`` carries the request's eviction count across the
        migration for honest end-to-end accounting."""
        if len(request.prompt) > self.max_seq:
            raise ValueError(f"prompt length {len(request.prompt)} exceeds "
                             f"max_seq {self.max_seq}")
        resume = list(resume_tokens) if resume_tokens else []
        if len(request.prompt) + len(resume) > self.max_seq:
            raise ValueError(
                f"prompt + resume length {len(request.prompt) + len(resume)} "
                f"exceeds max_seq {self.max_seq}")
        self._now()                          # pin the serve clock
        order, accepted = self.scheduler.submit(request, arrival_s,
                                                resume_tokens=resume,
                                                preemptions=preemptions)
        if self.obs.enabled:
            # a request ENQUEUES at its (possibly simulated) arrival — the
            # trace timeline starts there so queue_s covers admission wait
            self._traces[order] = self.obs.trace_start(
                request.id, order, len(request.prompt),
                self.obs.rebase(self._t0_perf) + arrival_s)
        if not accepted:
            self._finish_unserved(order, request, resume, REJECTED,
                                  preemptions=preemptions)
        return order

    def cancel(self, request_id) -> bool:
        """Cancel a request wherever it lives.  Queued: the CANCELLED
        result materializes immediately.  Running: the slot is retired at
        the next step boundary (its in-flight chunk is abandoned).
        Returns False when the id is unknown or already terminal."""
        found = self.scheduler.cancel(request_id)
        if found is None:
            return False
        kind, obj = found
        if kind == "queued":
            self._finish_unserved(obj.order, obj.request, obj.resume_tokens,
                                  CANCELLED, preemptions=obj.preemptions)
        else:
            self._cancels.add(request_id)
        return True

    def step(self) -> bool:
        """Run one scheduler round: expire deadlines, apply cancels, admit
        + prefill, grow pages (possibly preempting), dispatch one decode
        chunk, retire finished slots.  Admission honors submit-time
        arrival stamps (a request whose simulated arrival is still in the
        future stays queued).  Returns True if anything happened — the
        low-level API the chaos harness drives; ``generate`` is a loop
        over this."""
        with dist_ctx.activation_policy(self.mesh):
            now = self._now()
            return self._step(now, arrived_before=now)

    def drain(self) -> List[Dict]:
        """Graceful shutdown: stop admitting, shed fresh queued work as
        REJECTED, run in-flight requests (including preempted ones) to
        their terminal state, flush + close the obs emitter.  Returns the
        results of everything that went terminal during the drain."""
        before = set(self._results)
        self.scheduler.close_intake()
        for entry in self.scheduler.flush_queue():
            self._finish_unserved(entry.order, entry.request,
                                  entry.resume_tokens, REJECTED,
                                  preemptions=entry.preemptions)
        with dist_ctx.activation_policy(self.mesh):
            while not self.scheduler.idle:
                if not self._step(self._now()):
                    raise RuntimeError("drain stall: in-flight work cannot "
                                       "make progress")
            if self._shadow is not None:
                self._shadow.drain()
        self.obs.close()
        return [self._results[o] for o in sorted(set(self._results) - before)]

    def result(self, order: int, pop: bool = False) -> Optional[Dict]:
        """Terminal result for a submission order (None while in flight)."""
        return (self._results.pop(order, None) if pop
                else self._results.get(order))

    @property
    def anomalies(self) -> int:
        """Cumulative NaN/Inf-guard trips — the health signal
        ``fleet.EngineReplica`` folds into its DEGRADED transitions."""
        return int(self._c_anom.value)

    # -- serving loop -----------------------------------------------------
    def generate(self, reqs: Sequence[Request],
                 arrival_times: Optional[Sequence[float]] = None
                 ) -> List[Dict]:
        for r in reqs:                      # validate BEFORE admitting any:
            if len(r.prompt) > self.max_seq:   # a mid-loop raise would leak
                raise ValueError(              # running slots' pages
                    f"prompt length {len(r.prompt)} exceeds max_seq "
                    f"{self.max_seq}")
        self._t0_perf = time.perf_counter()
        arr = ([0.0] * len(reqs) if arrival_times is None
               else [float(a) for a in arrival_times])
        orders = [self.submit(r, a) for r, a in zip(reqs, arr)]
        gate = arrival_times is not None
        with dist_ctx.activation_policy(self.mesh):
            while not self.scheduler.idle:
                now = self._now()
                if gate and not self.scheduler.running and \
                        self.scheduler.queue:
                    # engine idle: sleep until the HEAD's arrival (admission
                    # is strictly FIFO, so the head's arrival is the binding
                    # one even when arrival times are unsorted)
                    next_arr = self.scheduler.queue[0].arrival_s
                    if next_arr > now:
                        time.sleep(next_arr - now)
                        now = self._now()
                progress = self._step(now,
                                      arrived_before=now if gate else None)
                if (not progress and not self.scheduler.running
                        and self.scheduler.queue):
                    if (gate and
                            self.scheduler.queue[0].arrival_s > self._now()):
                        continue            # head simply hasn't arrived yet
                    raise RuntimeError(
                        "scheduler stall: queued request cannot be admitted "
                        "into an idle engine (budget/pool too small)")
            if self._shadow is not None:
                # flush pending replays so short runs still publish
                # agreement/drift before the caller reads stats()
                self._shadow.drain()
        return [self._results.pop(o) for o in orders]

    def _step(self, now_s: float,
              arrived_before: Optional[float] = None) -> bool:
        """One scheduler round between device dispatches."""
        sched = self.scheduler
        progress = False
        # 1. queued deadlines
        for entry in sched.expire_queue(now_s):
            self._finish_unserved(entry.order, entry.request,
                                  entry.resume_tokens, TIMEOUT,
                                  preemptions=entry.preemptions)
            progress = True
        # 2. pending cancels of running slots (queued cancels resolved
        #    inside cancel(); stale ids — already terminal — are dropped)
        if self._cancels:
            for slot in list(sched.running):
                if slot.request.id in self._cancels:
                    self._finish(slot, CANCELLED)
                    progress = True
            self._cancels.clear()
        # 3. in-flight deadlines
        for slot in list(sched.running):
            if slot.deadline_s is not None and now_s > slot.deadline_s:
                self._finish(slot, TIMEOUT)
                progress = True
        # 4. admission + prefill (recompute-prefill for preempted entries)
        admitted = sched.try_admit(now_s, arrived_before)
        for entry in sched.drain_doomed():   # can NEVER fit the pool
            self._finish_unserved(entry.order, entry.request,
                                  entry.resume_tokens, FAILED,
                                  preemptions=entry.preemptions)
            progress = True
        for slot in admitted:
            self._prefill_slot(slot)
            progress = True
        # 5. page growth for the next chunk; preemptions free their victim's
        #    device state
        prep = sched.prepare_decode(self.decode_chunk)
        t_pre = self.obs.rebase(time.perf_counter())
        for idx, entry in prep.preempted:
            self._rem[idx] = 0              # victim's slot is dead on device
            progress = True
            if self.obs.enabled:
                tr = self._traces.get(entry.order)
                if tr is not None:
                    tr.mark_preempt(t_pre, len(entry.resume_tokens))
        # 6. decode dispatch over the slots whose pages cover the chunk
        if admitted or prep.preempted or prep.runnable:
            self._stall_streak = 0
        if prep.runnable:
            self._dispatch_decode(prep.runnable, prep.stalled)
            progress = True
        elif prep.stalled:
            # every live slot is starved and no victim remains under the
            # preemption bound.  Transient allocator faults clear on retry,
            # so retry a bounded number of rounds; past the limit this is
            # genuine starvation — FAIL the youngest stalled slot to free
            # pages instead of livelocking.
            self._stall_streak += 1
            progress = True
            if self._stall_streak >= self._stall_limit:
                victim = max(prep.stalled, key=lambda s: s.order)
                self._finish(victim, FAILED)
                self._stall_streak = 0
        if self._shadow is not None:
            self._shadow.tick()     # at most one replay, off the hot path
        self.obs.tick()             # emitter rides the dispatch cadence
        return progress

    def _prefill_slot(self, slot) -> None:
        t0 = time.perf_counter()
        req = slot.request
        # a resumed (preempted) request teacher-forces prompt + generated
        # tokens through prefill: greedy decode then continues identically
        prompt = list(np.asarray(req.prompt).tolist()) + list(slot.tokens)
        S = len(prompt)
        n_pages = kvc.pages_for(S, self.page_size)
        spad = n_pages * self.page_size
        toks = np.zeros(spad, np.int32)
        toks[:S] = prompt                              # right-pad
        batch = {"tokens": jnp.asarray(toks[None])}
        if self.cfg.frontend == "vision_stub":
            batch["patches"] = jnp.zeros(
                (1, self.cfg.num_patches, self.cfg.d_model), jnp.float32)
        pages = jnp.asarray(self.block_table.pages(slot.index)[:n_pages],
                            jnp.int32)
        fn, cost = self._prefill_exec(
            n_pages, (self.params, batch, self.pool, pages, jnp.int32(S)))
        nxt, ok, self.pool, pstats = fn(
            self.params, batch, self.pool, pages, jnp.int32(S))
        # fence the whole dispatch (token, page scatter AND the numerics
        # side-output) so the prefill span — and the trace's first-token
        # mark — measure device work, not a later host sync
        jax.block_until_ready((nxt, self.pool) if pstats is None
                              else (nxt, self.pool, pstats))
        t1 = time.perf_counter()
        self.obs.profiler.on_dispatch(cost, self.obs.rebase(t0),
                                      self.obs.rebase(t1))
        dt = t1 - t0
        self._ctr["prefill_s"].inc(dt)
        self._ctr["prompt_tokens"].inc(S)
        self._ctr["padded_prompt_tokens"].inc(spad)
        slot.prefill_s = dt
        if self._health is not None and pstats is not None:
            # fold BEFORE the guard branch: a poisoned prefill must bump
            # health.nonfinite_* in the same dispatch the guard retires it.
            # The device packs everything into ONE flat vector
            # [logit(4) | kv_clipped | kv_total | act_absmax...] so this
            # is a single device->host transfer per prefill, not four.
            arr = np.asarray(pstats, dtype=np.float64)
            self._health.on_prefill({"logit": arr[:4],
                                     "act_absmax": arr[6:]})
            kv_total = float(arr[5])
            if kv_total > 0:
                self._c_kv_clip.inc(float(arr[4]))
                self._c_kv_total.inc(kv_total)
                self._g_kv_clip.set(self._c_kv_clip.value
                                    / max(self._c_kv_total.value, 1.0))
        if self.nan_guard and not bool(ok):
            # poisoned prefill: never stream a garbage first token
            self._c_anom.inc()
            self._rem[slot.index] = 0
            if self.obs.enabled:
                self._h_prefill.observe(dt)
                tr = self._traces.get(slot.order)
                if tr is not None and tr.admit_s is None:
                    tr.mark_admit(self.obs.rebase(self._t0_perf)
                                  + slot.admit_s)
            self._finish(slot, FAILED)
            return
        first = int(nxt)
        slot.tokens.append(first)
        slot.pos = S                       # position of the token in flight
        slot.budget -= 1
        self._cur[slot.index] = first
        self._pos[slot.index] = S
        self._rem[slot.index] = slot.budget
        self._ctr["tokens"].inc()          # the prefill-emitted token
        if self.obs.enabled:
            self._h_prefill.observe(dt)
            tr = self._traces.get(slot.order)
            if tr is not None:
                t_first = self.obs.rebase(t1)
                if tr.admit_s is None:     # first admission of this request
                    tr.mark_admit(self.obs.rebase(self._t0_perf)
                                  + slot.admit_s)
                    tr.mark_first_token(t_first)
                else:                      # recompute-prefill after preempt
                    tr.mark_chunk(t_first, 1)
            if self._scales_host is not None:
                # prefill packs fresh pages (new scales, not grow events):
                # refresh the shadow so the next decode diff is clean, and
                # census the freshly written scales into the saturation
                # histograms
                new = kvc.pool_scale_map(self.pool)
                for k, h in self._h_scale.items():
                    fresh = new[k][(new[k] != self._scales_host[k])
                                   & (new[k] > 0)]
                    for sc in fresh.tolist():
                        h.observe(float(sc))
                self._scales_host = new
        if (len(slot.tokens) >= slot.total_budget
                or (self.eos_id is not None and first == self.eos_id)):
            self._rem[slot.index] = 0
            self._finish(slot)
        elif slot.deadline_s is not None and self._now() > slot.deadline_s:
            self._rem[slot.index] = 0
            self._finish(slot, TIMEOUT)

    def _dispatch_decode(self, runnable, stalled) -> None:
        if self.faults is not None:
            delay = self.faults.dispatch_delay()
            if delay > 0.0:
                time.sleep(delay)          # injected control-plane hiccup
            victim = self.faults.pick_corruption(runnable)
            if victim is not None:
                from .faults import poison_slot_pages
                self.pool = poison_slot_pages(
                    self.pool, self.block_table.pages(victim.index)[0])
        t0 = time.perf_counter()
        # stalled slots (no pages for the next chunk) are masked out of
        # this dispatch: rem=0 freezes them on device, their budget is
        # restored afterwards so they retry next round
        rem_dispatch = self._rem.copy()
        for s in stalled:
            rem_dispatch[s.index] = 0
        if self._table_version != self.block_table.version:
            self._dev_table = self.block_table.device_table()
            self._table_version = self.block_table.version
        if self._loop_exec is None:
            self._loop_exec = aot_compile(
                self._loop,
                (self.params, jnp.asarray(self._cur), self.pool,
                 self._dev_table, jnp.asarray(self._pos),
                 jnp.asarray(rem_dispatch)),
                self.obs.profiler, dec.DECODE_CHUNK_KIND)
        loop, loop_cost = self._loop_exec
        buf, cur, self.pool, pos, rem, done, anom, dstats = loop(
            self.params, jnp.asarray(self._cur), self.pool,
            self._dev_table, jnp.asarray(self._pos),
            jnp.asarray(rem_dispatch))
        # fence before the span boundary: the decode_chunk wall time (and
        # the per-chunk trace marks) measure the device program — the
        # numerics side-output fences with it, so the health fold below
        # is a pure host read
        jax.block_until_ready(buf if dstats is None else (buf, dstats))
        t1 = time.perf_counter()
        self.obs.profiler.on_dispatch(loop_cost, self.obs.rebase(t0),
                                      self.obs.rebase(t1))
        buf = np.asarray(buf)
        self._cur = np.array(cur)
        self._pos = np.array(pos)
        rem_after = np.array(rem)
        done = np.asarray(done)
        anom = np.asarray(anom)
        saved = {s.index: self._rem[s.index] for s in stalled}
        self._rem = rem_after
        for idx, v in saved.items():
            self._rem[idx] = v
        dt = t1 - t0
        self._ctr["decode_s"].inc(dt)
        self._ctr["dispatches"].inc()
        if self.obs.enabled:
            self._h_chunk.observe(dt)
            self._h_occup.observe(len(runnable) / max(self.max_slots, 1))
            if self._health is not None and dstats is not None:
                # steps[b] = tokens slot b advanced this dispatch: rows
                # with 0 still carry init sentinels (or stale maxima from
                # the donated carry) and are skipped by the fold
                self._health.on_decode(np.asarray(dstats),
                                       steps=rem_dispatch - rem_after)
            if self._scales_host is not None:
                new = kvc.pool_scale_map(self.pool)
                grown = 0
                for k, old in self._scales_host.items():
                    g = new[k] > old
                    if g.any():
                        grown += int(g.sum())
                        ns, olds = new[k][g], old[g]
                        # per-element round-off of a rescale is bounded by
                        # new_scale/2; accumulate the per-group bound
                        self._c_requant.inc(float(0.5 * ns.sum()))
                        for s_old, s_new in zip(olds.tolist(), ns.tolist()):
                            if s_new > 0:
                                self._h_grow.observe(s_old / s_new)
                            self._h_scale[k].observe(s_new)
                self._c_growths.inc(grown)
                self._scales_host = new
        t_chunk = self.obs.rebase(t1)
        for slot in runnable:
            b = slot.index
            n = int(rem_dispatch[b] - rem_after[b])
            if n:
                slot.tokens.extend(buf[b, :n].tolist())
                slot.pos = int(self._pos[b])
                self._ctr["tokens"].inc(n)
                if self.obs.enabled:
                    # live-length bytes/token: what attention actually
                    # streamed for this slot (worst case is in stats())
                    self._h_attn_bytes.observe(
                        self._attn_per_pos * int(self._pos[b]))
                    tr = self._traces.get(slot.order)
                    if tr is not None:
                        tr.mark_chunk(t_chunk, n)
            if anom[b]:
                self._c_anom.inc()
                self._finish(slot, FAILED)
            elif done[b]:
                self._finish(slot)

    # -- terminal transitions ---------------------------------------------
    def _finish(self, slot, status: Optional[str] = None) -> None:
        """Retire a slot-resident request.  ``status`` None infers the
        natural finish (EOS vs budget); explicit statuses come from the
        cancel/timeout/failure paths."""
        if status is None:
            toks = slot.tokens
            status = (FINISHED_EOS
                      if (self.eos_id is not None and toks
                          and toks[-1] == self.eos_id)
                      else FINISHED_BUDGET)
        if (self._shadow is not None
                and status in (FINISHED_EOS, FINISHED_BUDGET)):
            # only cleanly finished requests are parity-replayable (their
            # full greedy trajectory exists); the replay itself happens
            # between dispatches, in _step / drain
            self._shadow.maybe_enqueue(np.asarray(slot.request.prompt),
                                       len(slot.tokens))
        now = self._now()
        prefill_s = getattr(slot, "prefill_s", 0.0)
        arrival, admit = slot.arrival_s, slot.admit_s
        order = slot.order
        self._rem[slot.index] = 0           # device slot is dead
        res = self.scheduler.retire(slot, status)  # releases the pages
        tr = self._traces.pop(order, None)
        if tr is not None:
            # one timeline: the result's latency fields come FROM the trace,
            # so bench percentiles over results and over traces are the same
            # numbers by construction
            tr.status = status
            # clamp: a cancel/timeout can land before a SIMULATED arrival
            tr.mark_retire(max(self.obs.rebase(self._t0_perf) + now,
                               tr.enqueue_s))
            self.obs.trace_finish(tr)
            decode_s = tr.decode_s if tr.decode_s is not None else 0.0
            res.update({
                "tokens_per_s": res["decode_len"] / max(decode_s, 1e-9),
                "prefill_s": tr.prefill_s,
                "decode_s": decode_s,
                "queue_s": tr.queue_s,
                "latency_s": tr.latency_s,
            })
        else:
            decode_s = max(now - admit - prefill_s, 0.0)
            res.update({
                "tokens_per_s": res["decode_len"] / max(decode_s, 1e-9),
                "prefill_s": prefill_s,
                "decode_s": decode_s,
                "queue_s": max(admit - arrival, 0.0),
                "latency_s": max(now - arrival, 0.0),
            })
        self._ctr["requests"].inc()
        self._results[res.pop("order")] = res

    def _finish_unserved(self, order: int, request, tokens, status: str,
                         preemptions: int = 0) -> None:
        """Terminal result for a request that never (re)entered a slot —
        rejected, cancelled in queue, or expired in queue.  The scheduler
        already bumped the terminal counter on all of these paths."""
        now = self._now()
        tr = self._traces.pop(order, None)
        res = {
            "id": request.id,
            "tokens": list(tokens),
            "decode_len": len(tokens),
            "status": status,
            "preemptions": preemptions,
            "tokens_per_s": 0.0,
            "prefill_s": None,
            "decode_s": 0.0,
            "queue_s": None,
            "latency_s": None,
        }
        if tr is not None:
            tr.status = status
            # clamp: a cancel/reject can land before a SIMULATED arrival
            tr.mark_retire(max(self.obs.rebase(self._t0_perf) + now,
                               tr.enqueue_s))
            self.obs.trace_finish(tr)
            res["latency_s"] = tr.latency_s
            res["queue_s"] = tr.latency_s   # never admitted: all queue wait
        self._results[order] = res

    # -- telemetry --------------------------------------------------------
    def stats(self) -> Dict:
        """Engine + scheduler telemetry as a view over the obs registry —
        one schema shared with Engine.stats() (docs/observability.md):
        queue depth, in-flight tokens, page-pool utilization,
        prefill/decode split, pool footprint, and the decode-attention
        memory estimates (worst case: every slot at full length) the
        serving benchmarks record.  ``decode_dispatches`` is the legacy
        alias for the unified ``dispatches`` counter."""
        st = _engine_stats_view(self.obs, "continuous")
        st["decode_dispatches"] = st["dispatches"]  # legacy alias
        st.update(self.scheduler.stats())
        v = self.obs.registry.value
        st["anomalies"] = int(v("engine.anomalies"))
        st["free_pages"] = int(v("pool.free_pages"))
        # pool-pressure headroom: the low-water mark of the free list over
        # the whole serve (the number the prefix-cache sizing will need)
        low = self.obs.registry.gauge("pool.free_pages").min_seen
        st["min_free_pages"] = (int(low) if low is not None
                                else st["free_pages"])
        st["pages_alloc"] = int(v("pool.pages_alloc"))
        st["pages_freed"] = int(v("pool.pages_freed"))
        st["scale_growths"] = int(v("quant.scale_growths"))
        kv_total = v("quant.clip.kv_total")
        st["kv_clip_rate"] = (v("quant.clip.kv_clipped") / kv_total
                              if kv_total else None)
        if self._health is not None:
            st["health"] = self._health.stats()
        if self._shadow is not None:
            st["shadow_oracle"] = self._shadow.stats()
        st["pool_bytes"] = kvc.pool_bytes(self.pool)
        st["kv_pool_bytes"] = st["pool_bytes"]     # quant-satellite alias
        st["quant_policy"] = self.quant.describe()
        st["prefill_buckets"] = sorted(self._prefills)
        st["attention_impl"] = self.paged_attn
        st.update(kvc.attention_memory_est(
            self.pool, self.max_slots, self.max_pages_per_slot,
            self.page_size, self.paged_attn))
        st["decode_peak_bytes_est"] = (st["pool_bytes"]
                                       + st["peak_attention_bytes"])
        st["hardware"] = self.obs.profiler.spec.name
        st["roofline"] = self.obs.profiler.summary()
        return st
