"""Batched serving engine: continuous-batching-lite over prefill + decode.

Requests are gathered into fixed-size batches (padding short prompts),
prefilled once, then decoded by the DEVICE-RESIDENT loop in serve/decode.py:
one dispatch per batch instead of one per token, with the cache donated
through the loop.  Params are run through the offline spectral precompute
pass (serve/params.py) at construction, so no weight FFT executes inside the
decode program — the paper's offline-FFT'd weights, as a param-tree pass.

``decode_mode="per_token"`` keeps the seed per-token host loop (the baseline
`benchmarks/bench_decode.py` measures against, and the oracle the scanned
loop is tested bit-identical to).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..dist import ctx as dist_ctx
from ..launch import mesh as mesh_lib
from ..models import transformer as tfm
from ..models.registry import build_model
from . import decode as dec
from .params import precompute_serving_params


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    id: int = 0


class Engine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256, sample: bool = False, mesh=None,
                 precompute: bool = True, decode_mode: str = "scan",
                 eos_id: Optional[int] = None, temperature: float = 1.0):
        assert decode_mode in ("scan", "per_token"), decode_mode
        self.cfg = cfg
        self.params = (precompute_serving_params(params, cfg)
                       if precompute else params)
        self.model = build_model(cfg)
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sample = sample
        self.decode_mode = decode_mode
        self.eos_id = eos_id
        self.temperature = temperature
        # Largest sliding window any block uses: the ring-buffer prefill
        # keeps the window tail, so batch prompts must cover it (validated
        # per batch below instead of failing as a trace-time assert).
        self._swa_window = 0 if cfg.is_encoder_decoder else max(
            [tfm._window_for(kind, cfg)
             for pattern, _ in tfm.segments_for(cfg)
             for kind in pattern], default=0)
        # Activations are pinned through the same policy the production
        # dry-run uses; default is this host's (n, 1) data-parallel mesh.
        self.mesh = mesh if mesh is not None else mesh_lib.make_host_mesh()
        self._prefill = jax.jit(dec.make_prefill_step(cfg))
        self._decode = jax.jit(
            dec.make_decode_step(cfg, sample=sample, temperature=temperature),
            donate_argnums=(2,))
        self._loops: Dict[int, object] = {}

    def _loop_fn(self, steps: int):
        """jit'd decode loop for a step budget (cached per budget)."""
        fn = self._loops.get(steps)
        if fn is None:
            fn = jax.jit(dec.make_decode_loop(
                self.cfg, steps, sample=self.sample,
                temperature=self.temperature, eos_id=self.eos_id),
                donate_argnums=(2,))
            self._loops[steps] = fn
        return fn

    def _make_batch(self, reqs: Sequence[Request]) -> Dict:
        B = len(reqs)
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt     # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "audio_stub":
            batch["frames"] = jnp.zeros(
                (B, self.cfg.encoder_seq, self.cfg.d_model), jnp.float32)
        elif self.cfg.frontend == "vision_stub":
            batch["patches"] = jnp.zeros(
                (B, self.cfg.num_patches, self.cfg.d_model), jnp.float32)
        return batch

    def generate(self, reqs: Sequence[Request]) -> List[Dict]:
        """Serve a batch of requests; returns per-request token lists."""
        out: List[Dict] = []
        for i in range(0, len(reqs), self.max_batch):
            out.extend(self._generate_batch(reqs[i:i + self.max_batch]))
        return out

    def _generate_batch(self, reqs: Sequence[Request]) -> List[Dict]:
        with dist_ctx.activation_policy(self.mesh):
            return self._generate_batch_inner(reqs)

    def _generate_batch_inner(self, reqs: Sequence[Request]) -> List[Dict]:
        t0 = time.perf_counter()
        batch = self._make_batch(reqs)
        B, S = batch["tokens"].shape
        if S > self.max_seq:
            raise ValueError(f"prompt length {S} exceeds max_seq "
                             f"{self.max_seq}")
        # Decode step j writes cache position S+j-1 (j=1..steps-1), so the
        # cache needs S+steps-1 slots; clamp the step budget instead of
        # letting dynamic_update_slice silently clobber the last slot
        # (regression-tested in test_decode_loop.py).
        steps = max(r.max_new_tokens for r in reqs)
        steps = max(1, min(steps, self.max_seq - S + 1))
        need = min(self._swa_window, S + steps - 1)
        if self._swa_window and S < need:
            raise ValueError(
                f"batch prompt length {S} does not cover the sliding-window "
                f"ring buffer ({need}): SWA prefill keeps the window tail, "
                f"so prompts must be >= min(window, cache length)")
        cache = self.model.init_cache(B, S + steps - 1, dtype=jnp.float32)
        logits, cache = self._prefill(self.params, batch, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        jax.block_until_ready(nxt)
        t1 = time.perf_counter()

        if self.decode_mode == "per_token":
            gen = self._decode_per_token(nxt, cache, S, steps)
        else:
            lengths = jnp.asarray([min(r.max_new_tokens, steps)
                                   for r in reqs], jnp.int32)
            gen, _ = self._loop_fn(steps)(self.params, nxt, cache,
                                          jnp.int32(S), lengths)
        gen = np.asarray(gen)                          # (B, steps)
        t2 = time.perf_counter()
        prefill_s, decode_s = t1 - t0, t2 - t1

        out = []
        for i, r in enumerate(reqs):
            toks = gen[i, :min(r.max_new_tokens, steps)].tolist()
            if self.eos_id is not None and self.eos_id in toks:
                toks = toks[:toks.index(self.eos_id) + 1]
            out.append({
                "id": r.id,
                "tokens": toks,
                "decode_len": len(toks),
                "tokens_per_s": len(toks) / max(decode_s, 1e-9),
                "prefill_s": prefill_s,
                "decode_s": decode_s,
                "latency_s": prefill_s + decode_s,
            })
        return out

    def _decode_per_token(self, nxt, cache, S: int, steps: int) -> np.ndarray:
        """Seed host loop: one dispatch per token (baseline/oracle path)."""
        toks = [nxt]
        for pos in range(S, S + steps - 1):
            _, nxt, cache = self._decode(self.params, nxt[:, None], cache,
                                         jnp.int32(pos))
            toks.append(nxt)
        return np.asarray(jnp.stack(toks, 1))          # (B, steps)
