"""Serving step builders: prefill, single-token decode, and the
device-resident multi-token decode loop.

These are the functions the dry-run lowers for the ``prefill_*`` /
``decode_*`` / ``long_*`` cells, and the engine jit-calls for real serving.
The decode step donates the cache (in-place ring-buffer update — the paper's
in-place activation memory, as XLA buffer donation).  ``make_decode_loop``
wraps the step in a ``lax.while_loop`` so one dispatch decodes every token of
a batch — the host round-trip per token is what dominated the seed engine.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models.registry import Model, build_model

# -- dispatch-kind names (obs/prof.py attribution units) --------------------
# One vocabulary for what the engines dispatch, shared by the profiler's
# histogram labels, stats()["roofline"] keys, and the Chrome-trace lanes.
DECODE_CHUNK_KIND = "decode_chunk"


def prefill_kind(n_pages: int) -> str:
    """Continuous engine: one prefill program per page bucket."""
    return f"prefill_{n_pages}p"


def batch_prefill_kind(batch: int, seq: int) -> str:
    """Batch engine: prefill recompiles per (B, padded S)."""
    return f"prefill_b{batch}_s{seq}"


def batch_decode_kind(steps: int, batch: int) -> str:
    """Batch engine: one scanned decode loop per (step budget, B)."""
    return f"decode_loop_s{steps}_b{batch}"


def make_prefill_step(cfg: ArchConfig, logits_sharding=None) -> Callable:
    model = build_model(cfg)

    def prefill_step(params, batch, cache) -> Tuple[jax.Array, Any]:
        logits, new_cache = model.prefill(params, batch, cache)
        if logits_sharding is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
        # return only last-position logits: serving samples the next token
        return logits[:, -1:], new_cache
    return prefill_step


def make_decode_step(cfg: ArchConfig, sample: bool = False,
                     temperature: float = 1.0,
                     logits_sharding=None, seed: int = 0) -> Callable:
    """Single-token decode step.  ``seed`` keys the sampling PRNG (folded
    with the cache position), so sampled generations are reproducible per
    engine and distinct across engines with different seeds."""
    model = build_model(cfg)
    base_key = jax.random.PRNGKey(seed)

    def decode_step(params, tokens, cache, cache_pos):
        logits, new_cache = model.decode_step(params, tokens, cache,
                                              cache_pos)
        if logits_sharding is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
        if sample:
            key = jax.random.fold_in(base_key, cache_pos)
            nxt = jax.random.categorical(
                key, logits[:, -1].astype(jnp.float32) / temperature, -1)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        return logits, nxt.astype(jnp.int32), new_cache
    return decode_step


def make_decode_loop(cfg: ArchConfig, steps: int, *, sample: bool = False,
                     temperature: float = 1.0, eos_id: Optional[int] = None,
                     logits_sharding=None, seed: int = 0) -> Callable:
    """Device-resident multi-token decode: one dispatch for ``steps`` tokens.

    The per-token step above runs inside a ``lax.while_loop`` whose carry
    holds (step index, token buffer, current token, cache, done mask) — the
    cache is threaded through the loop and donated at the jit boundary, so
    decode stays a single in-place device program instead of ``steps``
    host-round-tripped dispatches.

    Per-request lengths are honored ON DEVICE: ``lengths[i]`` freezes request
    ``i`` after its budget (its slots hold ``eos_id``/0 and its carry token
    stops advancing); with ``eos_id`` set, a request also freezes after
    emitting EOS.  The loop exits EARLY once every request is done — with no
    EOS and uniform lengths it runs the full trip and emits bit-identical
    tokens to the per-token loop (greedy; tested per arch).

    Returns ``decode_loop(params, first_tok, cache, pos0, lengths)`` ->
    ``(tokens (B, steps) int32, cache)``; ``first_tok`` is the prefill's
    sampled token (slot 0 of the buffer), ``pos0`` the prompt length.
    """
    step = make_decode_step(cfg, sample=sample, temperature=temperature,
                            logits_sharding=logits_sharding, seed=seed)
    fill = 0 if eos_id is None else int(eos_id)

    def decode_loop(params, first_tok, cache, pos0, lengths):
        B = first_tok.shape[0]
        first = jnp.where(lengths > 0, first_tok, jnp.int32(fill))
        buf = jnp.full((B, steps), fill, jnp.int32).at[:, 0].set(first)
        done = lengths <= 1
        if eos_id is not None:
            done = done | (first_tok == eos_id)

        def cond_fn(st):
            j, _, _, _, done_ = st
            return jnp.logical_and(j < steps, ~jnp.all(done_))

        def body_fn(st):
            j, buf_, cur, cache_, done_ = st
            _, nxt, cache_ = step(params, cur[:, None], cache_, pos0 + j - 1)
            tok = jnp.where(done_, jnp.int32(fill), nxt)
            buf_ = jax.lax.dynamic_update_slice(buf_, tok[:, None], (0, j))
            nd = done_ | (j + 1 >= lengths)
            if eos_id is not None:
                nd = nd | (nxt == eos_id)
            cur = jnp.where(done_, cur, nxt)
            return (j + 1, buf_, cur, cache_, nd)

        state = (jnp.int32(1), buf, first_tok, cache, done)
        _, buf, _, cache, _ = jax.lax.while_loop(cond_fn, body_fn, state)
        return buf, cache
    return decode_loop


# ---------------------------------------------------------------------------
# Device-side numerics capture (repro.obs.health)
# ---------------------------------------------------------------------------
def logit_stats(lg):
    """``(..., V)`` logits -> ``(..., 4)`` cheap health reductions:
    ``[absmax, softmax entropy, top1-top2 margin, non-finite count]``.

    One extra pass over a logit row per step — noise next to the matmuls
    that produced it (the same budget argument as the NaN guard, which
    is the degenerate binary form of column 3).  Rows containing
    non-finite values yield non-finite absmax/entropy/margin; consumers
    (``obs/health.py``) key on column 3 and skip the rest."""
    r = lg.astype(jnp.float32)
    nonf = jnp.sum(~jnp.isfinite(r), axis=-1).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(r), axis=-1)
    m = jnp.max(r, axis=-1, keepdims=True)
    z = r - m
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=-1))
    p = jnp.exp(z - lse[..., None])
    ent = lse - jnp.sum(p * z, axis=-1)
    # top-2 margin WITHOUT lax.top_k (a full sort on CPU, ~20x the cost
    # of every other reduction here combined): mask exactly the argmax
    # position and re-max — tie semantics identical to top_k (margin 0)
    idx = jnp.argmax(r, axis=-1)
    vocab = jax.lax.broadcasted_iota(jnp.int32, r.shape, r.ndim - 1)
    r2 = jnp.where(vocab == idx[..., None], -jnp.inf, r)
    margin = m[..., 0] - jnp.max(r2, axis=-1)
    return jnp.stack([absmax, ent, margin, nonf], axis=-1)


def cache_group_absmax(cache):
    """Per-layer-group activation absmax over a dense cache's K/V leaves.

    The prefill cache is the one place every layer group's activations
    are already materialized (the paged pool only ever holds quantized
    pages), so prefill dispatches carry this fixed-shape vector out as a
    health side-output: a datapath drifting toward overflow marches up
    the ``health.act_absmax`` buckets layers before logits go non-finite."""
    out = []

    def walk(node):
        if isinstance(node, dict) and "k" in node and "v" in node:
            for key in ("k", "v"):
                leaf = node[key]
                out.append(jnp.max(jnp.abs(leaf.astype(jnp.float32)),
                                   axis=tuple(range(1, leaf.ndim))))
        elif isinstance(node, dict):
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(cache)
    if not out:
        return jnp.zeros((1,), jnp.float32)
    return jnp.concatenate([jnp.atleast_1d(a) for a in out])


# ---------------------------------------------------------------------------
# Paged continuous-batching builders (serve/kvcache.py + serve/scheduler.py)
# ---------------------------------------------------------------------------
def make_prefill_pack_step(cfg: ArchConfig, n_pages: int,
                           page_size: int,
                           capture_stats: bool = False) -> Callable:
    """B=1 exact-position prefill + page scatter, one dispatch per admission.

    The prompt is right-padded to ``n_pages * page_size`` (a page-aligned
    bucket, so a handful of page counts cover every prompt length — no
    per-length recompiles).  Padding sits AFTER the prompt: causal masking
    keeps positions < S bit-exact vs. an unpadded prefill, and the garbage
    cache tail stays masked until decode overwrites it (position validity is
    ``i <= slot position``).

    Returns ``prefill_pack(params, batch, pool, pages, true_len)`` ->
    ``(first_token scalar int32, ok scalar bool, pool, stats)`` — the first
    token is the greedy argmax at the prompt's true last position (same op
    the batch engine runs on its prefill logits); ``ok`` is a cheap
    device-side finiteness check on those logits (False = the slot is
    poisoned and the engine retires it FAILED instead of streaming
    garbage).

    With ``capture_stats`` (the obs-enabled engines) ``stats`` is ONE
    flat fixed-shape f32 vector of health reductions —
    ``[logit_stats(4) | kv_clipped | kv_total | act_absmax per layer
    group]`` — packed device-side so the host pays a single transfer per
    prefill (four small device_gets per dispatch showed up in the
    obs_overhead budget); the engine slices it and hands
    ``obs/health.py`` the pieces after the fence.  Without it ``stats``
    is None and the compiled program is byte-identical to the pre-health
    one (the disabled arm of the ``obs_overhead`` bench stays honest).
    """
    from . import kvcache as kvc
    model = build_model(cfg)
    spad = n_pages * page_size

    def prefill_pack(params, batch, pool, pages, true_len):
        cache = model.init_cache(1, spad, dtype=jnp.float32)
        logits, dense = model.prefill(params, batch, cache)
        last = jax.lax.dynamic_index_in_dim(logits[0], true_len - 1, 0,
                                            keepdims=False)
        ok = jnp.all(jnp.isfinite(last))
        nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        if capture_stats:
            pool, clipped, total = kvc.pack_prefill_cache(
                pool, dense, pages, page_size, true_len=true_len,
                with_stats=True)
            stats = jnp.concatenate([
                logit_stats(last),
                jnp.stack([jnp.asarray(clipped, jnp.float32),
                           jnp.asarray(total, jnp.float32)]),
                cache_group_absmax(dense)])
        else:
            pool = kvc.pack_prefill_cache(pool, dense, pages, page_size,
                                          true_len=true_len)
            stats = None
        return nxt, ok, pool, stats
    return prefill_pack


def make_paged_decode_loop(cfg: ArchConfig, chunk: int, *,
                           sample: bool = False, temperature: float = 1.0,
                           eos_id: Optional[int] = None, seed: int = 0,
                           logits_sharding=None,
                           paged_impl: str = "stream",
                           nan_guard: bool = True,
                           capture_stats: bool = False) -> Callable:
    """Device-resident decode over paged slots: one dispatch per ``chunk``.

    The carry holds per-slot (token, position, remaining budget, done) —
    every slot advances at ITS OWN position (RoPE + mask + page writes are
    per-slot), so slots admitted at different times decode together in one
    program.  A slot freezes when its budget hits zero or it emits
    ``eos_id``; its writes route to the trash page (position -1) and its
    buffer slots hold ``eos_id``/0.  The loop exits early once every slot
    is frozen; the scheduler retires/refills slots between dispatches.

    ``paged_impl`` selects the attention lowering inside the step:
    "stream" (default) runs the fused paged flash-decode — pool pages
    stream through online-softmax, so the loop's peak memory no longer
    carries a ``(B, maxp * page, Hkv, D)`` gathered KV view per layer;
    "gather" keeps the PR 3 materialized-view path as the parity oracle.

    With ``nan_guard`` (default) the step checks its last-position logits
    for NaN/Inf ON DEVICE (one ``isfinite`` reduce over the logit row —
    noise next to the matmuls).  A non-finite slot freezes exactly like an
    EOS slot (no token appended, position/budget stop advancing, writes
    route to the trash page) and is flagged in the returned ``anom`` mask
    so the engine retires it with status FAILED instead of streaming
    garbage tokens.

    Returns ``decode_loop(params, cur, pool, table, pos, rem)`` ->
    ``(buf (B, chunk) int32, cur, pool, pos, rem, done, anom, stats)``.

    With ``capture_stats``, ``stats`` is a ``(B, 4)`` float32 row per
    slot — ``[logit absmax, entropy, top1-margin, non-finite step count]``
    (``logit_stats`` columns).  Columns 0–2 are SAMPLED once per
    dispatch: the loop carries each slot's latest finite-step logit row
    (a masked 12 KB copy per step — noise) and the reductions run ONCE
    on it AFTER the ``while_loop``.  Computing them per step cost ~9% of
    the decode program, and hiding them behind an in-loop ``lax.cond``
    did not help (XLA rewrites small conditionals inside loops into
    both-branch selects).  Column 3 stays exact and per-step: it
    accumulates the NaN guard's ``bad`` mask, which the program computes
    every step regardless, so the ``anom`` mask remains the thresholded
    view of this column and anomalies surface on the exact dispatch they
    occur.  The carried row is gated on ``finite & ~halt``, so a
    poisoned step can never corrupt the sample.  Idle/never-advanced
    slots keep an all-zero carried row (margin +inf after reduction);
    the engine skips rows that took no step.  Without ``capture_stats``,
    ``stats`` is None and the compiled loop is unchanged.

    Telemetry contract (repro.obs): dispatch is async, so the engine
    fences the loop outputs (``jax.block_until_ready``) before stamping a
    span boundary — the ``engine.decode_chunk_s`` histogram and the
    per-chunk trace marks measure this device program, not its dispatch.
    """
    model = build_model(cfg)
    base_key = jax.random.PRNGKey(seed)
    fill = 0 if eos_id is None else int(eos_id)

    def step(params, cur, pool, pos_masked, table):
        logits, pool = model.decode_step(params, cur[:, None], pool,
                                         pos_masked, block_table=table,
                                         paged_impl=paged_impl)
        if logits_sharding is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
        finite = (jnp.all(jnp.isfinite(logits[:, -1]), axis=-1)
                  if nan_guard else jnp.ones(cur.shape[0], bool))
        lastlg = logits[:, -1] if capture_stats else None
        if sample:
            # fold in slot index AND position: slots at the same position
            # (e.g. identical prompts admitted together) must not draw from
            # identical PRNG noise
            slots = jnp.arange(cur.shape[0])
            keys = jax.vmap(lambda s, p: jax.random.fold_in(
                jax.random.fold_in(base_key, s), p))(
                slots, jnp.maximum(pos_masked, 0))
            nxt = jax.vmap(lambda k, lg: jax.random.categorical(
                k, lg.astype(jnp.float32) / temperature, -1))(
                keys, logits[:, -1])
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        return nxt.astype(jnp.int32), finite, pool, lastlg

    def decode_loop(params, cur, pool, table, pos, rem):
        B = cur.shape[0]
        done0 = rem <= 0
        anom0 = jnp.zeros(B, bool)
        buf = jnp.full((B, chunk), fill, jnp.int32)
        # carry = (latest finite-step logit row, per-step nonfinite count);
        # the reductions run once AFTER the loop (docstring)
        stats0 = ((jnp.zeros((B, cfg.vocab_size), jnp.float32),
                   jnp.zeros((B,), jnp.float32))
                  if capture_stats else None)

        def cond_fn(st):
            return jnp.logical_and(st[0] < chunk, ~jnp.all(st[6]))

        def body_fn(st):
            j, buf_, cur_, pool_, pos_, rem_, done_, anom_, stats_ = st
            masked = jnp.where(done_, -1, pos_)
            nxt, finite, pool_, lastlg = step(params, cur_, pool_, masked,
                                              table)
            # a poisoned slot freezes like EOS: no token, no advance — the
            # bad logits never pick a token and the slot retires FAILED
            bad = ~done_ & ~finite
            halt = done_ | bad
            if capture_stats:
                lastrow, nonf = stats_
                # keep the latest FINITE active row per slot (a poisoned
                # row never lands in the sample); non-finite accounting
                # is exact because ``bad`` rides the per-step NaN guard
                upd = (~halt & finite)[:, None]
                lastrow = jnp.where(upd, lastlg.astype(jnp.float32),
                                    lastrow)
                stats_ = (lastrow, nonf + bad.astype(jnp.float32))
            tok = jnp.where(halt, jnp.int32(fill), nxt)
            buf_ = jax.lax.dynamic_update_slice(buf_, tok[:, None], (0, j))
            pos_ = jnp.where(halt, pos_, pos_ + 1)
            rem_ = jnp.where(halt, rem_, rem_ - 1)
            nd = halt | (rem_ <= 0)
            if eos_id is not None:
                nd = nd | (~halt & (nxt == eos_id))
            cur_ = jnp.where(halt, cur_, nxt)
            return (j + 1, buf_, cur_, pool_, pos_, rem_, nd,
                    anom_ | bad, stats_)

        st = (jnp.int32(0), buf, cur, pool, pos, rem, done0, anom0, stats0)
        _, buf, cur, pool, pos, rem, done, anom, stats = jax.lax.while_loop(
            cond_fn, body_fn, st)
        if capture_stats:
            lastrow, nonf = stats
            stats = logit_stats(lastrow).at[:, 3].set(nonf)
        return buf, cur, pool, pos, rem, done, anom, stats
    return decode_loop
