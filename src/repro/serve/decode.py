"""Serving step builders: prefill and single-token decode.

These are the functions the dry-run lowers for the ``prefill_*`` /
``decode_*`` / ``long_*`` cells, and the engine jit-calls for real serving.
The decode step donates the cache (in-place ring-buffer update — the paper's
in-place activation memory, as XLA buffer donation).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models.registry import Model, build_model


def make_prefill_step(cfg: ArchConfig, logits_sharding=None) -> Callable:
    model = build_model(cfg)

    def prefill_step(params, batch, cache) -> Tuple[jax.Array, Any]:
        logits, new_cache = model.prefill(params, batch, cache)
        if logits_sharding is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
        # return only last-position logits: serving samples the next token
        return logits[:, -1:], new_cache
    return prefill_step


def make_decode_step(cfg: ArchConfig, sample: bool = False,
                     temperature: float = 1.0,
                     logits_sharding=None) -> Callable:
    model = build_model(cfg)

    def decode_step(params, tokens, cache, cache_pos):
        logits, new_cache = model.decode_step(params, tokens, cache,
                                              cache_pos)
        if logits_sharding is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
        if sample:
            key = jax.random.fold_in(jax.random.PRNGKey(17), cache_pos)
            nxt = jax.random.categorical(
                key, logits[:, -1].astype(jnp.float32) / temperature, -1)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        return logits, nxt.astype(jnp.int32), new_cache
    return decode_step
