"""Training step: loss, gradient accumulation, NaN guard, optimizer update.

``make_train_step(cfg, ...)`` returns a pure ``(state, batch) -> (state,
metrics)`` function ready for jit with donated state.  Design points for the
1000+-node posture (DESIGN.md §7):

* microbatch gradient accumulation via ``lax.scan`` — under SPMD the
  per-microbatch backward's gradient reduce-scatter overlaps the next
  microbatch's compute (XLA latency-hiding scheduler);
* optional int8 error-feedback gradient compression before the update
  (wire-format on the cross-pod axis — optim/grad_compression.py);
* non-finite-gradient guard: a bad step (hardware flake, loss spike)
  SKIPS the update instead of poisoning the weights, and is counted in
  ``state["skipped"]`` for the trainer's telemetry;
* Bayesian (variational-inference) mode per the paper: sample weights via
  reparameterization, add KL/num_examples to the loss (core/bayesian.py).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import bayesian
from ..models.registry import Model, build_model
from ..optim import adamw, grad_compression, schedule


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  zloss: float = 0.0) -> jax.Array:
    """Mean token NLL in f32 (+ z-loss on the partition function).

    Sharding-friendly by construction: the label log-prob is a one-hot
    contraction (reduces over the vocab axis WITHOUT gathering it — under a
    vocab-sharded TP layout this is a partial sum + tiny all-reduce), never
    a take_along_axis gather (which GSPMD can only serve by all-gathering
    the full (B,S,V) f32 logits — measured at +443 GB/step on the
    tinyllama dry-run before this fix; see EXPERIMENTS.md §Perf).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    ll = jnp.sum(logits * onehot, axis=-1)
    nll = (lse - ll).mean()
    if zloss:
        nll = nll + zloss * jnp.square(lse).mean()
    return nll


def make_loss_fn(cfg: ArchConfig, model: Optional[Model] = None,
                 moe_aux_coef: float = 0.01,
                 logits_sharding=None) -> Callable:
    model = model or build_model(cfg)

    def loss_fn(params, batch):
        logits, aux = model.forward_train(params, batch)
        if logits_sharding is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
        nll = cross_entropy(logits, batch["labels"], cfg.zloss)
        loss = nll + moe_aux_coef * aux.get("moe_aux", 0.0)
        return loss, {"loss": loss, "nll": nll,
                      "moe_aux": aux.get("moe_aux", jnp.zeros(()))}
    return loss_fn


def init_state(key, cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
               compress_grads: bool = False,
               bayesian_mode: bool = False) -> Dict:
    model = build_model(cfg)
    params = model.init(key)
    if bayesian_mode:
        params = bayesian.init_bayesian(params)
    state = {
        "params": params,
        "opt": adamw.init(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
        "skipped": jnp.zeros((), jnp.int32),
    }
    if compress_grads:
        state["ef"] = grad_compression.init_error_feedback(params)
    return state


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    *, accum: int = 1, moe_aux_coef: float = 0.01,
                    lr_schedule: Optional[Callable] = None,
                    compress_grads: bool = False,
                    bayesian_mode: bool = False,
                    num_examples: int = 1_000_000,
                    logits_sharding=None) -> Callable:
    model = build_model(cfg)
    base_loss = make_loss_fn(cfg, model, moe_aux_coef, logits_sharding)

    if bayesian_mode:
        def loss_fn(bparams, batch, step):
            key = jax.random.fold_in(jax.random.PRNGKey(0), step)
            w, kl = bayesian.sample(key, bparams), bayesian.kl_to_prior(bparams)
            loss, metrics = base_loss(w, batch)
            loss = loss + kl / num_examples
            metrics = dict(metrics, kl=kl, loss=loss)
            return loss, metrics
    else:
        def loss_fn(params, batch, step):
            return base_loss(params, batch)

    def grads_of(params, batch, step):
        if accum <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch, step)

        def micro(carry, mb):
            (g_acc, m_acc) = carry
            (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb, step)
            return (jax.tree.map(jnp.add, g_acc, g),
                    jax.tree.map(jnp.add, m_acc, m)), None

        mbs = jax.tree.map(
            lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
            batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = {"loss": jnp.zeros(()), "nll": jnp.zeros(()),
              "moe_aux": jnp.zeros(())}
        if bayesian_mode:
            m0["kl"] = jnp.zeros(())
        (g, m), _ = jax.lax.scan(micro, (g0, m0), mbs)
        scale = 1.0 / accum
        return ((m["loss"] * scale, jax.tree.map(lambda x: x * scale, m)),
                jax.tree.map(lambda x: x * scale, g))

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params = state["params"]
        (loss, metrics), grads = grads_of(params, batch, state["step"])

        if compress_grads:
            grads, new_ef = grad_compression.compress_decompress(
                grads, state["ef"])

        gnorm = adamw.global_norm(grads)
        ok = jnp.isfinite(gnorm) & jnp.isfinite(loss)
        lr = (lr_schedule(state["step"]) if lr_schedule is not None
              else opt_cfg.lr)
        new_params, new_opt = adamw.update(grads, state["opt"], params,
                                           opt_cfg, lr)
        # NaN/inf guard: keep old params & opt state on a bad step
        new_params = jax.tree.map(
            lambda n, o: jnp.where(ok, n, o), new_params, params)
        new_opt = jax.tree.map(
            lambda n, o: jnp.where(ok, n, o), new_opt, state["opt"])

        new_state = dict(state, params=new_params, opt=new_opt,
                         step=state["step"] + 1,
                         skipped=state["skipped"] + (1 - ok.astype(jnp.int32)))
        if compress_grads:
            new_state["ef"] = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_ef, state["ef"])
        metrics = dict(metrics, grad_norm=gnorm, lr=jnp.asarray(lr),
                       ok=ok.astype(jnp.int32))
        return new_state, metrics

    return train_step
