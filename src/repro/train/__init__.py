from . import checkpoint, train_step, trainer  # noqa: F401
