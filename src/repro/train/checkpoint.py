"""Atomic, mesh-elastic, resumable checkpoints.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json (tree structure, shapes,
dtypes, integrity hashes).  Writes go to a temp dir then ``os.replace`` —
a preempted write can never corrupt the latest checkpoint (fault tolerance,
DESIGN.md §7).  Arrays are saved as LOGICAL (fully-addressable) values, so a
restore may reshard onto ANY mesh — elastic scaling across pod counts.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}, treedef


def save(ckpt_dir: str, step: int, state: Any, keep: int = 3) -> str:
    """Atomically persist ``state`` for ``step``; prune old checkpoints."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    arrays, treedef = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "arrays.npz"), "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "sha256": digest,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish

    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def latest_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (mesh-elastic: pass target
    ``shardings`` to place each leaf on the CURRENT mesh).  Returns
    (state, step); raises FileNotFoundError when no checkpoint exists."""
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step = steps[-1] if step is None else step
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(path, "arrays.npz"), "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    if digest != manifest["sha256"]:
        raise IOError(f"checkpoint {path} fails integrity check")

    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(data.files), \
        f"checkpoint has {len(data.files)} leaves, model needs {len(leaves)}"
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves))
    restored = []
    for i, (l, s) in enumerate(zip(leaves, shard_leaves)):
        arr = data[f"a{i}"]
        assert tuple(arr.shape) == tuple(l.shape), \
            f"leaf {i}: ckpt {arr.shape} vs model {l.shape}"
        restored.append(jax.device_put(arr, s) if s is not None
                        else jnp.asarray(arr))
    return jax.tree.unflatten(treedef, restored), step
