"""Fault-tolerant training loop.

Production posture (DESIGN.md §7), exercised at host scale by the examples:

* auto-resume from the newest intact checkpoint (atomic writes mean a
  preemption mid-save can't corrupt it);
* periodic atomic checkpoints + terminal-signal checkpoint (preemption);
* NaN/inf steps are SKIPPED inside the jit'd step (train_step.py) and
  surfaced here as telemetry;
* heartbeat file per host — a watchdog (or test) detects stragglers /
  hangs by heartbeat age, the restart path is just "run the same command";
* deterministic step-indexed data: no pipeline state to restore, stragglers
  never desynchronize the batch contents.

Telemetry rides the same ``repro.obs`` plane the serving stack uses: pass
``obs=`` (or let the trainer build one) and every step lands step-time /
loss / grad-norm / tokens-per-second in the shared registry — with an
emitter attached the snapshots stream to JSONL on the usual cadence.  The
heartbeat file keeps its own format (the watchdog contract predates obs).
"""
from __future__ import annotations

import json
import os
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from . import checkpoint as ckpt
from . import train_step as ts
from ..obs import Obs
from ..optim import adamw, schedule


class Trainer:
    def __init__(self, cfg, opt_cfg: Optional[adamw.AdamWConfig] = None, *,
                 workdir: str = "/tmp/repro_run", data_fn: Callable,
                 total_steps: int = 100, ckpt_every: int = 50,
                 accum: int = 1, log_every: int = 10,
                 compress_grads: bool = False, bayesian_mode: bool = False,
                 heartbeat_timeout: float = 600.0, lr_schedule=None,
                 obs: Optional[Obs] = None):
        self.cfg = cfg
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        self.workdir = workdir
        self.data_fn = data_fn
        self.total_steps = total_steps
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.heartbeat_timeout = heartbeat_timeout
        self.obs = obs if obs is not None else Obs()
        reg = self.obs.registry
        self._c_steps = reg.counter("train.steps")
        self._c_tokens = reg.counter("train.tokens")
        self._c_skipped = reg.counter("train.skipped_steps")
        self._h_step = reg.histogram("train.step_s")
        self._g_loss = reg.gauge("train.loss")
        self._g_gnorm = reg.gauge("train.grad_norm")
        self._g_tps = reg.gauge("train.tokens_per_s")
        os.makedirs(workdir, exist_ok=True)
        lr_fn = lr_schedule or (
            lambda step: schedule.warmup_cosine(
                step, peak_lr=self.opt_cfg.lr,
                warmup_steps=max(total_steps // 20, 1),
                total_steps=total_steps))
        self.step_fn = jax.jit(
            ts.make_train_step(cfg, self.opt_cfg, accum=accum,
                               lr_schedule=lr_fn,
                               compress_grads=compress_grads,
                               bayesian_mode=bayesian_mode),
            donate_argnums=(0,))
        self._state = None
        self._preempted = False
        self.compress_grads = compress_grads
        self.bayesian_mode = bayesian_mode
        self.history: list = []

    # -- fault-tolerance plumbing ------------------------------------------
    def _heartbeat(self, step: int):
        # "time" (wall clock) is the absolute for-humans field; age deltas
        # use "mono" — perf_counter is CLOCK_MONOTONIC on Linux, so it is
        # comparable across processes on one host (the heartbeat-file
        # scope) and immune to NTP steps that would skew a wall-clock
        # difference into a false straggler alarm
        hb = {"step": step, "time": time.time(),
              "mono": time.perf_counter(), "host": jax.process_index()}
        with open(os.path.join(self.workdir, "heartbeat.json"), "w") as f:
            json.dump(hb, f)

    @staticmethod
    def heartbeat_age(workdir: str) -> float:
        """Straggler/hang detection: seconds since last heartbeat."""
        path = os.path.join(workdir, "heartbeat.json")
        if not os.path.exists(path):
            return float("inf")
        with open(path) as f:
            hb = json.load(f)
        if "mono" in hb:                    # same-boot monotonic delta
            return time.perf_counter() - hb["mono"]
        return time.time() - hb["time"]     # legacy wall-clock heartbeat

    def _install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True          # checkpoint at next step boundary
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass                            # non-main thread (tests)

    # -- the loop -----------------------------------------------------------
    def init_or_restore(self, key=None) -> Dict:
        key = key if key is not None else jax.random.PRNGKey(0)
        state = ts.init_state(key, self.cfg, self.opt_cfg,
                              compress_grads=self.compress_grads,
                              bayesian_mode=self.bayesian_mode)
        try:
            state, step = ckpt.restore(
                os.path.join(self.workdir, "ckpt"), state)
            print(f"[trainer] resumed from step {step}", flush=True)
        except FileNotFoundError:
            pass
        self._state = state
        return state

    def run(self) -> Dict:
        self._install_preemption_handler()
        if self._state is None:
            self.init_or_restore()
        state = self._state
        start = int(state["step"])
        ckpt_dir = os.path.join(self.workdir, "ckpt")
        skipped0 = int(state["skipped"])
        for step in range(start, self.total_steps):
            t0 = time.perf_counter()
            batch = self.data_fn(step)
            state, metrics = self.step_fn(state, batch)
            ntok = int(batch["tokens"].size)
            self._c_steps.inc()
            self._c_tokens.inc(ntok)
            if self.obs.enabled:
                # fence so step_s measures device work, not dispatch
                # latency; with obs disabled steps stay async-pipelined
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self._h_step.observe(dt)
                self._g_loss.set(float(metrics["loss"]))
                self._g_gnorm.set(float(metrics["grad_norm"]))
                self._g_tps.set(ntok / max(dt, 1e-9))
                skipped = int(state["skipped"])
                if skipped > skipped0:
                    self._c_skipped.inc(skipped - skipped0)
                    skipped0 = skipped
            if (step + 1) % self.log_every == 0 or step == start:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step + 1
                self.history.append(m)
                print(f"[trainer] step {step+1} "
                      f"loss={m['loss']:.4f} gnorm={m['grad_norm']:.3f} "
                      f"skipped={int(state['skipped'])}", flush=True)
            self._heartbeat(step + 1)
            self.obs.tick()                # emitter rides the step cadence
            if (step + 1) % self.ckpt_every == 0 or self._preempted:
                ckpt.save(ckpt_dir, step + 1, state)
                if self._preempted:
                    print("[trainer] preemption checkpoint saved; exiting",
                          flush=True)
                    break
        ckpt.save(ckpt_dir, int(state["step"]), state)
        self._state = state
        return state
