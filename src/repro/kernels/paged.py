"""Pallas TPU kernel: paged KV gather (block table -> contiguous KV view).

The continuous-batching engine stores KV state as fixed-size pages in a
shared pool (``serve/kvcache.py``); decode needs each slot's pages laid out
contiguously for attention.  On TPU the block table rides scalar prefetch
(``PrefetchScalarGridSpec``), so the page id is known before the grid step
runs and the pool page is DMA'd straight into the output block — one page
per grid step, no gather materialization in HBM beyond the output itself.

This mirrors the paper's hierarchical control: the block table is the
"control plane" (tiny, scalar memory), the pool is the "data plane"
(weights-sized, streamed) — the same split the FPGA controller uses between
its instruction BRAM and the data buffers.

Call through ``kernels.ops.paged_gather`` — the REPRO_KERNELS dispatch
('interpret'/'tpu'/'off') lives there; 'off' lowers the same gather as
plain XLA ``pool[table]`` indexing (see ops).

LEGACY / ORACLE PATH: the decode hot loop now streams pages through the
fused paged flash-decode (``kernels/paged_attention.py``) and never forms
this gathered view; the gather survives as the parity oracle
(``ContinuousEngine(paged_attn="gather")``, ``tests/test_paged_attention``)
and for tooling that genuinely needs a contiguous KV copy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(table_ref, pool_ref, out_ref):
    out_ref[...] = pool_ref[...].reshape(out_ref.shape)


def paged_gather_kernel(pool: jax.Array, table: jax.Array,
                        interpret: bool = False) -> jax.Array:
    """pool: (P, page, H, D); table: (B, maxp) int32 page ids.

    Returns (B, maxp * page, H, D): slot b's pages concatenated in table
    order (position ``i`` of slot b lives at page ``table[b, i // page]``,
    offset ``i % page``).
    """
    P, page, H, D = pool.shape
    B, maxp = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, maxp),
        in_specs=[
            pl.BlockSpec((1, page, H, D),
                         lambda b, p, tref: (tref[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, page, H, D),
                               lambda b, p, tref: (b, p, 0, 0, 0)),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, maxp, page, H, D), pool.dtype),
        interpret=interpret,
    )(table, pool)
    return out.reshape(B, maxp * page, H, D)
