"""Pure-jnp oracles for every Pallas kernel.  Tests assert_allclose against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Spectral block matmul oracle:  Y[f,b,p] = sum_q X[f,b,q] * W[f,q,p]  (complex)
# ---------------------------------------------------------------------------
def spectral_matmul_ref(xr, xi, wr, wi):
    """Inputs laid out (F, B, Q) and (F, Q, P); complex contraction over Q."""
    yr = jnp.einsum("fbq,fqp->fbp", xr, wr) - jnp.einsum("fbq,fqp->fbp", xi, wi)
    yi = jnp.einsum("fbq,fqp->fbp", xr, wi) + jnp.einsum("fbq,fqp->fbp", xi, wr)
    return yr, yi


# ---------------------------------------------------------------------------
# Attention oracle: full-materialization softmax attention with all the mask
# variants the models need (causal, sliding window, softcap, GQA).
# ---------------------------------------------------------------------------
def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                  scale=None, kv_offset=0):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D).  kv_offset: absolute position
    of q[0] minus position of k[0] (for decode: Skv - Sq)."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   kk.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    rows = jnp.arange(Sq)[:, None] + kv_offset
    cols = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask &= cols <= rows
    if window:
        mask &= cols > rows - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv)
    return out.astype(q.dtype)
