"""Pallas TPU kernel: frequency-domain block-circulant matmul (the paper's
"spectral element-wise MAC" phase, re-cast for the MXU).

Per frequency bin ``f`` the decoupled computation is a dense complex matmul
``Y[f] = X[f] @ W[f]`` with ``X (B, Q)``, ``W (Q, P)`` — the contraction runs
over the *input block index* q.  The FPGA implementation did this with scalar
MAC pipelines; on TPU we batch the bins on the grid and feed each one to the
MXU as real matmuls using Gauss's 3-multiplication complex product:

    t1 = (Xr + Xi) @ Wr          t2 = Xr @ (Wi - Wr)         t3 = Xi @ (Wr + Wi)
    Yr = t1 - t3                 Yi = t1 + t2

The weight-side combinations (Wi-Wr, Wr+Wi) are precomputed offline together
with the weight rfft (paper: weights FFT'd before inference), so runtime cost
is 3 MXU matmuls per bin instead of 4.

VMEM budget per grid step (f32): bB·Q + 3·Q·bP + 2·bB·bP.  With the default
bB=bP=128 and Q ≤ 512 this is < 1.5 MiB — deep pipelining across the grid
(the paper's phase-2 pipeline) is handled by the Pallas double-buffered DMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xr_ref, xi_ref, wr_ref, ws1_ref, ws2_ref, yr_ref, yi_ref):
    xr = xr_ref[0]                                   # (bB, Q)
    xi = xi_ref[0]
    wr = wr_ref[0]                                   # (Q, bP)
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    t1 = dot(xr + xi, wr)
    t2 = dot(xr, ws1_ref[0])
    t3 = dot(xi, ws2_ref[0])
    yr_ref[0] = (t1 - t3).astype(yr_ref.dtype)
    yi_ref[0] = (t1 + t2).astype(yi_ref.dtype)


def spectral_matmul(xr, xi, wr, ws1, ws2, *, block_b: int = 128,
                    block_p: int = 128, interpret: bool = True):
    """Y = X·W in the frequency domain, real planes.

    xr/xi: (F, B, Q);  wr/ws1/ws2: (F, Q, P)  ->  yr/yi: (F, B, P)
    F = number of retained rfft bins (k//2+1), padded by the caller if needed.
    """
    F, B, Q = xr.shape
    P = wr.shape[-1]
    bB, bP = min(block_b, B), min(block_p, P)
    grid = (F, -(-B // bB), -(-P // bP))
    x_spec = pl.BlockSpec((1, bB, Q), lambda f, ib, jp: (f, ib, 0))
    w_spec = pl.BlockSpec((1, Q, bP), lambda f, ib, jp: (f, 0, jp))
    y_spec = pl.BlockSpec((1, bB, bP), lambda f, ib, jp: (f, ib, jp))
    out_shape = [jax.ShapeDtypeStruct((F, B, P), xr.dtype)] * 2
    yr, yi = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec, w_spec],
        out_specs=[y_spec, y_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, wr, ws1, ws2)
    return yr, yi
