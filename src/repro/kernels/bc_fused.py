"""Pallas TPU kernel: the paper's whole three-phase pipeline in one kernel.

Phase 1 (FFT), phase 2 (spectral element-wise MAC), phase 3 (IFFT) — the
FPGA time-multiplexes one butterfly block across the phases; the TPU
version keeps the (k × kf) DFT matrices and the spectral weight planes
VMEM-resident and runs all three phases as MXU dots per grid step, so the
intermediate spectra never touch HBM (the paper's on-chip dataflow).

    xb (B, q, k)  --Cr/Ci-->  Xr/Xi (B, q, kf)
    Gauss 3-mult MAC over q against wr/ws1/ws2 (p, q, kf)
    Yr/Yi (B, p, kf)  --Dr/Di-->  y (B, p, k)

Grid: (B/bB, p/bP); weight tiles re-read per batch tile (they are k×
compressed, so the re-read traffic is what the paper's compression already
paid for).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import circulant as cc


def _kernel(x_ref, wr_ref, ws1_ref, ws2_ref, cr_ref, ci_ref, dr_ref, di_ref,
            y_ref):
    bB, q, k = x_ref.shape
    kf = cr_ref.shape[1]
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    x2 = x_ref[...].reshape(bB * q, k)
    xr = dot(x2, cr_ref[...]).reshape(bB, q, kf)          # phase 1: DFT
    xi = dot(x2, ci_ref[...]).reshape(bB, q, kf)
    t1 = jnp.einsum("bqf,pqf->bpf", xr + xi, wr_ref[...],
                    preferred_element_type=jnp.float32)   # phase 2: MAC
    t2 = jnp.einsum("bqf,pqf->bpf", xr, ws1_ref[...],
                    preferred_element_type=jnp.float32)
    t3 = jnp.einsum("bqf,pqf->bpf", xi, ws2_ref[...],
                    preferred_element_type=jnp.float32)
    yr = (t1 - t3).reshape(-1, kf)
    yi = (t1 + t2).reshape(-1, kf)
    y = dot(yr, dr_ref[...]) + dot(yi, di_ref[...])       # phase 3: iDFT
    y_ref[...] = y.reshape(*y_ref.shape).astype(y_ref.dtype)


def bc_fused_matmul(xb: jax.Array, wr, ws1, ws2, *, k: int,
                    block_b: int = 128, block_p: int = 8,
                    interpret: bool = False) -> jax.Array:
    """xb: (B, q, k) blockified input; w planes: (p, q, kf).  -> (B, p, k)."""
    B, q, _ = xb.shape
    p, _, kf = wr.shape
    bB, bP = min(block_b, B), min(block_p, p)
    Cr, Ci, Dr, Di = (jnp.asarray(m) for m in cc.dft_mats(k))
    grid = (-(-B // bB), -(-p // bP))
    x_spec = pl.BlockSpec((bB, q, k), lambda ib, ip: (ib, 0, 0))
    w_spec = pl.BlockSpec((bP, q, kf), lambda ib, ip: (ip, 0, 0))
    c_spec = pl.BlockSpec((k, kf), lambda ib, ip: (0, 0))
    d_spec = pl.BlockSpec((kf, k), lambda ib, ip: (0, 0))
    y_spec = pl.BlockSpec((bB, bP, k), lambda ib, ip: (ib, ip, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[x_spec, w_spec, w_spec, w_spec, c_spec, c_spec, d_spec,
                  d_spec],
        out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((B, p, k), xb.dtype),
        interpret=interpret,
    )(xb, wr, ws1, ws2, Cr, Ci, Dr, Di)


def bc_linear_fused_kernel(x: jax.Array, w: jax.Array, n_out: int,
                           interpret: bool = False, block_b: int = 128,
                           block_p: int = 8) -> jax.Array:
    """Drop-in for bc_matmul_spectral using the fused kernel.

    x: (..., n_in); w: (p, q, k) first-row generators.  Call through
    ``kernels.ops.bc_linear_fused`` — the REPRO_KERNELS dispatch policy
    ('interpret'/'tpu'/'off') lives there, like the other two kernels;
    direct callers must pass ``interpret`` explicitly (compiled Pallas is
    the default, matching a real TPU target)."""
    p, q, k = w.shape
    lead = x.shape[:-1]
    xb = cc._blockify(x, q, k).reshape(-1, q, k).astype(jnp.float32)
    cache = cc.spectral_cache(w)
    y = bc_fused_matmul(xb, cache["wr"], cache["ws1"], cache["ws2"], k=k,
                        block_b=block_b, block_p=block_p, interpret=interpret)
    y = y.reshape(*lead, p * k)[..., :n_out]
    return y.astype(x.dtype)
