"""Fused paged flash-decode attention: stream pool pages through the
online-softmax recurrence instead of materializing the gathered KV view.

The PR 3 paged decode path paid O(max_seq) HBM traffic *twice* per emitted
token: ``paged_gather`` wrote a dense ``(B, maxp * page, Hkv, D)`` copy of
every live slot's whole KV history, then dense attention read it back.  The
paper's hardware chapter wins by never letting the hot loop touch more
memory than it must ("effective reconfiguration, batch processing, deep
pipelining, resource re-using"); this kernel applies the same discipline to
paged decode: each slot's pages stream one at a time through the classic
flash m/l/acc carry, so the gathered view is never formed — per-token
attention traffic drops to one read of the live positions with an O(page)
working set.

Masking reproduces the gather path exactly: a kv position ``i`` of slot
``b`` is valid iff ``i <= positions[b]`` — that single predicate covers
trash-page-0 reads (unowned table entries only appear beyond the length),
the partially-filled last page, and idle slots (``positions == -1`` masks
everything, so the output is exactly zero, as the gather path produced).

Two lowerings, dispatched by ``kernels.ops.paged_attention``:

* ``paged_attention_stream`` — pure XLA: a live-length-bounded
  ``lax.while_loop`` over page-sized KV chunks (one tiny per-chunk gather
  each step; serving-only — a while loop is not reverse-differentiable).
  Same memory win under XLA alone; this is what ``REPRO_KERNELS=off`` (the
  default, and the 512-chip dry-run) lowers.
* ``paged_attention_kernel`` — Pallas: the block table and per-slot
  positions ride scalar prefetch (``PrefetchScalarGridSpec``), so each
  grid step DMAs exactly one pool page straight into VMEM next to the
  running softmax state — the paper's hierarchical-control split with the
  data plane never leaving on-chip memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30

# Pages streamed per 'off'-scan step (the streamed working set is
# B * BLOCK_PAGES * page positions; serve/kvcache.attention_memory_est
# accounts the same factor in its peak estimate).
BLOCK_PAGES = 4


# ---------------------------------------------------------------------------
# Pure-XLA streamed lowering ('off' dispatch)
# ---------------------------------------------------------------------------
def paged_attention_stream(q, pool_k, pool_v, table, positions, *,
                           scale=None, softcap: float = 0.0,
                           block_pages: int = BLOCK_PAGES,
                           k_scale=None, v_scale=None) -> jax.Array:
    """q: (B, Hq, D); pool: (P, page, Hkv, D); table: (B, maxp) int32 page
    ids; positions: (B,) int32 per-slot absolute position of the decode
    token (-1 = idle slot, fully masked).  Returns (B, Hq, D) in q.dtype.

    ``k_scale``/``v_scale`` (both (P, Hkv) f32, or both None) enable the
    quantized lane: the pool leaves are int8 and each streamed page chunk
    is dequantized IN-REGISTER right next to the m/l/acc carry — HBM
    traffic stays int8 bytes, the softmax recurrence stays f32.

    The streaming loop is a ``lax.while_loop`` bounded by the LIVE page
    count (``max(positions) + 1`` over the batch), not the table width: a
    fully-masked page updates nothing (p == 0 everywhere, m/l/acc carry
    through bit-exact), so skipping the reservation tail beyond the longest
    live slot changes no result — per-token traffic is O(seq_len), not
    O(max_seq).  ``block_pages`` pages stream per step: enough MXU/AVX work
    per iteration to amortize loop overhead, still an O(page) working set.
    """
    _, page, Hkv, D = pool_k.shape
    B, maxp = table.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qh = q.reshape(B, Hkv, G, D).astype(jnp.float32) * scale

    bp = min(block_pages, maxp)
    n_blocks = -(-maxp // bp)                    # static bound
    if maxp % bp:                                # pad tables to block width
        table = jnp.pad(table, ((0, 0), (0, n_blocks * bp - maxp)))
    # live extent: blocks holding any position <= max(positions)
    n_live = jnp.maximum(jnp.max(positions), -1) + 1
    live_blocks = jnp.minimum((n_live + bp * page - 1) // (bp * page),
                              n_blocks)

    m0 = jnp.full((B, Hkv, G), _NEG, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, D), jnp.float32)

    def body(st):
        j, m_p, l_p, acc = st
        pids = jax.lax.dynamic_slice_in_dim(table, j * bp, bp, 1)  # (B, bp)
        kc = pool_k[pids].astype(jnp.float32)    # (B, bp, page, Hkv, D)
        vc = pool_v[pids].astype(jnp.float32)
        if k_scale is not None:                  # int8 lane: dequantize the
            kc = kc * k_scale[pids][:, :, None, :, None]   # chunk in-register
            vc = vc * v_scale[pids][:, :, None, :, None]
        kc = kc.reshape(B, bp * page, Hkv, D)
        vc = vc.reshape(B, bp * page, Hkv, D)
        s = jnp.einsum("bhgd,bkhd->bhgk", qh, kc)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        cols = j * bp * page + jnp.arange(bp * page)
        msk = (cols[None, :] <= positions[:, None])[:, None, None, :]
        s = jnp.where(msk, s, _NEG)
        m_n = jnp.maximum(m_p, s.max(-1))
        p = jnp.exp(s - m_n[..., None])
        p = jnp.where(msk, p, 0.0)               # fully-masked-page guard
        alpha = jnp.exp(m_p - m_n)
        l_n = l_p * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgk,bkhd->bhgd", p, vc)
        return (j + 1, m_n, l_n, acc)

    _, _, l_f, acc = jax.lax.while_loop(
        lambda st: st[0] < live_blocks, body,
        (jnp.int32(0), m0, l0, a0))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.reshape(B, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Pallas kernel ('interpret' / 'tpu' dispatch)
# ---------------------------------------------------------------------------
def _pa_kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, *refs,
               scale, softcap, page, maxp, quantized):
    if quantized:                                # int8 lane: per-(page, head)
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = refs   # scales ride
    else:                                        # tiny (1, 1) VMEM blocks
        o_ref, m_scr, l_scr, acc_scr = refs
    b = pl.program_id(0)
    jp = pl.program_id(2)                        # sequential page dim

    @pl.when(jp == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Pages past the slot's live extent are fully masked and contribute
    # nothing to the carry — skip their softmax update entirely (the grid
    # itself is static at maxp: dead table entries all index the single
    # trash page, so their DMA re-reads one hot page, not the pool).
    @pl.when(jp * page <= pos_ref[b])
    def _update():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)                 # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:                            # dequantize in VMEM, right
            k = k * ks_ref[0, 0]                 # next to the m/l/acc carry
            v = v * vs_ref[0, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (G, page)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)

        cols = jp * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols <= pos_ref[b]                # pos -1 masks everything
        s = jnp.where(mask, s, _NEG)

        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_scr[:, 0] * alpha + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[:, 0] = m_new
        l_scr[:, 0] = l_new

    @pl.when(jp == maxp - 1)
    def _finalize():
        l = jnp.maximum(l_scr[:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_attention_kernel(q, pool_k, pool_v, table, positions, *,
                           scale=None, softcap: float = 0.0,
                           interpret: bool = False,
                           k_scale=None, v_scale=None) -> jax.Array:
    """Same contract as ``paged_attention_stream``; grid (B, Hkv, maxp) with
    the page dim sequential, block table + positions scalar-prefetched so
    the page id is known before each step's pool DMA issues.  With
    ``k_scale``/``v_scale`` ((P, Hkv) f32) the pool is int8: each step's
    page DMA moves int8 bytes and the (1, 1) scale block for that
    (page, head) rides along, dequantizing in VMEM."""
    _, page, Hkv, D = pool_k.shape
    B, maxp = table.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qh = q.reshape(B, Hkv, G, D)
    quantized = k_scale is not None

    pool_spec = pl.BlockSpec((1, page, 1, D),
                             lambda b, h, jp, tref, pref: (tref[b, jp], 0, h, 0))
    in_specs = [
        pl.BlockSpec((1, 1, G, D),
                     lambda b, h, jp, tref, pref: (b, h, 0, 0)),
        pool_spec,
        pool_spec,
    ]
    operands = [qh, pool_k, pool_v]
    if quantized:
        scale_spec = pl.BlockSpec(
            (1, 1), lambda b, h, jp, tref, pref: (tref[b, jp], h))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # (table, positions)
        grid=(B, Hkv, maxp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, jp, tref, pref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),     # running max
            pltpu.VMEM((G, 1), jnp.float32),     # running sum
            pltpu.VMEM((G, D), jnp.float32),     # output accumulator
        ],
    )
    kern = functools.partial(_pa_kernel, scale=scale, softcap=softcap,
                             page=page, maxp=maxp, quantized=quantized)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(table, positions, *operands)
    return out.reshape(B, Hq, D)
