# Pallas TPU kernels for the paper's compute hot-spots:
#   spectral_matmul — the frequency-domain block-circulant MAC phase (MXU)
#   flash_attention — online-softmax attention (causal/window/softcap/GQA)
#   bc_fused        — the whole FFT -> MAC -> IFFT pipeline in one kernel
# ops.py holds the jit'd dispatch wrappers; ref.py the pure-jnp oracles.
from . import bc_fused, ops, ref  # noqa: F401
