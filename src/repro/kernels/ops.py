"""jit'd public wrappers around the Pallas kernels with XLA fallbacks.

Kernel dispatch policy (``REPRO_KERNELS`` env var or explicit argument):
  'interpret' — run the Pallas kernel bodies in interpret mode (CPU-correct;
                what tests use to validate the TPU kernels).
  'tpu'       — compiled Pallas (real TPU target).
  'off'       — pure-XLA lowering (what the 512-device dry-run uses: the
                einsum/chunked-scan forms lower to the same collectives and
                FLOPs the roofline needs, without paying interpret-mode cost).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from ..core import circulant as _cc
from . import bc_fused as _bcf
from . import flash_attention as _fa
from . import paged as _paged
from . import paged_attention as _pa
from . import ref as _ref
from . import spectral_matmul as _sm


def kernel_mode() -> str:
    return os.environ.get("REPRO_KERNELS", "off")


# ---------------------------------------------------------------------------
def spectral_matmul(xr, xi, wr, ws1, ws2, mode: str | None = None):
    """(F,B,Q) x (F,Q,P) complex contraction via real planes + Gauss trick."""
    mode = mode or kernel_mode()
    if mode == "off":
        wi = ws1 + wr           # recover plain planes for the einsum fallback
        return _ref.spectral_matmul_ref(xr, xi, wr, wi)
    return _sm.spectral_matmul(xr, xi, wr, ws1, ws2,
                               interpret=(mode == "interpret"))


def bc_linear_fused(x, w, n_out: int, mode: str | None = None, **block_kw):
    """Whole three-phase block-circulant linear (DFT -> spectral MAC -> iDFT)
    as one fused kernel; 'off' lowers the same math through the XLA
    cached-spectral path (bit-equal contraction, separate HLO ops)."""
    mode = mode or kernel_mode()
    if mode == "off":
        return _cc.bc_matmul_spectral(x, _cc.spectral_cache(w),
                                      w.shape[-1], n_out)
    return _bcf.bc_linear_fused_kernel(x, w, n_out,
                                       interpret=(mode == "interpret"),
                                       **block_kw)


def paged_gather(pool, table, mode: str | None = None):
    """Gather a slot-contiguous KV view out of a paged pool.

    pool: (P, page, H, D); table: (B, maxp) int32 page ids ->
    (B, maxp * page, H, D).  'off' lowers through a plain XLA gather
    (``pool[table]``); kernel modes run the scalar-prefetch Pallas gather.
    """
    mode = mode or kernel_mode()
    if mode == "off":
        _, page, H, D = pool.shape
        B, maxp = table.shape
        return pool[table].reshape(B, maxp * page, H, D)
    return _paged.paged_gather_kernel(pool, table,
                                      interpret=(mode == "interpret"))


def paged_attention(q, pool_k, pool_v, table, positions, *, scale=None,
                    softcap=0.0, k_scale=None, v_scale=None,
                    mode: str | None = None):
    """Fused paged flash-decode: stream pool pages through online-softmax.

    q: (B, Hq, D) one decode query per slot; pool: (P, page, Hkv, D);
    table: (B, maxp) int32 page ids; positions: (B,) int32 per-slot
    absolute position of the decode token (-1 = idle, fully masked; the
    output row is exactly zero) -> (B, Hq, D).

    The gathered ``(B, maxp * page, Hkv, D)`` KV view of the old
    ``paged_gather`` + dense-attention path is never formed: 'off' lowers a
    live-length-bounded ``lax.while_loop`` over page-sized chunks (same
    masking semantics, O(page) working set under pure XLA; serving-only —
    not reverse-differentiable); kernel modes run the scalar-prefetch
    Pallas flash-decode kernel (kernels/paged_attention.py).

    ``k_scale``/``v_scale`` ((P, Hkv) f32, both or neither) select the
    QUANTIZED lane: the pool leaves are int8 (repro.quant) and every
    lowering dequantizes page chunks in-register beside the m/l/acc carry
    — attention HBM traffic is measured in int8 bytes.
    """
    mode = mode or kernel_mode()
    if mode == "off":
        return _pa.paged_attention_stream(q, pool_k, pool_v, table,
                                          positions, scale=scale,
                                          softcap=softcap,
                                          k_scale=k_scale, v_scale=v_scale)
    return _pa.paged_attention_kernel(q, pool_k, pool_v, table, positions,
                                      scale=scale, softcap=softcap,
                                      k_scale=k_scale, v_scale=v_scale,
                                      interpret=(mode == "interpret"))


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, kv_offset=0, mode: str | None = None,
                    **block_kw):
    mode = mode or kernel_mode()
    if mode == "off":
        return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale,
                                  kv_offset=kv_offset)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               kv_offset=kv_offset,
                               interpret=(mode == "interpret"), **block_kw)
