"""Pallas TPU kernel: online-softmax (flash) attention forward.

Supports the mask/score variants the assigned architectures need: causal,
sliding-window (mixtral / gemma2-local / recurrentgemma-local), logit softcap
(gemma2), GQA (kv-head sharing via the index map — no materialized repeat),
and a kv offset for decode-style queries.

Grid: (B, Hq, nQ, nKV); the last dimension is sequential on TPU, so the
running max / sum / accumulator live in VMEM scratch across kv steps
(the classic flash recurrence).  Block shapes are multiples of the MXU tile
(128) in the model dims; softmax statistics are kept in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, softcap, block_q, block_k, num_kv_blocks,
            kv_offset, seq_kv):
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, D)
    # zero the grid-padding kv rows: uninitialized pad values must not reach
    # the dot products (0 * NaN = NaN would poison whole rows)
    kv_ids = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, k.shape, 0)
    kv_valid = kv_ids < seq_kv
    k = jnp.where(kv_valid, k, 0.0)
    v = jnp.where(kv_valid, v_ref[0, 0].astype(jnp.float32), 0.0)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    iq = pl.program_id(2)
    rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + kv_offset
    cols = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = cols < seq_kv                 # grid padding beyond the kv length
    if causal:
        mask &= cols <= rows
    if window:
        mask &= cols > rows - window
    s = jnp.where(mask, s, _NEG)

    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)                            # fully-masked-row guard
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scr[:, 0] * alpha + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[:, 0] = m_new
    l_scr[:, 0] = l_new

    @pl.when(jk == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, kv_offset=0, block_q=128, block_k=128,
                    interpret=True):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq, bk = min(block_q, Sq), min(block_k, Skv)
    nq, nkv = -(-Sq // bq), -(-Skv // bk)

    q_spec = pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, jk: (b, h, iq, 0))
    kv_spec = pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, jk: (b, h // group, jk, 0))
    o_spec = pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, jk: (b, h, iq, 0))

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, num_kv_blocks=nkv, kv_offset=kv_offset,
        seq_kv=Skv)

    return pl.pallas_call(
        kern,
        grid=(B, Hq, nq, nkv),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
