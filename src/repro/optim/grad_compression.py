"""int8 gradient compression with error feedback (distributed-optimization
trick for the cross-pod gradient all-reduce — DESIGN.md §7).

``compress_decompress(grads, ef)`` quantizes each gradient leaf to int8 with
a per-tensor absmax scale, carries the quantization residual in an error-
feedback buffer (so the bias vanishes over steps: Karimireddy et al.'s EF),
and returns the dequantized gradients the optimizer consumes.  Under SPMD
the quantize happens before the (sharding-induced) gradient reduction of the
data axes on every pod; ``wire_allreduce_int8`` is the explicit shard_map
form that provably moves int8 across the "pod" axis — used by the pure-DP
trainer path and the tests.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _q(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """int8 round-trip with error feedback.  Returns (grads', new_ef)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _q(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def wire_allreduce_int8(grads: Any, mesh, axis: str = "pod") -> Any:
    """Explicit int8 all-reduce over one mesh axis via shard_map.

    Quantize -> psum(int32 accumulate) -> dequantize-and-average.  This is
    the wire-format path: the tensor crossing `axis` is int8-scaled ints, a
    4x byte reduction on the slowest (cross-pod DCI) links.
    """
    n = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def reduce_one(g):
        def f(gl):
            q, scale = _q(gl.astype(jnp.float32))
            acc = jax.lax.psum(q.astype(jnp.int32), axis)       # int wire
            smax = jax.lax.pmax(scale, axis)                    # scalar wire
            return (acc.astype(jnp.float32) * smax / n).astype(gl.dtype)
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        spec = P(*([None] * g.ndim))
        return shard_map(f, mesh=mesh, in_specs=spec, out_specs=spec,
                         check_rep=False)(g)

    return jax.tree.map(reduce_one, grads)
