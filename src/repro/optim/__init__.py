from . import adamw, grad_compression, schedule  # noqa: F401
