"""AdamW in pure JAX, with optional int8-quantized moments.

The int8 moment store (per-tensor absmax scales, symmetric for m, plus a
uint8 sqrt-encoded second moment) quarters optimizer-state HBM — the
difference between fitting and not fitting llama4-maverick's dense baseline
on 16 GiB chips (DESIGN.md §7).  Both stores expose the same update(); the
state layout mirrors the param pytree so the sharding rule engine applies
verbatim.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_moments: bool = False


# ---------------------------------------------------------------------------
# int8 moment codec (error is re-absorbed every step by the fresh quantize)
# ---------------------------------------------------------------------------
def _q_sym(x):
    """Symmetric int8 with per-tensor absmax scale (for m, sign-carrying)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq_sym(q, scale):
    return q.astype(jnp.float32) * scale


def _q_pos(x):
    """uint8 sqrt-companded codec for the (non-negative) second moment."""
    r = jnp.sqrt(jnp.maximum(x, 0.0))
    scale = jnp.maximum(jnp.max(r), 1e-12) / 255.0
    q = jnp.clip(jnp.round(r / scale), 0, 255).astype(jnp.uint8)
    return q, scale.astype(jnp.float32)


def _dq_pos(q, scale):
    r = q.astype(jnp.float32) * scale
    return r * r


# ---------------------------------------------------------------------------
def init(params: Any, cfg: AdamWConfig) -> Dict:
    if cfg.quantize_moments:
        def zq(p):
            return {"m": jnp.zeros(p.shape, jnp.int8),
                    "m_s": jnp.zeros((), jnp.float32),
                    "v": jnp.zeros(p.shape, jnp.uint8),
                    "v_s": jnp.zeros((), jnp.float32)}
        mv = jax.tree.map(zq, params)
    else:
        mv = jax.tree.map(
            lambda p: {"m": jnp.zeros(p.shape, jnp.float32),
                       "v": jnp.zeros(p.shape, jnp.float32)}, params)
    return {"mv": mv, "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(grads: Any, state: Dict, params: Any, cfg: AdamWConfig,
           lr: Optional[jax.Array] = None) -> Tuple[Any, Dict]:
    """One AdamW step.  Returns (new_params, new_state)."""
    count = state["count"] + 1
    lr = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def one(g, p, mv):
        g = g.astype(jnp.float32) * clip
        if cfg.quantize_moments:
            m = _dq_sym(mv["m"], mv["m_s"])
            v = _dq_pos(mv["v"], mv["v_s"])
        else:
            m, v = mv["m"], mv["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        upd = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0  # no decay on norms
        new_p = (p.astype(jnp.float32) - lr * (upd + decay * p.astype(jnp.float32)))
        if cfg.quantize_moments:
            mq, ms = _q_sym(m)
            vq, vs = _q_pos(v)
            return new_p.astype(p.dtype), {"m": mq, "m_s": ms, "v": vq, "v_s": vs}
        return new_p.astype(p.dtype), {"m": m, "v": v}

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_mv = treedef.flatten_up_to(state["mv"])
    out = [one(g, p, mv) for g, p, mv in zip(flat_g, flat_p, flat_mv)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mv = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_params, {"mv": new_mv, "count": count}
