import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Perf hillclimb driver: lower one cell under a named variant, report the
roofline terms.  Each §Perf iteration is one invocation; EXPERIMENTS.md
records hypothesis -> change -> before -> after.

  PYTHONPATH=src python -m repro.launch.hillclimb \
      --arch tinyllama-1.1b --shape train_4k --variant fuse --out results/hc.json
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402

from ..configs.base import SHAPES_BY_NAME  # noqa: E402
from ..configs.registry import get_config  # noqa: E402
from . import dryrun, mesh as mesh_lib  # noqa: E402

VARIANTS = {
    # paper-faithful baseline (same knobs the roofline sweep uses)
    "baseline": dict(),
    # paper-faithful WITHOUT the Gauss 3-mult trick (the pure-paper MAC count)
    "nogauss": dict(comp=dict(gauss_trick=False)),
    # beyond-paper: fused q/k/v + gate/up DFT pipelines
    "fuse": dict(comp=dict(fuse_projections=True)),
    # beyond-paper: no remat (flops down ~25%, memory up)
    "noremat": dict(cfg=dict(remat="none")),
    "fuse_noremat": dict(comp=dict(fuse_projections=True),
                         cfg=dict(remat="none")),
    # beyond-paper: token-parallel layout (weights replicated over "model",
    # sequence sharded over it) — kills TP collectives on compressed layers
    "tokenpar": dict(strategy="tokenpar"),
    "fuse_tokenpar": dict(comp=dict(fuse_projections=True),
                          strategy="tokenpar"),
    # block-size sensitivity (transform cost ∝ n·k, MAC ∝ n²/k)
    "k64": dict(comp=dict(block_ffn=64, block_attn=64, block_expert=64)),
    "k256": dict(comp=dict(block_ffn=256, block_attn=256, block_expert=256)),
    # decode: f8 KV cache (halves the cache-read memory term)
    "kvf8": dict(cfg=dict(kv_cache_dtype="float8_e4m3fn")),
    "kvf8_fuse": dict(cfg=dict(kv_cache_dtype="float8_e4m3fn"),
                      comp=dict(fuse_projections=True)),
    # combined best-of for train cells
    "best": dict(comp=dict(fuse_projections=True), cfg=dict(remat="none"),
                 strategy="tokenpar"),
    "kvf8_tokenpar": dict(cfg=dict(kv_cache_dtype="float8_e4m3fn"),
                          strategy="tokenpar"),
    # dense reference (the paper's uncompressed baseline)
    "dense": dict(compress=False),
}


def run_variant(arch: str, shape: str, variant: str, accum: int = 0):
    spec = VARIANTS[variant]
    cfg = get_config(arch, compress=spec.get("compress", True))
    if "comp" in spec:
        cfg = cfg.replace(compression=dataclasses.replace(
            cfg.compression, **spec["comp"]))
    if "cfg" in spec:
        cfg = cfg.replace(**spec["cfg"])
    mesh = mesh_lib.make_production_mesh(multi_pod=False)
    strategy = spec.get("strategy", "megatron")
    # roofline lowering knobs (accum=0 -> exact-cost unrolled)
    if accum == 0:
        S = SHAPES_BY_NAME[shape].seq_len
        cfg = cfg.replace(unroll_scan=True, attn_q_chunk=max(S // 4, 1),
                          attn_kv_chunk=max(S, 1), mlstm_chunk=max(S, 1))
        accum = 1
    rec = {"arch": arch, "shape": shape, "variant": variant,
           "strategy": strategy}
    import time
    import traceback
    t0 = time.time()
    try:
        lowered, compiled, meta = dryrun.lower_cell(
            arch, shape, mesh, strategy,
            compress=spec.get("compress", True), accum=accum,
            cfg_override=cfg)
        from ..roofline import analysis as roofline
        rec.update(roofline.cell_report(lowered, compiled, meta["cfg"],
                                        meta["shape"], mesh))
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["trace"] = traceback.format_exc()[-1500:]
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True,
                    help=f"comma list of {sorted(VARIANTS)}")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    recs = []
    existing = (json.load(open(args.out))
                if args.out and os.path.exists(args.out) else [])
    for v in args.variant.split(","):
        rec = run_variant(args.arch, args.shape, v)
        recs.append(rec)
        if args.out:                          # incremental: survive kills
            with open(args.out, "w") as f:
                json.dump(existing + recs, f, indent=1)
        if rec["status"] == "ok":
            print(f"{v}: compute={rec['compute_s']*1e3:.1f}ms "
                  f"memory={rec['memory_s']*1e3:.1f}ms "
                  f"collective={rec['collective_s']*1e3:.1f}ms "
                  f"dom={rec['dominant']} mhr={rec['model_hlo_ratio']:.3f} "
                  f"roof={rec['roofline_frac_overlap']:.3f} "
                  f"({rec['wall_s']}s)", flush=True)
        else:
            print(f"{v}: FAIL {rec['error']}", flush=True)


if __name__ == "__main__":
    main()
