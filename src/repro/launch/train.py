"""Training launcher: ``--arch <id>`` selects any assigned architecture.

Host-scale (this container) runs the REDUCED same-family config by default;
``--full`` selects the published config (for multi-host TPU launches — the
same entrypoint, the mesh comes from the environment).

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse

import jax

from ..configs.registry import ARCH_IDS, get_config, get_smoke_config
from ..data.pipeline import SyntheticLM
from ..dist import ctx as dist_ctx
from ..obs import Obs
from ..optim import adamw
from ..train.trainer import Trainer
from . import mesh as mesh_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--full", action="store_true",
                    help="published config (TPU-scale launch)")
    ap.add_argument("--no-compress", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--bayesian", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--int8-moments", action="store_true")
    ap.add_argument("--workdir", default="/tmp/repro_launch_train")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write repro.obs JSONL telemetry (train.loss / "
                         "train.step_s / train.tokens_per_s snapshots) to "
                         "FILE; the heartbeat file is unaffected")
    ap.add_argument("--metrics-every", type=int, default=10,
                    help="with --metrics-out: flush every N steps")
    args = ap.parse_args()

    getter = get_config if args.full else get_smoke_config
    cfg = getter(args.arch, compress=not args.no_compress)
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq, seed=0)
    obs = Obs(emit_path=args.metrics_out, emit_every=args.metrics_every)
    trainer = Trainer(
        cfg,
        adamw.AdamWConfig(lr=args.lr, quantize_moments=args.int8_moments),
        workdir=args.workdir, data_fn=data, total_steps=args.steps,
        ckpt_every=max(args.steps // 2, 1), log_every=10, accum=args.accum,
        compress_grads=args.compress_grads, bayesian_mode=args.bayesian,
        obs=obs)
    # The step jit traces lazily (first call inside run()), so installing the
    # activation policy here pins block-boundary activations for the whole run.
    with dist_ctx.activation_policy(mesh_lib.make_host_mesh()):
        state = trainer.run()
    n = sum(p.size for p in jax.tree.leaves(state["params"]))
    loss = (f"{trainer.history[-1]['loss']:.4f}" if trainer.history
            else "n/a (fewer steps than log_every)")
    print(f"[launch.train] {args.arch}: {int(state['step'])} steps, "
          f"{n:,} params, loss {loss}")
    if args.metrics_out is not None:
        obs.close()                         # final cumulative snapshot
        print(f"[launch.train] metrics: {obs.emitter.lines_written} "
              f"lines -> {args.metrics_out}")


if __name__ == "__main__":
    main()
