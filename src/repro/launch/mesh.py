"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).

Mesh layout (TPU v5e pods):
  single pod : (16, 16)    axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

DP runs over ("pod","data"); TP/EP/SP over "model"; FSDP param sharding over
"data".  The "pod" axis only ever carries pure data parallelism + gradient
all-reduce, so cross-pod (DCI) traffic is one gradient reduction per step —
the layout that scales past 1000 nodes.
"""
from __future__ import annotations

import inspect
from typing import Optional, Tuple

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types=Auto`` where the jax version supports it.

    ``jax.sharding.AxisType`` (and the ``axis_types`` kwarg on
    ``jax.make_mesh``) only exist on newer jax; older versions are
    Auto-by-default, so omitting the kwarg is behavior-identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if (axis_type is None or
            "axis_types" not in inspect.signature(jax.make_mesh).parameters):
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    n = math.prod(shape)
    devices = jax.devices()[:n]              # dry-run exposes 512 host devices
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} "
            "(the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import)")
    return jax.make_mesh(shape, axes, devices=devices,
                         **_axis_type_kwargs(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh with GSPMD-auto axis types (tests use small meshes)."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh():
    """Single-process CPU mesh (trainer/serve on this container)."""
    n = jax.device_count()
    return make_mesh((n, 1), ("data", "model"))
