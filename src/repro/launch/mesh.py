"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).

Mesh layout (TPU v5e pods):
  single pod : (16, 16)    axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

DP runs over ("pod","data"); TP/EP/SP over "model"; FSDP param sharding over
"data".  The "pod" axis only ever carries pure data parallelism + gradient
all-reduce, so cross-pod (DCI) traffic is one gradient reduction per step —
the layout that scales past 1000 nodes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    n = math.prod(shape)
    devices = jax.devices()[:n]              # dry-run exposes 512 host devices
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} "
            "(the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import)")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        devices=devices)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh with GSPMD-auto axis types (tests use small meshes)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Single-process CPU mesh (trainer/serve on this container)."""
    n = jax.device_count()
    return make_mesh((n, 1), ("data", "model"))
