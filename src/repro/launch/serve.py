"""Serving launcher: batched-request engine for any assigned architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs.registry import ARCH_IDS, get_config, get_smoke_config
from ..models.registry import build_model
from ..serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="early-exit the device decode loop at this token")
    ap.add_argument("--decode-mode", default="scan",
                    choices=["scan", "per_token"],
                    help="device-resident loop (default) or the seed "
                         "per-token host loop")
    ap.add_argument("--no-precompute", action="store_true",
                    help="skip the offline spectral-weight pass")
    args = ap.parse_args()

    getter = get_config if args.full else get_smoke_config
    cfg = getter(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_batch=args.max_batch,
                    max_seq=64 + args.new_tokens, sample=args.sample,
                    precompute=not args.no_precompute,
                    decode_mode=args.decode_mode, eos_id=args.eos_id)
    rng = np.random.RandomState(0)
    # prompts cover the smoke sliding window (16): the ring-buffer prefill
    # keeps the window tail and needs S >= window for SWA archs
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size, size=rng.randint(
        16, 32)).astype(np.int32), max_new_tokens=args.new_tokens, id=i)
        for i in range(args.requests)]
    t0 = time.time()
    results = engine.generate(reqs)
    dt = time.time() - t0
    toks = sum(r["decode_len"] for r in results)
    pre = sum(r["prefill_s"] for r in results) / max(len(results), 1)
    deco = sum(r["decode_s"] for r in results) / max(len(results), 1)
    print(f"[launch.serve] {args.arch}: {len(results)} requests, "
          f"{toks} tokens, {dt:.2f}s ({toks / dt:.1f} tok/s; "
          f"mean prefill {pre * 1e3:.0f}ms / decode {deco * 1e3:.0f}ms)")


if __name__ == "__main__":
    main()
