"""Serving launcher: batched-request engine for any assigned architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs.registry import ARCH_IDS, get_config, get_smoke_config
from ..models.registry import build_model
from ..serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--sample", action="store_true")
    args = ap.parse_args()

    getter = get_config if args.full else get_smoke_config
    cfg = getter(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_batch=args.max_batch,
                    max_seq=64 + args.new_tokens, sample=args.sample)
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size, size=rng.randint(
        4, 32)).astype(np.int32), max_new_tokens=args.new_tokens, id=i)
        for i in range(args.requests)]
    t0 = time.time()
    results = engine.generate(reqs)
    dt = time.time() - t0
    toks = sum(len(r["tokens"]) for r in results)
    print(f"[launch.serve] {args.arch}: {len(results)} requests, "
          f"{toks} tokens, {dt:.2f}s ({toks / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
