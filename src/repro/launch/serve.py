"""Serving launcher: batch-synchronous or continuous-batching engine for any
assigned architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 8
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --engine continuous --page-size 16 --max-tokens-in-flight 512
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs.registry import ARCH_IDS, get_config, get_smoke_config
from ..models.registry import build_model
from ..obs import Obs, resolve_hardware
from ..obs.chrometrace import write_trace
from ..quant import QuantPolicy
from ..roofline.analysis import HARDWARE_PRESETS
from ..serve.engine import ContinuousEngine, Engine, Request
from ..serve.kvcache import servable_reasons


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="batch engine: batch size; continuous: decode slots")
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling PRNG seed (reproducible per engine)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="early-exit the device decode loop at this token")
    ap.add_argument("--engine", default="batch",
                    choices=["batch", "continuous"],
                    help="batch-synchronous engine or the continuous-"
                         "batching engine over the paged KV pool")
    ap.add_argument("--page-size", type=int, default=16,
                    help="continuous: KV pool page size (tokens per block)")
    ap.add_argument("--max-tokens-in-flight", type=int, default=None,
                    help="continuous: admission token budget")
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="continuous: decode steps per device dispatch")
    ap.add_argument("--admission", default="optimistic",
                    choices=["optimistic", "reserve"],
                    help="continuous: optimistic page admission (preempt on "
                         "exhaustion) or legacy worst-case reservation")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="continuous: bounded submit queue; requests beyond "
                         "it are REJECTED (backpressure)")
    ap.add_argument("--max-preemptions", type=int, default=4,
                    help="continuous: per-request preemption bound before a "
                         "slot stalls instead of thrashing")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="continuous: per-request deadline (seconds from "
                         "arrival); expired requests go terminal TIMEOUT")
    ap.add_argument("--paged-attn", default="stream",
                    choices=["stream", "gather"],
                    help="continuous: fused paged flash-decode (default) or "
                         "the legacy gather-then-attend oracle path")
    ap.add_argument("--kv-dtype", default="f32",
                    choices=["f32", "bf16", "int8"],
                    help="continuous: paged KV-pool storage dtype; int8 "
                         "adds per-(page, head) absmax scales and halves-"
                         "to-quarters pool bytes (repro.quant)")
    ap.add_argument("--quant-weights", action="store_true",
                    help="quantize the precomputed spectral weight planes "
                         "to fixed point (per-block-row absmax scales)")
    ap.add_argument("--weight-bits", type=int, default=8, choices=[8, 4],
                    help="with --quant-weights: int8 planes or the packed-"
                         "int4 stretch mode (two nibbles per byte)")
    ap.add_argument("--decode-mode", default="scan",
                    choices=["scan", "per_token"],
                    help="batch engine: device-resident loop (default) or "
                         "the seed per-token host loop")
    ap.add_argument("--no-bucket", action="store_true",
                    help="batch engine: disable prompt-length bucketing")
    ap.add_argument("--no-precompute", action="store_true",
                    help="skip the offline spectral-weight pass")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write repro.obs JSONL telemetry (registry "
                         "snapshots + per-request traces) to FILE; validate "
                         "with python -m repro.obs.emit --validate FILE")
    ap.add_argument("--metrics-every", type=int, default=10,
                    help="with --metrics-out: flush every N engine "
                         "dispatches (default 10)")
    ap.add_argument("--no-obs", action="store_true",
                    help="disable traces/histograms (counters stay live; "
                         "the zero-overhead telemetry path)")
    ap.add_argument("--shadow-sample", type=float, default=0.0,
                    metavar="FRAC",
                    help="continuous: replay this fraction of FINISHED "
                         "requests through the f32 dense-cache oracle "
                         "between dispatches, publishing online "
                         "health.greedy_agreement / health.logit_drift "
                         "(obs/health.py)")
    ap.add_argument("--slo", action="store_true",
                    help="run the stock SLO watchdog (obs/slo.py) over "
                         "every emitted snapshot; fired alerts are "
                         "appended to --metrics-out as alert records and "
                         "summarized on exit")
    ap.add_argument("--slo-rules", default=None, metavar="RULES.json",
                    help="with --slo: JSON list of Rule dicts instead of "
                         "the stock ruleset")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write a Perfetto-loadable Chrome trace of the "
                         "serve (engine dispatch lanes, one lane per "
                         "request, counter tracks) to FILE; open at "
                         "https://ui.perfetto.dev")
    ap.add_argument("--replicas", type=int, default=1,
                    help="continuous: serve through a replicated fleet of N "
                         "engines behind the health-checked failover router "
                         "(repro.fleet); telemetry gains a replica= label "
                         "and per-replica trace lanes")
    ap.add_argument("--router-policy", default="jsq",
                    choices=["jsq", "round_robin"],
                    help="with --replicas: join-shortest-queue placement "
                         "(default) or round-robin")
    ap.add_argument("--hedge-after", type=float, default=None,
                    metavar="SECONDS",
                    help="with --replicas: hedge a request to a second "
                         "replica if its first token takes longer than this "
                         "(default: adaptive, 4x the fleet's p99 TTFT)")
    ap.add_argument("--hardware", default="auto",
                    choices=["auto"] + sorted(HARDWARE_PRESETS),
                    help="roofline HardwareSpec the profiler attributes "
                         "dispatches against (auto = detect jax backend)")
    args = ap.parse_args(argv)

    getter = get_config if args.full else get_smoke_config
    cfg = getter(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = 64 + args.new_tokens
    quant = QuantPolicy(kv_dtype=args.kv_dtype,
                        quant_weights=args.quant_weights,
                        weight_bits=args.weight_bits)
    watchdog = None
    if args.slo:
        from ..obs.slo import SloWatchdog, rules_from_json
        watchdog = SloWatchdog(rules_from_json(args.slo_rules)
                               if args.slo_rules else None)
    obs = Obs(enabled=not args.no_obs, emit_path=args.metrics_out,
              emit_every=args.metrics_every,
              hardware=resolve_hardware(args.hardware), slo=watchdog)
    router = None
    if args.replicas > 1 and args.engine != "continuous":
        raise SystemExit("[launch.serve] --replicas > 1 requires "
                         "--engine continuous")
    if args.engine == "continuous":
        reasons = servable_reasons(cfg)
        if reasons:
            raise SystemExit(f"[launch.serve] {args.arch} is not continuous-"
                             f"servable ({'; '.join(reasons)}); "
                             f"use --engine batch")

        def make_engine(eng_obs):
            return ContinuousEngine(
                cfg, params, max_slots=args.max_batch, max_seq=max_seq,
                page_size=args.page_size,
                max_tokens_in_flight=args.max_tokens_in_flight,
                decode_chunk=args.decode_chunk, sample=args.sample,
                seed=args.seed, eos_id=args.eos_id,
                precompute=not args.no_precompute,
                paged_attn=args.paged_attn,
                quant=quant, obs=eng_obs, admission=args.admission,
                max_queue=args.max_queue,
                max_preemptions=args.max_preemptions,
                shadow_sample=args.shadow_sample)

        if args.replicas > 1:
            from ..fleet import EngineReplica, Router
            pool = [EngineReplica(f"r{i}",
                                  make_engine(obs.scoped(replica=f"r{i}")))
                    for i in range(args.replicas)]
            router = Router(pool, policy=args.router_policy,
                            hedge_after_s=args.hedge_after, obs=obs,
                            seed=args.seed)
        else:
            engine = make_engine(obs)
    else:
        if args.kv_dtype != "f32":
            print(f"[launch.serve] note: --kv-dtype {args.kv_dtype} applies "
                  f"to the continuous engine's paged pool; the batch "
                  f"engine's dense cache stays f32 (parity oracle)")
        if args.shadow_sample > 0.0:
            print("[launch.serve] note: --shadow-sample applies to the "
                  "continuous engine (the batch engine IS the f32 oracle)")
        engine = Engine(cfg, params, max_batch=args.max_batch,
                        max_seq=max_seq, sample=args.sample,
                        precompute=not args.no_precompute,
                        decode_mode=args.decode_mode, eos_id=args.eos_id,
                        seed=args.seed, bucket_prompts=not args.no_bucket,
                        quant=quant, obs=obs)
    rng = np.random.RandomState(0)
    # prompts cover the smoke sliding window (16): the ring-buffer prefill
    # keeps the window tail and needs S >= window for SWA archs
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size, size=rng.randint(
        16, 32)).astype(np.int32), max_new_tokens=args.new_tokens, id=i,
        deadline_s=args.deadline_s)
        for i in range(args.requests)]
    t0 = time.time()
    server = router if router is not None else engine
    results = server.generate(reqs)
    dt = time.time() - t0
    toks = sum(r["decode_len"] for r in results)
    # unserved terminals (TIMEOUT/REJECTED/...) carry no prefill span
    served = [r for r in results if r.get("prefill_s") is not None]
    pre = sum(r["prefill_s"] for r in served) / max(len(served), 1)
    deco = sum(r["decode_s"] for r in served) / max(len(served), 1)
    label = (f"{args.engine} x{args.replicas}" if router is not None
             else args.engine)
    print(f"[launch.serve] {args.arch} ({label}): {len(results)} "
          f"requests, {toks} tokens, {dt:.2f}s ({toks / dt:.1f} tok/s; "
          f"mean prefill {pre * 1e3:.0f}ms / decode {deco * 1e3:.0f}ms)")
    if router is not None:
        rs = router.stats()
        nonzero = {s: n for s, n in rs["statuses"].items() if n}
        print(f"[launch.serve] fleet: policy={rs['policy']} "
              f"live={rs['live_replicas']}/{len(router.replicas)} "
              f"placed={rs['placed']} retries={rs['place_retries']} "
              f"hedges={rs['hedges']} failovers={rs['failovers']} "
              f"migrated={rs['migrated_requests']} shed={rs['shed']} "
              f"statuses={nonzero}")
        for rep in rs["replicas"]:
            e = rep["engine"]
            print(f"[launch.serve]   {rep['name']}: {rep['state']} "
                  f"served_statuses="
                  f"{ {s: n for s, n in e['statuses'].items() if n} } "
                  f"preempted={e['preempted']} "
                  f"peak_pages={e['peak_pages_in_use']}")
        router.drain()
    st = server.stats() if router is None else None
    if st is not None and args.engine == "continuous":
        print(f"[launch.serve] telemetry: queue_depth={st['queue_depth']} "
              f"peak_tokens_in_flight={st['peak_tokens_in_flight']} "
              f"peak_pages={st['peak_pages_in_use']}/{engine.num_pages - 1} "
              f"pool={st['pool_bytes'] / 1e6:.1f}MB "
              f"prefill/decode split={st['prefill_s']:.2f}s/"
              f"{st['decode_s']:.2f}s "
              f"dispatches={st['decode_dispatches']} "
              f"buckets={st['prefill_buckets']}")
        print(f"[launch.serve] memory: attn={st['attention_impl']} "
              f"attn_bytes/token={st['attention_bytes_per_token'] / 1e6:.2f}MB "
              f"peak_attn={st['peak_attention_bytes'] / 1e6:.2f}MB "
              f"decode_peak_est={st['decode_peak_bytes_est'] / 1e6:.1f}MB")
        qp = st["quant_policy"]
        print(f"[launch.serve] quant: kv_dtype={qp['kv_dtype']} "
              f"weights={'int' + str(qp['weight_bits']) if qp['quant_weights'] else 'f32'} "
              f"kv_pool_bytes={st['kv_pool_bytes'] / 1e6:.1f}MB")
        nonzero = {s: n for s, n in st["statuses"].items() if n}
        print(f"[launch.serve] lifecycle: statuses={nonzero} "
              f"admission={st['admission']} preempted={st['preempted']} "
              f"stalled={st['stalled']} anomalies={st['anomalies']}")
        print(f"[launch.serve] pool pressure: free_pages={st['free_pages']} "
              f"min_free_pages={st['min_free_pages']} (low-water headroom "
              f"of {engine.num_pages - 1} usable)")
        if st.get("health") is not None:
            h = st["health"]
            print(f"[launch.serve] health: nonfinite_dispatches="
                  f"{h['nonfinite_dispatches']} "
                  f"act_absmax_peak={h['act_absmax_peak']} "
                  f"kv_clip_rate={st['kv_clip_rate']}")
        if st.get("shadow_oracle") is not None:
            sh = st["shadow_oracle"]
            agree = sh["greedy_agreement"]
            drift = sh["logit_drift"]
            print(f"[launch.serve] shadow oracle: sampled={sh['sampled']} "
                  f"replays={sh['replays']} dropped={sh['dropped']} "
                  f"greedy_agreement="
                  f"{'n/a' if agree is None else f'{agree:.4f}'} "
                  f"logit_drift="
                  f"{'n/a' if drift is None else f'{drift:.4g}'}")
    elif st is not None:
        print(f"[launch.serve] telemetry: batches={st['batches']} "
              f"prompt_pad_waste={st['prompt_pad_waste']} tokens "
              f"prefill/decode split={st['prefill_s']:.2f}s/"
              f"{st['decode_s']:.2f}s")
    if not args.no_obs and st is not None and st.get("roofline"):
        print(f"[launch.serve] roofline ({st['hardware']}):")
        for kind, r in st["roofline"].items():
            if not r["dispatches"]:
                continue
            print(f"  {kind:<22} n={r['dispatches']:<4} "
                  f"{r['achieved_flops_per_s'] / 1e9:8.2f} GFLOP/s  "
                  f"{r['achieved_bytes_per_s'] / 1e9:8.2f} GB/s  "
                  f"frac={r['roofline_frac']:.3g} ({r['bound']}-bound)")
    if args.metrics_out is not None:
        obs.close()                        # final snapshot + trailing traces
        print(f"[launch.serve] metrics: {obs.emitter.lines_written} "
              f"lines -> {args.metrics_out}")
    if watchdog is not None:
        ws = watchdog.stats()
        print(f"[launch.serve] slo: {ws['alerts']} alerts "
              f"({ws['page_alerts']} page) by_rule={ws['by_rule']}")
        for a in watchdog.alerts:
            print(f"[launch.serve]   {a['severity'].upper()} {a['rule']} "
                  f"{a['series']}: {a['value']:.6g} {a['op']} "
                  f"{a['threshold']:.6g}")
    if args.trace_out is not None:
        trace = write_trace(obs, args.trace_out,
                            extra_meta={"arch": args.arch,
                                        "engine": args.engine,
                                        "replicas": args.replicas})
        print(f"[launch.serve] chrome trace: "
              f"{len(trace['traceEvents'])} events -> {args.trace_out} "
              f"(open at https://ui.perfetto.dev)")
    if not args.no_obs:
        print("[launch.serve] obs summary:")
        print(obs.summary())


if __name__ == "__main__":
    main()
