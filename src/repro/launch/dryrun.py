import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell against the production mesh, with NO device allocation (inputs are
ShapeDtypeStructs).  This proves the distribution config is coherent — a
sharding mismatch, compile-time OOM, or unsupported collective here is a bug
in the system, not an environment problem.

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init); smoke tests and benchmarks import the library
normally and see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun.json
"""
import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs.base import ALL_SHAPES, SHAPES_BY_NAME, cell_is_applicable  # noqa: E402
from ..configs.registry import ARCH_IDS, get_config  # noqa: E402
from ..dist import ctx as dist_ctx  # noqa: E402
from ..dist import sharding as sh  # noqa: E402
from ..models import registry as mreg  # noqa: E402
from ..optim import adamw  # noqa: E402
from ..roofline import analysis as roofline  # noqa: E402
from ..serve import decode as serve_decode  # noqa: E402
from ..serve import params as serve_params  # noqa: E402
from ..train import train_step as ts  # noqa: E402
from . import mesh as mesh_lib  # noqa: E402


def state_specs_for(cfg, mesh, strategy):
    """ShapeDtypeStructs + PartitionSpecs of the train state (no alloc)."""
    model = mreg.build_model(cfg)
    opt_cfg = adamw.AdamWConfig(quantize_moments=True)
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    state_shapes = jax.eval_shape(
        lambda p: {"params": p, "opt": adamw.init(p, opt_cfg),
                   "step": jnp.zeros((), jnp.int32),
                   "skipped": jnp.zeros((), jnp.int32)}, params_shapes)
    pspecs = sh.param_specs(params_shapes, mesh, strategy)

    def mv_spec(path, leaf):
        # opt moments mirror the param; scalar scales/counters replicate
        names = tuple(getattr(p, "key", getattr(p, "idx", p)) for p in path)
        if leaf.ndim == 0:
            return jax.sharding.PartitionSpec()
        base = [str(n) for n in names if str(n) not in
                ("mv", "m", "v", "m_s", "v_s")]
        return sh.param_spec(tuple(base), leaf.shape, mesh, strategy)

    opt_specs = jax.tree_util.tree_map_with_path(
        mv_spec, state_shapes["opt"])
    state_spec = {"params": pspecs, "opt": opt_specs,
                  "step": jax.sharding.PartitionSpec(),
                  "skipped": jax.sharding.PartitionSpec()}
    return state_shapes, state_spec, opt_cfg


def lower_cell(arch_id: str, shape_name: str, mesh, strategy: str = "megatron",
               compress: bool = True, donate: bool = True, seq_shard=None,
               accum: int = 4, cfg_override=None):
    """Lower + compile one cell.  Returns (lowered, compiled, meta).

    ``accum``: microbatch gradient-accumulation factor for train cells —
    global batch 256 is stepped as 4 microbatches of 64, bounding live
    activations to fit the 16 GiB HBM (EXPERIMENTS.md §Dry-run).
    """
    cfg = cfg_override or get_config(arch_id, compress=compress)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = cell_is_applicable(cfg, shape)
    if not ok:
        return None, None, {"skipped": why}
    if accum == 0:
        # ROOFLINE lowering: XLA's cost model counts a while body once, so
        # exact FLOP/collective counts need unrolled layer loops, accum=1,
        # and single-chunk attention/mlstm (fit numbers come from the
        # default scanned+accumulated lowering instead).
        accum = 1
        S = shape.seq_len
        # q chunks stay a PYTHON loop (counted exactly, causal extent
        # savings realized); kv runs as a single scan trip (counted once =
        # counted exactly).
        cfg = cfg.replace(unroll_scan=True, attn_q_chunk=max(S // 4, 1),
                          attn_kv_chunk=max(S, 1), mlstm_chunk=max(S, 1))
    specs = mreg.input_specs(cfg, shape)
    B = shape.global_batch
    if seq_shard is None:
        seq_shard = strategy == "tokenpar" and shape.kind != "decode"

    lsh = jax.sharding.NamedSharding(
        mesh, sh.logits_spec(mesh, B, cfg.padded_vocab()))
    with mesh, dist_ctx.activation_policy(mesh, seq_shard=seq_shard):
        if shape.kind == "train":
            state_shapes, state_spec, opt_cfg = state_specs_for(
                cfg, mesh, strategy)
            step_fn = ts.make_train_step(cfg, opt_cfg, logits_sharding=lsh,
                                         accum=accum)
            in_shardings = (sh.to_shardings(state_spec, mesh),
                            sh.to_shardings(
                                sh.batch_specs(specs["batch"], mesh, B,
                                               seq_shard), mesh))
            out_shardings = (in_shardings[0], None)
            jitted = jax.jit(step_fn, in_shardings=in_shardings,
                             out_shardings=out_shardings,
                             donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state_shapes, specs["batch"])
        elif shape.kind == "prefill":
            step_fn = serve_decode.make_prefill_step(cfg, logits_sharding=lsh)
            model = mreg.build_model(cfg)
            # Serve cells lower against the production serving params: the
            # offline spectral planes baked in (paper's offline weight FFT).
            params_shapes = jax.eval_shape(
                lambda: serve_params.precompute_serving_params(
                    model.init(jax.random.PRNGKey(0)), cfg))
            pshard = sh.to_shardings(
                sh.param_specs(params_shapes, mesh, strategy), mesh)
            cshard = sh.to_shardings(
                sh.cache_specs(specs["cache"], mesh, B), mesh)
            bshard = sh.to_shardings(
                sh.batch_specs(specs["batch"], mesh, B, seq_shard), mesh)
            jitted = jax.jit(step_fn,
                             in_shardings=(pshard, bshard, cshard),
                             out_shardings=(None, cshard),
                             donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(params_shapes, specs["batch"],
                                   specs["cache"])
        else:  # decode
            step_fn = serve_decode.make_decode_step(cfg, logits_sharding=lsh)
            model = mreg.build_model(cfg)
            params_shapes = jax.eval_shape(
                lambda: serve_params.precompute_serving_params(
                    model.init(jax.random.PRNGKey(0)), cfg))
            pshard = sh.to_shardings(
                sh.param_specs(params_shapes, mesh, strategy), mesh)
            cshard = sh.to_shardings(
                sh.cache_specs(specs["cache"], mesh, B), mesh)
            tshard = sh.to_shardings(
                sh.batch_specs(specs["tokens"], mesh, B), mesh)
            jitted = jax.jit(
                step_fn,
                in_shardings=(pshard, tshard, cshard, None),
                out_shardings=(None, None, cshard),
                donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(params_shapes, specs["tokens"],
                                   specs["cache"], specs["cache_pos"])
        # CompiledCompat: cost_analysis() is a list-of-dicts on older jax;
        # everything downstream (reports, tests) indexes the flat dict.
        compiled = roofline.CompiledCompat(lowered.compile())
    return lowered, compiled, {"cfg": cfg, "shape": shape}


def run_cell(arch_id, shape_name, mesh, mesh_name, strategy, compress=True,
             accum=4):
    t0 = time.time()
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "strategy": strategy, "compress": compress,
           "lowering": "roofline" if accum == 0 else "production"}
    try:
        lowered, compiled, meta = lower_cell(arch_id, shape_name, mesh,
                                             strategy, compress, accum=accum)
        if lowered is None:
            rec["status"] = "skipped"
            rec["why"] = meta["skipped"]
            return rec
        rec.update(roofline.cell_report(lowered, compiled, meta["cfg"],
                                        meta["shape"], mesh))
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — report, continue the sweep
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["trace"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--strategy", default="megatron")
    ap.add_argument("--no-compress", action="store_true",
                    help="dense baseline (paper's uncompressed reference)")
    ap.add_argument("--roofline", action="store_true",
                    help="unrolled exact-cost lowering (accum=1; see "
                         "roofline/analysis.py)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = ([s.name for s in ALL_SHAPES] if args.shape == "all"
              else args.shape.split(","))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for multi in meshes:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi)
        mname = "2x16x16" if multi else "16x16"
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, mesh, mname, args.strategy,
                               compress=not args.no_compress,
                               accum=0 if args.roofline else 4)
                status = rec["status"]
                extra = (rec.get("why") or rec.get("error", "")
                         if status != "ok" else
                         f"bytes/dev={rec['bytes_per_device']:.2e} "
                         f"flops/dev={rec['flops_per_device']:.3e}")
                print(f"[{mname}] {a} x {s}: {status} {extra}", flush=True)
                results.append(rec)
                if args.out:                    # incremental: survive kills
                    os.makedirs(os.path.dirname(args.out) or ".",
                                exist_ok=True)
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"\n== dry-run: {n_ok} ok / {n_skip} skipped / {n_fail} FAILED ==")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print("wrote", args.out)
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
