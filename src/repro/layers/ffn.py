"""Feed-forward layers: gated MLPs and mixture-of-experts.

MoE uses grouped token-choice top-k routing with a capacity factor: tokens are
routed within fixed-size groups so the one-hot dispatch tensors stay small
(t·E·c per group instead of T·E·C globally), which is what makes the
dispatch/combine einsums slice cleanly under data parallelism and the expert
weights shard over the model axis (expert parallelism).

Expert FFN weights are `(E, ...)`-stacked and — when the paper's compression
is on — per-expert block-circulant ((E, p, q, k) first rows).
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from ..core.circulant import (LinearSpec, apply_linear, bc_matmul_fft,
                              bc_matmul_spectral, init_block_circulant,
                              init_linear)


def _act(name: str, x):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name](x)


# ---------------------------------------------------------------------------
# Dense (gated) MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, comp=None, gated: bool = True):
    spec = LinearSpec.from_config(comp, "ffn")
    ks = jax.random.split(key, 3)
    p = {"up": init_linear(ks[0], d_model, d_ff, spec),
         "down": init_linear(ks[1], d_ff, d_model, spec)}
    if gated:
        p["gate"] = init_linear(ks[2], d_model, d_ff, spec)
    return p


def mlp(params, x, *, d_ff: int, comp=None, activation="silu", mode="train"):
    spec = LinearSpec.from_config(comp, "ffn")
    fuse = (comp is not None and getattr(comp, "fuse_projections", False)
            and spec.kind == "block_circulant" and "gate" in params)
    if fuse:
        from ..core.circulant import bc_matmul_fused
        upgate_cache = params.get("upgate_cache") if mode != "train" else None
        up, gate = bc_matmul_fused(
            x, [params["up"]["wc"], params["gate"]["wc"]], [d_ff, d_ff], mode,
            cache=upgate_cache, gauss=spec.gauss)
        up = _act(activation, gate) * up
    else:
        up = apply_linear(params["up"], x, spec, d_ff, mode)
        if "gate" in params:
            up = _act(activation,
                      apply_linear(params["gate"], x, spec, d_ff, mode)) * up
        else:
            up = _act(activation, up)
    return apply_linear(params["down"], up, spec, x.shape[-1], mode)


# ---------------------------------------------------------------------------
# Mixture of experts
# ---------------------------------------------------------------------------
def init_moe(key, d_model: int, d_ff: int, moe_cfg, comp=None):
    E = moe_cfg.num_experts
    ks = jax.random.split(key, 5)
    k = comp.block_for("expert") if comp is not None and comp.enabled else 0
    scale_in = 1.0 / math.sqrt(d_model)
    scale_ff = 1.0 / math.sqrt(d_ff)
    if k:
        def bc(key_, n_in, n_out):
            keys = jax.random.split(key_, E)
            return jnp.stack([init_block_circulant(kk, n_in, n_out, k)
                              for kk in keys])
        experts = {"up": bc(ks[0], d_model, d_ff),
                   "gate": bc(ks[1], d_model, d_ff),
                   "down": bc(ks[2], d_ff, d_model)}
    else:
        experts = {
            "up": jax.random.normal(ks[0], (E, d_model, d_ff)) * scale_in,
            "gate": jax.random.normal(ks[1], (E, d_model, d_ff)) * scale_in,
            "down": jax.random.normal(ks[2], (E, d_ff, d_model)) * scale_ff,
        }
    p = {"router": jax.random.normal(ks[3], (d_model, E)) * scale_in,
         "experts": experts}
    if moe_cfg.shared_expert:
        p["shared"] = init_mlp(ks[4], d_model, d_ff, comp)
    return p


def _expert_ffn(experts: Dict, xe, activation: str, d_ff: int, d_model: int,
                bc_block: int, mode: str = "train"):
    """xe: (E, cap, d_model) -> (E, cap, d_model), per-expert weights."""
    if bc_block:
        if mode != "train" and "up_cache" in experts:
            # serve: per-expert offline-FFT'd planes (serve/params.py)
            k = bc_block
            spec_fwd = lambda n_out: jax.vmap(
                lambda c, x: bc_matmul_spectral(x, c, k, n_out))
            up = spec_fwd(d_ff)(experts["up_cache"], xe)
            gate = spec_fwd(d_ff)(experts["gate_cache"], xe)
            h = _act(activation, gate) * up
            return spec_fwd(d_model)(experts["down_cache"], h)
        fwd = jax.vmap(lambda w, x: bc_matmul_fft(x, w, d_ff))
        up = fwd(experts["up"], xe)
        gate = fwd(experts["gate"], xe)
        h = _act(activation, gate) * up
        return jax.vmap(lambda w, x: bc_matmul_fft(x, w, d_model))(
            experts["down"], h)
    up = jnp.einsum("ecd,edf->ecf", xe, experts["up"].astype(xe.dtype))
    gate = jnp.einsum("ecd,edf->ecf", xe, experts["gate"].astype(xe.dtype))
    h = _act(activation, gate) * up
    return jnp.einsum("ecf,efd->ecd", h, experts["down"].astype(xe.dtype))


def moe(params, x, *, d_ff: int, moe_cfg, comp=None, activation="silu",
        mode="train"):
    """Grouped top-k token-choice MoE.  x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    E, topk = moe_cfg.num_experts, moe_cfg.top_k
    T = B * S
    g = math.gcd(min(moe_cfg.router_group_size, T), T)  # largest divisor <= cfg
    G = T // g
    cap = max(1, int(math.ceil(g * topk / E * moe_cfg.capacity_factor)))
    cap = min(cap, g)
    if mode == "serve" and S == 1:
        cap = g          # decode is DROPLESS: worst case all tokens one expert
    bc_block = comp.block_for("expert") if comp is not None else 0

    xt = x.reshape(G, g, d)
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, topk)          # (G, g, topk)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)     # (G,g,topk,E)
    flat = onehot.reshape(G, g * topk, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat                # (G, g*topk, E)
    pos = (pos_in_e * flat).sum(-1).reshape(G, g, topk)
    within_cap = pos < cap
    disp = (jax.nn.one_hot(gate_idx, E, dtype=x.dtype)[..., :, None] *
            jax.nn.one_hot(pos, cap, dtype=x.dtype)[..., None, :])  # (G,g,topk,E,cap)
    disp = disp * within_cap[..., None, None].astype(x.dtype)
    comb = disp * gate_vals[..., None, None].astype(x.dtype)
    disp_t = disp.sum(2)                                      # (G,g,E,cap)
    comb_t = comb.sum(2)

    xe = jnp.einsum("gtd,gtec->gecd", xt, disp_t)             # (G,E,cap,d)
    xe = xe.transpose(1, 0, 2, 3).reshape(E, G * cap, d)
    ye = _expert_ffn(params["experts"], xe, activation, d_ff, d, bc_block,
                     mode)
    ye = ye.reshape(E, G, cap, d).transpose(1, 0, 2, 3)       # (G,E,cap,d)
    out = jnp.einsum("gecd,gtec->gtd", ye, comb_t)

    if "shared" in params:
        out = out + mlp(params["shared"], xt, d_ff=d_ff, comp=comp,
                        activation=activation, mode=mode)

    # load-balancing auxiliary loss (Switch-style), returned via aux dict
    density = flat.astype(jnp.float32).mean(1)                # (G, E)
    router_prob = probs.mean(1)                               # (G, E)
    aux = (density * router_prob).sum(-1).mean() * E
    return out.reshape(B, S, d), aux
