"""Attention: GQA/MQA/MHA with RoPE / learned positions, qk-norm, QKV bias,
logit softcap, sliding windows, cross-attention, and KV caches.

Two lowerings of the same math:
  * 'chunked' — pure-XLA two-level online-softmax: a static python loop over
    query chunks, each running a `lax.scan` over exactly the KV chunks its
    causal/window extent needs (no wasted FLOPs on fully-masked blocks, no
    S×S materialization; differentiable for training).
  * 'kernel'  — the Pallas flash kernel (kernels/flash_attention.py).

All models route through `attend()`; projections route through the paper's
`apply_linear`, so block-circulant compression applies to q/k/v/o uniformly.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.circulant import LinearSpec, apply_linear, init_linear
from ..dist.ctx import shard_heads
from ..kernels import ops as kops
from . import norms

_NEG = -1e30


# ---------------------------------------------------------------------------
# Core chunked online-softmax attention
# ---------------------------------------------------------------------------
def _mask(rows, cols, causal: bool, window: int):
    m = jnp.ones(jnp.broadcast_shapes(rows.shape, cols.shape), jnp.bool_)
    if causal:
        m &= cols <= rows
    if window:
        m &= cols > rows - window
    m &= cols >= 0                    # ring-buffer slots not yet written
    return m


def chunked_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                      scale=None, q_pos0=0, kv_positions=None,
                      q_chunk=1024, kv_chunk=1024):
    """q: (B, Sq, Hq, D);  k/v: (B, Skv, Hkv, D)  ->  (B, Sq, Hq, D).

    ``q_pos0``: absolute position of q[:,0] (decode: cache length).  May be
    a per-row ``(B,)`` array (paged decode: every slot sits at its own
    position); the masks then broadcast per row.
    ``kv_positions``: explicit kv absolute positions (ring buffers); default
    is contiguous `arange(Skv)`.  May be ``(B, Skv)`` (paged decode).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    cq = min(q_chunk, Sq)
    ck = min(kv_chunk, Skv)
    nq = -(-Sq // cq)

    qh = q.reshape(B, Sq, Hkv, G, D).transpose(0, 2, 3, 1, 4)   # (B,Hkv,G,Sq,D)
    kh = k.transpose(0, 2, 1, 3)                                 # (B,Hkv,Skv,D)
    vh = v.transpose(0, 2, 1, 3)

    outs = []
    for iq in range(nq):
        q_blk = qh[:, :, :, iq * cq:(iq + 1) * cq].astype(jnp.float32) * scale
        if getattr(q_pos0, "ndim", 0) == 1:               # per-row positions
            rows = q_pos0[:, None] + iq * cq + jnp.arange(q_blk.shape[3])
        else:
            rows = q_pos0 + iq * cq + jnp.arange(q_blk.shape[3])

        # static kv extent for this q chunk (contiguous-position case only)
        if kv_positions is None and causal and not isinstance(q_pos0, jax.Array):
            hi = min(Skv, q_pos0 + (iq + 1) * cq)
        else:
            hi = Skv
        if (kv_positions is None and window
                and not isinstance(q_pos0, jax.Array)):
            lo = max(0, (q_pos0 + iq * cq - window + 1) // ck * ck)
        else:
            lo = 0
        nkv = -(-(hi - lo) // ck)
        pad = nkv * ck - (hi - lo)
        k_blk = jax.lax.slice_in_dim(kh, lo, hi, axis=2)
        v_blk = jax.lax.slice_in_dim(vh, lo, hi, axis=2)
        if pad:
            k_blk = jnp.pad(k_blk, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v_blk = jnp.pad(v_blk, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if kv_positions is None:
            kpos = lo + jnp.arange(nkv * ck)
        elif kv_positions.ndim == 2:                      # (B, Skv) per-row
            kpos = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                           constant_values=-1)
        else:
            kpos = jnp.pad(kv_positions, (0, pad), constant_values=-1)
        kpos = jnp.where(jnp.arange(nkv * ck) < (hi - lo), kpos, -1)

        # (nkv, B, Hkv, ck, D) stacked chunks for the scan
        ks = k_blk.reshape(B, Hkv, nkv, ck, D).transpose(2, 0, 1, 3, 4)
        vs = v_blk.reshape(B, Hkv, nkv, ck, D).transpose(2, 0, 1, 3, 4)
        if kpos.ndim == 2:
            kps = kpos.reshape(B, nkv, ck).transpose(1, 0, 2)
        else:
            kps = kpos.reshape(nkv, ck)

        m0 = jnp.full((B, Hkv, G, q_blk.shape[3]), _NEG, jnp.float32)
        l0 = jnp.zeros_like(m0)
        a0 = jnp.zeros((*m0.shape, D), jnp.float32)

        def body(carry, xs):
            m_p, l_p, acc = carry
            kc, vc, kp = xs
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk,
                           kc.astype(jnp.float32))
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            if rows.ndim == 2 or kp.ndim == 2:            # per-row masking
                r = rows if rows.ndim == 2 else rows[None, :]
                c = kp if kp.ndim == 2 else kp[None, :]
                msk = _mask(r[:, None, None, :, None],
                            c[:, None, None, None, :], causal, window)
            else:
                msk = _mask(rows[:, None], kp[None, :], causal, window)
            s = jnp.where(msk, s, _NEG)
            m_n = jnp.maximum(m_p, s.max(-1))
            p = jnp.exp(s - m_n[..., None])
            p = jnp.where(msk, p, 0.0)
            alpha = jnp.exp(m_p - m_n)
            l_n = l_p * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32))
            return (m_n, l_n, acc), None

        (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kps))
        o = acc / jnp.maximum(l_f, 1e-30)[..., None]
        outs.append(o)

    out = jnp.concatenate(outs, axis=3) if len(outs) > 1 else outs[0]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D).astype(q.dtype)


def attend(q, k, v, *, impl="chunked", **kw):
    if impl == "kernel":
        qt = q.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        o = kops.flash_attention(qt, kt, vt, causal=kw.get("causal", True),
                                 window=kw.get("window", 0),
                                 softcap=kw.get("softcap", 0.0),
                                 scale=kw.get("scale"),
                                 kv_offset=kw.get("q_pos0", 0))
        return o.transpose(0, 2, 1, 3)
    return chunked_attention(q, k, v, **kw)


# ---------------------------------------------------------------------------
# Attention block: projections + rope + cache plumbing
# ---------------------------------------------------------------------------
def init_attention(key, cfg, d_model: int, comp=None) -> Dict:
    a = cfg.attention
    spec = LinearSpec.from_config(comp, "attn", bias=a.qkv_bias)
    ospec = LinearSpec.from_config(comp, "attn")
    ks = jax.random.split(key, 6)
    p = {
        "q": init_linear(ks[0], d_model, a.num_heads * a.head_dim, spec),
        "k": init_linear(ks[1], d_model, a.num_kv_heads * a.head_dim, spec),
        "v": init_linear(ks[2], d_model, a.num_kv_heads * a.head_dim, spec),
        "o": init_linear(ks[3], a.num_heads * a.head_dim, d_model, ospec),
    }
    if a.qk_norm:
        p["qn"] = norms.init_rmsnorm(a.head_dim)
        p["kn"] = norms.init_rmsnorm(a.head_dim)
    return p


def attention_block(params, x, *, cfg, causal=True, window=0,
                    positions=None, cache=None, cache_pos=None,
                    cross_kv=None, mode="train", impl="chunked",
                    q_chunk=1024, kv_chunk=1024,
                    block_table=None,
                    paged_impl="stream") -> Tuple[jax.Array, Optional[Dict]]:
    """Full attention block.  Returns (out, updated_cache).

    cache: {"k": (B, Smax, Hkv, D), "v": ..., "pos": (Smax,) int32} or None.
    cache_pos: scalar absolute position of the first new token (decode).
    cross_kv: precomputed (k, v) from the encoder (cross-attention).

    Paged decode (``block_table`` set): cache is a page POOL
    {"k": (P, page, Hkv, D), "v": ...} shared by every slot;
    ``block_table`` (B, maxp) maps slot positions onto pages and
    ``cache_pos`` is per-slot (B,) — position ``i`` of slot ``b`` lives at
    page ``block_table[b, i // page]``, offset ``i % page``.  A slot with
    ``cache_pos == -1`` is idle: its write routes to the reserved trash
    page 0 and its attention is fully masked (output discarded upstream).

    ``paged_impl`` picks the paged attention lowering: "stream" (default)
    runs the fused paged flash-decode (``kernels.ops.paged_attention`` —
    pages stream through online-softmax, no gathered KV view); "gather"
    keeps the legacy ``paged_gather`` + dense-attention path (the parity
    oracle, O(B * maxp * page) traffic and peak memory per token).
    """
    a = cfg.attention
    comp = cfg.compression
    spec = LinearSpec.from_config(comp, "attn", bias=a.qkv_bias)
    ospec = LinearSpec.from_config(comp, "attn")
    B, S, _ = x.shape
    H, Hkv, D = a.num_heads, a.num_kv_heads, a.head_dim

    fuse = (comp is not None and getattr(comp, "fuse_projections", False)
            and spec.kind == "block_circulant" and cross_kv is None)
    if fuse:
        from ..core.circulant import bc_matmul_fused
        # serve: contract against the offline-FFT'd fused planes when the
        # precompute pass baked them (serve/params.py)
        qkv_cache = params.get("qkv_cache") if mode != "train" else None
        q, k, v = bc_matmul_fused(
            x, [params["q"]["wc"], params["k"]["wc"], params["v"]["wc"]],
            [H * D, Hkv * D, Hkv * D], mode, cache=qkv_cache,
            gauss=spec.gauss)
        if "b" in params["q"]:
            q = q + params["q"]["b"].astype(q.dtype)
            k = k + params["k"]["b"].astype(k.dtype)
            v = v + params["v"]["b"].astype(v.dtype)
        q = q.reshape(B, S, H, D)
        k = k.reshape(B, S, Hkv, D)
        v = v.reshape(B, S, Hkv, D)
    else:
        q = apply_linear(params["q"], x, spec, H * D, mode).reshape(B, S, H, D)
        if cross_kv is not None:
            k, v = cross_kv
        else:
            k = apply_linear(params["k"], x, spec, Hkv * D, mode).reshape(
                B, S, Hkv, D)
            v = apply_linear(params["v"], x, spec, Hkv * D, mode).reshape(
                B, S, Hkv, D)

    if "qn" in params:                                   # qwen3 qk-norm
        q = norms.rmsnorm(params["qn"], q)
        k = norms.rmsnorm(params["kn"], k)

    paged = block_table is not None and cache is not None and cross_kv is None
    q_pos0 = 0 if cache_pos is None else cache_pos
    if paged:
        q_pos0 = jnp.maximum(cache_pos, 0)           # -1 marks idle slots
    if positions is None:
        if getattr(q_pos0, "ndim", 0) == 1:          # per-slot (B,) positions
            positions = q_pos0[:, None] + jnp.arange(S)
        else:
            positions = q_pos0 + jnp.arange(S)
            if positions.ndim == 1:
                positions = jnp.broadcast_to(positions, (B, S))
    if not a.learned_pos and cross_kv is None:
        from .embeddings import apply_rope
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)

    new_cache = None
    kv_positions = None
    streamed = None
    if paged:
        assert S == 1, "paged KV path is decode-only (S == 1)"
        assert not window, "paged KV path serves linear caches only"
        pool_k, pool_v = cache["k"], cache["v"]
        k_sc = v_sc = None
        page = pool_k.shape[1]
        maxp = block_table.shape[1]
        col = jnp.minimum(q_pos0 // page, maxp - 1)
        pid = jnp.where(cache_pos >= 0,
                        block_table[jnp.arange(B), col], 0)   # 0 = trash page
        off = q_pos0 % page
        if "k_scale" in cache:                   # int8 pool (repro.quant):
            from ..quant import codec as qcodec  # per-(page, head) absmax
            pool_k, k_sc = qcodec.page_scatter(  # scatter, requantize-on-grow
                pool_k, cache["k_scale"], pid, off, k[:, 0])
            pool_v, v_sc = qcodec.page_scatter(
                pool_v, cache["v_scale"], pid, off, v[:, 0])
            new_cache = {"k": pool_k, "v": pool_v,
                         "k_scale": k_sc, "v_scale": v_sc}
        else:
            pool_k = pool_k.at[pid, off].set(k[:, 0].astype(pool_k.dtype))
            pool_v = pool_v.at[pid, off].set(v[:, 0].astype(pool_v.dtype))
            new_cache = {"k": pool_k, "v": pool_v}
        if paged_impl == "stream":
            # fused paged flash-decode: pages stream through the online
            # softmax (dequantizing in-register on the int8 lane); the
            # gathered (B, maxp*page, Hkv, D) view is never formed.  Idle
            # slots (cache_pos == -1) come back exactly zero, the same
            # rows the masked gather path produced.
            qd = shard_heads(q[:, 0])
            streamed = shard_heads(kops.paged_attention(
                qd, pool_k, pool_v, block_table, cache_pos,
                softcap=a.logit_softcap, k_scale=k_sc, v_scale=v_sc))[:, None]
        else:
            k = kops.paged_gather(pool_k, block_table)
            v = kops.paged_gather(pool_v, block_table)
            if k_sc is not None:                 # dequantize the gathered
                rep = lambda s: jnp.repeat(     # view: page scales repeat
                    s[block_table], page, axis=1)[..., None]  # per offset
                k = k.astype(jnp.float32) * rep(k_sc)
                v = v.astype(jnp.float32) * rep(v_sc)
            idx = jnp.arange(k.shape[1])[None, :]
            kv_positions = jnp.where(idx <= cache_pos[:, None], idx, -1)
    elif cache is not None and cross_kv is None:
        Smax = cache["k"].shape[1]
        if window and Smax <= window:                    # ring buffer (SWA)
            if S == 1:                                   # decode: single slot
                slot = cache_pos % Smax
                upd = lambda c, new: jax.lax.dynamic_update_slice(
                    c, new.astype(c.dtype), (0, slot, 0, 0))
                kc, vc = upd(cache["k"], k), upd(cache["v"], v)
                pos_c = jax.lax.dynamic_update_slice(
                    cache["pos"], positions[0].astype(cache["pos"].dtype),
                    (slot,))
                new_cache = {"k": kc, "v": vc, "pos": pos_c}
                k, v, kv_positions = kc, vc, pos_c
            else:                                        # prefill: keep tail
                assert S >= Smax, "SWA prefill shorter than window"
                kc = k[:, -Smax:].astype(cache["k"].dtype)
                vc = v[:, -Smax:].astype(cache["v"].dtype)
                pos_c = positions[0][-Smax:].astype(cache["pos"].dtype)
                new_cache = {"k": kc, "v": vc, "pos": pos_c}
        else:                                            # linear cache
            upd = lambda c, new: jax.lax.dynamic_update_slice(
                c, new.astype(c.dtype), (0, cache_pos, 0, 0))
            kc, vc = upd(cache["k"], k), upd(cache["v"], v)
            pos_c = jax.lax.dynamic_update_slice(
                cache["pos"], positions[0].astype(cache["pos"].dtype),
                (cache_pos,))
            new_cache = {"k": kc, "v": vc, "pos": pos_c}
            if S == 1:                                   # decode reads cache
                k, v, kv_positions = kc, vc, pos_c

    if streamed is not None:
        o = streamed
    else:
        o = attend(q, k, v, impl=impl, causal=causal and cross_kv is None,
                   window=window, softcap=a.logit_softcap,
                   q_pos0=q_pos0, kv_positions=kv_positions,
                   q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = apply_linear(params["o"], o.reshape(B, S, H * D), ospec,
                       x.shape[-1], mode)
    return out, new_cache


def init_kv_cache(batch: int, seq: int, cfg, window: int = 0,
                  dtype=jnp.bfloat16) -> Dict:
    a = cfg.attention
    size = min(window, seq) if window else seq
    return {
        "k": jnp.zeros((batch, size, a.num_kv_heads, a.head_dim), dtype),
        "v": jnp.zeros((batch, size, a.num_kv_heads, a.head_dim), dtype),
        "pos": jnp.full((size,), -1, jnp.int32),
    }
