"""Token embeddings, LM head, and rotary position embeddings."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def init_embedding(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, dim), dtype) * (dim ** -0.5)}


def embed(params, tokens, scale_by_dim: bool = False):
    t = params["table"][tokens]
    if scale_by_dim:                       # gemma-style sqrt(d) input scaling
        t = t * (params["table"].shape[-1] ** 0.5)
    return t


def logits(params, x, softcap: float = 0.0):
    """Tied LM head: x @ table.T (+ optional gemma2 final softcap)."""
    out = jnp.einsum("...d,vd->...v", x, params["table"].astype(x.dtype))
    if softcap:
        out = softcap * jnp.tanh(out / softcap)
    return out


def init_learned_pos(key, max_pos: int, dim: int, dtype=jnp.float32):
    return {"pos": jax.random.normal(key, (max_pos, dim), dtype) * 0.02}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S) int32."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    if x.ndim == ang.ndim + 1:                         # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
