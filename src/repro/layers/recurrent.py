"""Recurrent sequence-mixing cells: RG-LRU (RecurrentGemma/Griffin) and
xLSTM's mLSTM / sLSTM.

All cells expose both a *sequence* form (train/prefill: parallel associative
scan or chunkwise recurrence — sub-quadratic, which is why these archs run the
long_500k shape) and a *step* form (decode: O(1) state update).

The cells' in/out projections route through `apply_linear`, so the paper's
block-circulant compression applies; the recurrences themselves are diagonal/
elementwise and have no weight matrix to compress (see DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.circulant import LinearSpec, apply_linear, init_linear


# ---------------------------------------------------------------------------
# RG-LRU (Griffin): h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t * x_t)
# ---------------------------------------------------------------------------
_C = 8.0   # Griffin's fixed recurrence sharpness constant


def init_rglru(key, d_model: int, width: int, comp=None, conv_width: int = 4):
    spec = LinearSpec.from_config(comp, "ffn")
    ks = jax.random.split(key, 6)
    return {
        "in_x": init_linear(ks[0], d_model, width, spec),
        "in_gate": init_linear(ks[1], d_model, width, spec),
        "out": init_linear(ks[2], width, d_model, spec),
        "conv_w": jax.random.normal(ks[3], (conv_width, width)) * 0.1,
        "conv_b": jnp.zeros((width,)),
        # per-channel recurrence parameter Λ, init so a ~ U(0.9, 0.999)
        "lam": jnp.log(jnp.expm1(  # inverse softplus
            -jnp.log(jnp.linspace(0.9, 0.999, width)) / _C)),
        "gate_r": init_linear(ks[4], width, width, spec),
        "gate_i": init_linear(ks[5], width, width, spec),
    }


def _causal_conv1d(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,W); w: (cw, W). state: (B, cw-1, W)."""
    cw = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw)) + b
    new_state = xp[:, -(cw - 1):] if cw > 1 else None
    return out.astype(x.dtype), new_state


def rglru_scan(log_a, gated_x):
    """Parallel linear recurrence via associative scan over (a, b) pairs."""
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 + a2, a2.astype(b1.dtype) * b1 + b2  # log-space decay product
    # work with log(a) for stability; b in linear space
    la, b = jax.lax.associative_scan(
        lambda e1, e2: (e1[0] + e2[0], jnp.exp(e2[0]) * e1[1] + e2[1]),
        (log_a, gated_x), axis=1)
    return b


def rglru_block(params, x, *, width: int, comp=None, mode="train",
                state=None) -> Tuple[jax.Array, Dict]:
    """x: (B, S, d_model). state: {"h": (B,W), "conv": (B,cw-1,W)} or None."""
    spec = LinearSpec.from_config(comp, "ffn")
    B, S, _ = x.shape
    xb = apply_linear(params["in_x"], x, spec, width, mode)
    gate_branch = apply_linear(params["in_gate"], x, spec, width, mode)
    gate_branch = jax.nn.gelu(gate_branch)

    xb, conv_state = _causal_conv1d(
        xb, params["conv_w"], params["conv_b"],
        None if state is None else state["conv"])

    r = jax.nn.sigmoid(apply_linear(params["gate_r"], xb, spec, width, mode)
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(apply_linear(params["gate_i"], xb, spec, width, mode)
                       .astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r          # (B,S,W) f32
    gated = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-9)) * (
        i * xb.astype(jnp.float32))

    if state is not None and "h" in state:
        # fold previous state into the first step: b_0 += a_0 * h_prev
        h_prev = state["h"].astype(jnp.float32)
        first = gated[:, 0] + jnp.exp(log_a[:, 0]) * h_prev
        gated = gated.at[:, 0].set(first)
    h = rglru_scan(log_a, gated)                              # (B,S,W)

    out = h.astype(x.dtype) * gate_branch
    out = apply_linear(params["out"], out, spec, x.shape[-1], mode)
    new_state = {"h": h[:, -1], "conv": conv_state}
    return out, new_state


def init_rglru_state(batch: int, width: int, conv_width: int = 4):
    return {"h": jnp.zeros((batch, width), jnp.float32),
            "conv": jnp.zeros((batch, conv_width - 1, width), jnp.float32)}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM): matrix memory C_t = f_t C_{t-1} + i_t v_t k_t^T, chunkwise.
# ---------------------------------------------------------------------------
def init_mlstm(key, d_model: int, heads: int, proj_factor: float = 2.0,
               comp=None):
    spec = LinearSpec.from_config(comp, "ffn")
    d_in = int(d_model * proj_factor)
    dh = d_in // heads
    ks = jax.random.split(key, 8)
    return {
        "up": init_linear(ks[0], d_model, d_in, spec),
        "up_gate": init_linear(ks[1], d_model, d_in, spec),
        "q": init_linear(ks[2], d_in, d_in, spec),
        "k": init_linear(ks[3], d_in, d_in, spec),
        "v": init_linear(ks[4], d_in, d_in, spec),
        "ifg": jax.random.normal(ks[5], (d_in, 2 * heads)) * (d_in ** -0.5),
        "ifg_b": jnp.concatenate([jnp.zeros((heads,)),
                                  jnp.linspace(3.0, 6.0, heads)]),
        "out": init_linear(ks[6], d_in, d_model, spec),
        "onorm_scale": jnp.ones((d_in,), jnp.float32),
    }


def _mlstm_seq(q, k, v, i_pre, f_pre, state=None, chunk: int = 256):
    """Stabilized chunkwise mLSTM.  q/k/v: (B,H,S,dh); gates (B,H,S) pre-act.

    Within a chunk, outputs use the quadratic masked form; across chunks a
    scan carries (C, n, m).  Equivalent to the step recurrence (tested).
    """
    B, H, S, dh = q.shape
    c = min(chunk, S)
    nc = S // c
    assert nc * c == S
    logf = jax.nn.log_sigmoid(f_pre)                   # (B,H,S)
    logi = i_pre

    qs = q.reshape(B, H, nc, c, dh).transpose(2, 0, 1, 3, 4)
    ks_ = k.reshape(B, H, nc, c, dh).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, H, nc, c, dh).transpose(2, 0, 1, 3, 4)
    lfs = logf.reshape(B, H, nc, c).transpose(2, 0, 1, 3)
    lis = logi.reshape(B, H, nc, c).transpose(2, 0, 1, 3)

    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    scale = dh ** -0.5

    def body(carry, xs):
        C, n, m = carry
        qc, kc, vc, lf, li = xs
        qc = qc.astype(jnp.float32) * scale
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        F = jnp.cumsum(lf, axis=-1)                    # (B,H,c) cumulative logf
        # decay of initial state to position t: exp(F_t); gate of source s->t:
        # exp(F_t - F_s + li_s) for s<=t
        dmat = F[..., :, None] - F[..., None, :] + li[..., None, :]
        tri = jnp.tril(jnp.ones((c, c), bool))
        dmat = jnp.where(tri, dmat, -jnp.inf)
        m_intra = dmat.max(-1)                         # (B,H,c)
        m_inter = F + m[..., None]                     # init-state log decay
        m_new = jnp.maximum(m_intra, m_inter)          # (B,H,c)
        dmat = jnp.exp(dmat - m_new[..., None])
        inter = jnp.exp(m_inter - m_new)               # (B,H,c)
        s_intra = jnp.einsum("bhtd,bhsd->bhts", qc, kc) * dmat
        # C is (v-dim d, k-dim e): contract q with the k index.
        h_num = (jnp.einsum("bhts,bhsd->bhtd", s_intra, vc) +
                 jnp.einsum("bhte,bhde->bhtd", qc, C) * inter[..., None])
        norm = (s_intra.sum(-1) +
                jnp.einsum("bhte,bhe->bht", qc, n) * inter)
        h = h_num / jnp.maximum(jnp.abs(norm),
                                jnp.exp(-m_new))[..., None]
        # carry to next chunk
        Ftot = F[..., -1]
        m_next = jnp.maximum(Ftot + m, (Ftot[..., None] - F + li).max(-1))
        decay_state = jnp.exp(Ftot + m - m_next)
        src = jnp.exp(Ftot[..., None] - F + li - m_next[..., None])
        C_next = (C * decay_state[..., None, None] +
                  jnp.einsum("bhs,bhsd,bhse->bhde", src, vc, kc))
        n_next = n * decay_state[..., None] + jnp.einsum(
            "bhs,bhse->bhe", src, kc)
        return (C_next, n_next, m_next), h

    (C, n, m), hs = jax.lax.scan(body, (C0, n0, m0), (qs, ks_, vs, lfs, lis))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dh)
    return h, (C, n, m)


def mlstm_step(q, k, v, i_pre, f_pre, state):
    """Single-token recurrent step.  q/k/v: (B,H,dh); gates (B,H)."""
    C, n, m = state
    dh = q.shape[-1]
    qf = q.astype(jnp.float32) * dh ** -0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    fg = jnp.exp(logf + m - m_new)
    ig = jnp.exp(i_pre - m_new)
    C_new = C * fg[..., None, None] + ig[..., None, None] * (
        vf[..., :, None] * kf[..., None, :])        # (B,H, v-dim d, k-dim e)
    n_new = n * fg[..., None] + ig[..., None] * kf
    num = jnp.einsum("bhe,bhde->bhd", qf, C_new)    # contract q with k index
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", qf, n_new)),
                        jnp.exp(-m_new))
    h = num / denom[..., None]
    return h, (C_new, n_new, m_new)


def mlstm_block(params, x, *, heads: int, proj_factor: float = 2.0,
                comp=None, mode="train", state=None, chunk: int = 256):
    """Full mLSTM residual block. x: (B,S,d)."""
    spec = LinearSpec.from_config(comp, "ffn")
    B, S, d = x.shape
    d_in = int(d * proj_factor)
    dh = d_in // heads
    up = apply_linear(params["up"], x, spec, d_in, mode)
    gate = jax.nn.silu(apply_linear(params["up_gate"], x, spec, d_in, mode))
    q = apply_linear(params["q"], up, spec, d_in, mode)
    k = apply_linear(params["k"], up, spec, d_in, mode)
    v = apply_linear(params["v"], up, spec, d_in, mode)
    ifg = (up.astype(jnp.float32) @ params["ifg"] + params["ifg_b"])
    i_pre, f_pre = ifg[..., :heads], ifg[..., heads:]         # (B,S,H)

    def to_heads(t):
        return t.reshape(B, S, heads, dh).transpose(0, 2, 1, 3)

    if S == 1 and state is not None:
        h, new_state = mlstm_step(
            to_heads(q)[:, :, 0], to_heads(k)[:, :, 0], to_heads(v)[:, :, 0],
            i_pre[:, 0], f_pre[:, 0], state)
        h = h[:, :, None]
    else:
        h, new_state = _mlstm_seq(to_heads(q), to_heads(k), to_heads(v),
                                  i_pre.transpose(0, 2, 1),
                                  f_pre.transpose(0, 2, 1),
                                  state=state, chunk=chunk)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, d_in)
    # per-head groupnorm-ish: rms over dh
    hf = h.astype(jnp.float32).reshape(B, S, heads, dh)
    hf = hf * (jnp.mean(hf * hf, -1, keepdims=True) + 1e-6) ** -0.5
    h = (hf.reshape(B, S, d_in) * params["onorm_scale"]).astype(x.dtype)
    out = apply_linear(params["out"], h * gate, spec, d, mode)
    return out, new_state


def init_mlstm_state(batch: int, heads: int, dh: int):
    return (jnp.zeros((batch, heads, dh, dh), jnp.float32),
            jnp.zeros((batch, heads, dh), jnp.float32),
            jnp.full((batch, heads), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM: scalar memory with exponential gating — strictly sequential scan.
# ---------------------------------------------------------------------------
def init_slstm(key, d_model: int, heads: int, comp=None):
    spec = LinearSpec.from_config(comp, "ffn")
    ks = jax.random.split(key, 3)
    return {
        "wx": init_linear(ks[0], d_model, 4 * d_model, spec),
        "wh": jax.random.normal(ks[1], (d_model, 4 * d_model)) * (d_model ** -0.5),
        "b": jnp.zeros((4 * d_model,)),
        "out": init_linear(ks[2], d_model, d_model, spec),
    }


def slstm_cell(gates, state):
    """gates: (B, 4d) pre-activations [i f z o]; state: (c, n, h, m)."""
    c, n, h, m = state
    d = c.shape[-1]
    i_pre, f_pre, z_pre, o_pre = jnp.split(gates.astype(jnp.float32), 4, -1)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    ig = jnp.exp(i_pre - m_new)
    fg = jnp.exp(logf + m - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c_new = fg * c + ig * z
    n_new = fg * n + ig
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_block(params, x, *, comp=None, mode="train", state=None):
    spec = LinearSpec.from_config(comp, "ffn")
    B, S, d = x.shape
    gx = apply_linear(params["wx"], x, spec, 4 * d, mode)     # (B,S,4d)
    if state is None:
        z = jnp.zeros((B, d), jnp.float32)
        state = (z, z, z, jnp.full((B, d), -1e30, jnp.float32))

    def body(st, g_t):
        g = g_t + (st[2] @ params["wh"]).astype(jnp.float32) + params["b"]
        st = slstm_cell(g, st)
        return st, st[2]

    state, hs = jax.lax.scan(body, state, gx.swapaxes(0, 1).astype(jnp.float32))
    h = hs.swapaxes(0, 1).astype(x.dtype)                     # (B,S,d)
    out = apply_linear(params["out"], h, spec, d, mode)
    return out, state


def init_slstm_state(batch: int, d_model: int):
    z = jnp.zeros((batch, d_model), jnp.float32)
    return (z, z, z, jnp.full((batch, d_model), -1e30, jnp.float32))
