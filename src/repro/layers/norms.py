"""Normalization layers (f32 statistics regardless of activation dtype)."""
from __future__ import annotations

import jax.numpy as jnp


def init_rmsnorm(dim: int):
    return {"scale": jnp.zeros((dim,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * (var + eps) ** -0.5
    return (y * (1.0 + params["scale"])).astype(x.dtype)


def init_layernorm(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * (var + eps) ** -0.5
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


def init_norm(kind: str, dim: int):
    return init_rmsnorm(dim) if kind == "rmsnorm" else init_layernorm(dim)


def apply_norm(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)
