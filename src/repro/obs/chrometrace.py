"""Chrome-trace (Perfetto) export of a serve run's timeline.

One JSON file in the Chrome Trace Event Format — open it at
https://ui.perfetto.dev (or chrome://tracing) to *look at* what the
registry and TraceStore only aggregate:

* **engine dispatch lanes** (process "engine"): one lane per dispatch
  kind (``prefill_4p``, ``decode_chunk``, ...), slices from the
  profiler's bounded dispatch log, each carrying its roofline fraction
  as args — a slow bucket is visually wider AND redder-on-sort than
  its neighbours.
* **one lane per request** (process "requests"): queue → prefill →
  decode slices derived from the ``RequestTrace`` marks, preemptions as
  thread-scoped instants, terminal status + token counts as args on
  every slice.  Fleet runs label traces with their serving replica;
  each replica's requests group under their own process
  (``requests@r0``, ``requests@r1``, ...) so a failover migration reads
  as the lane jumping processes.
* **counter tracks**: free pages, queue depth, tokens in flight —
  whatever gauges the profiler was asked to ``watch()`` — sampled at
  each dispatch end.

All timestamps are the obs clock (seconds, rebased to engine creation)
scaled to microseconds, so every lane shares one timeline.  The export
is a pure read of state obs already holds — building it after a serve
run costs the run nothing.

Wired behind ``python -m repro.launch.serve ... --trace-out trace.json``.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from .trace import RequestTrace

# Process ids are arbitrary but fixed: lanes group under them in the UI.
PID_ENGINE = 1
PID_REQUESTS = 2

_US = 1e6     # obs clock seconds -> trace microseconds


def _meta(pid: int, name: str, tid: Optional[int] = None,
          sort: Optional[int] = None) -> List[Dict]:
    """process_name / thread_name / sort-index metadata records."""
    out = []
    if tid is None:
        out.append({"ph": "M", "pid": pid, "name": "process_name",
                    "args": {"name": name}})
        if sort is not None:
            out.append({"ph": "M", "pid": pid, "name": "process_sort_index",
                        "args": {"sort_index": sort}})
    else:
        out.append({"ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
                    "args": {"name": name}})
        if sort is not None:
            out.append({"ph": "M", "pid": pid, "tid": tid,
                        "name": "thread_sort_index",
                        "args": {"sort_index": sort}})
    return out


def dispatch_events(profiler) -> List[Dict]:
    """Engine dispatch lanes: one thread per dispatch kind, "X" complete
    slices from the profiler's event log (kind, t0, t1, roofline_frac)."""
    events: List[Dict] = []
    tids: Dict[str, int] = {}
    for kind, t0, t1, frac in profiler.events:
        tid = tids.get(kind)
        if tid is None:
            tid = tids[kind] = len(tids)
        args: Dict = {"dispatch": kind}
        if frac is not None:
            args["roofline_frac"] = round(frac, 6)
            cost = profiler.costs.get(kind)
            if cost is not None:
                args["flops"] = cost.flops
                args["bytes_accessed"] = cost.bytes_accessed
                args["bound"] = cost.bound
        events.append({"ph": "X", "pid": PID_ENGINE, "tid": tid,
                       "name": kind, "cat": "dispatch",
                       "ts": max(t0, 0.0) * _US,
                       "dur": max(t1 - t0, 0.0) * _US,
                       "args": args})
    meta: List[Dict] = []
    for kind, tid in sorted(tids.items(), key=lambda kv: kv[1]):
        meta.extend(_meta(PID_ENGINE, kind, tid=tid, sort=tid))
    return meta + events


def counter_events(profiler) -> List[Dict]:
    """Counter tracks from the profiler's watched-gauge samples.  Chrome
    counters are per-(pid, name); consecutive duplicate samples are
    dropped (the track is a step function anyway)."""
    events: List[Dict] = []
    for name, series in sorted(profiler.samples.items()):
        last = None
        for t, v in series:
            if v == last:
                continue
            last = v
            events.append({"ph": "C", "pid": PID_ENGINE, "name": name,
                           "ts": max(t, 0.0) * _US, "args": {"value": v}})
    return events


def request_events(trace: RequestTrace, tid: Optional[int] = None,
                   pid: int = PID_REQUESTS) -> List[Dict]:
    """One request's lane: a slice between each adjacent pair of present
    lifecycle marks, preemptions as thread-scoped instants.

    Served requests carry all four marks → exactly queue/prefill/decode.
    Unserved terminals span whatever marks exist — a request cancelled in
    queue renders one long "queue" slice ending at its retire — so the
    lane always covers enqueue → retire and the phase names stay honest
    about where the request died.  Every slice carries the terminal
    status and token counts as args.
    """
    tid = trace.order if tid is None else tid
    args = {"order": trace.order, "id": trace.id,
            "status": trace.status or "FINISHED",
            "prompt_len": trace.prompt_len, "decode_len": trace.decode_len}
    if trace.replica is not None:
        args["replica"] = trace.replica
    # adjacent present marks; the slice is named for the phase it opens
    marks = [("queue", trace.enqueue_s), ("prefill", trace.admit_s),
             ("decode", trace.first_token_s), (None, trace.retire_s)]
    present = [(n, t) for n, t in marks if t is not None]
    events: List[Dict] = []
    for (name, t0), (_, t1) in zip(present, present[1:]):
        events.append({"ph": "X", "pid": pid, "tid": tid,
                       "name": name, "cat": "request",
                       "ts": max(t0, 0.0) * _US,
                       "dur": max(t1 - t0, 0.0) * _US,
                       "args": dict(args)})
    for t, recompute in trace.preemptions:
        events.append({"ph": "i", "pid": pid, "tid": tid,
                       "name": "preempt", "cat": "request", "s": "t",
                       "ts": max(t, 0.0) * _US,
                       "args": {"recompute_tokens": recompute}})
    return events


def build_trace(obs, extra_meta: Optional[Dict] = None) -> Dict:
    """Assemble the full trace dict from an ``Obs`` bundle: dispatch lanes
    + counter tracks (profiler) and one lane per completed request
    (TraceStore).  Events are sorted by timestamp (metadata first) so the
    file is monotone — some trace viewers stream it.
    """
    meta = _meta(PID_ENGINE, "engine", sort=0) + \
        _meta(PID_REQUESTS, "requests", sort=1)
    events: List[Dict] = []
    prof = getattr(obs, "profiler", None)
    if prof is not None:
        for ev in dispatch_events(prof):
            (meta if ev["ph"] == "M" else events).append(ev)
        events.extend(counter_events(prof))
    # replica-labelled traces get their own process per replica
    # (requests@r0 = PID_REQUESTS+1, ...); unlabelled stay on "requests"
    replica_pids: Dict[str, int] = {}
    for trace in obs.traces.completed:
        if trace.replica is None:
            pid = PID_REQUESTS
        else:
            pid = replica_pids.get(trace.replica)
            if pid is None:
                pid = PID_REQUESTS + 1 + len(replica_pids)
                replica_pids[trace.replica] = pid
                meta.extend(_meta(pid, f"requests@{trace.replica}",
                                  sort=pid - 1))
        meta.extend(_meta(pid, f"req {trace.order}",
                          tid=trace.order, sort=trace.order))
        events.extend(request_events(trace, pid=pid))
    events.sort(key=lambda e: e["ts"])
    out = {"traceEvents": meta + events, "displayTimeUnit": "ms"}
    if prof is not None:
        out["otherData"] = {"hardware": prof.spec.name}
    if extra_meta:
        out.setdefault("otherData", {}).update(extra_meta)
    return out


def write_trace(obs, path: str, extra_meta: Optional[Dict] = None) -> Dict:
    """Build and write the trace JSON; returns the dict (tests assert on
    it without re-reading the file)."""
    trace = build_trace(obs, extra_meta=extra_meta)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


def validate_trace(trace: Dict) -> None:
    """Schema check for CI smoke: raises ValueError on a malformed trace.

    Asserts the envelope, per-event required keys, non-negative
    monotonically non-decreasing ``ts`` over timed events, and
    non-negative durations.
    """
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace: missing traceEvents envelope")
    last_ts = None
    for i, ev in enumerate(trace["traceEvents"]):
        ph = ev.get("ph")
        if ph not in ("X", "M", "C", "i"):
            raise ValueError(f"trace event {i}: unknown ph {ph!r}")
        if "pid" not in ev or "name" not in ev:
            raise ValueError(f"trace event {i}: missing pid/name")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"trace event {i}: bad ts {ts!r}")
        if last_ts is not None and ts < last_ts:
            raise ValueError(f"trace event {i}: ts {ts} < previous "
                             f"{last_ts} (events must be sorted)")
        last_ts = ts
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"trace event {i}: bad dur {dur!r}")


def main(argv=None) -> int:
    """``python -m repro.obs.chrometrace --validate trace.json`` — CI's
    schema smoke for ``--trace-out`` artifacts."""
    import argparse
    p = argparse.ArgumentParser(prog="repro.obs.chrometrace",
                                description=__doc__.splitlines()[0])
    p.add_argument("--validate", metavar="FILE", required=True,
                   help="chrome-trace JSON file to schema-check")
    p.add_argument("--min-requests", type=int, default=0,
                   help="require at least N request lanes")
    args = p.parse_args(argv)
    with open(args.validate) as f:
        trace = json.load(f)
    validate_trace(trace)
    lanes = {(ev.get("pid"), ev.get("tid")) for ev in trace["traceEvents"]
             if ev.get("cat") == "request" and ev.get("ph") == "X"}
    if len(lanes) < args.min_requests:
        raise SystemExit(f"{args.validate}: {len(lanes)} request lanes "
                         f"< required {args.min_requests}")
    n = len(trace["traceEvents"])
    print(f"{args.validate}: OK ({n} events, {len(lanes)} request lanes)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
