"""CLI entry: ``python -m repro.obs --validate metrics.jsonl``.

Lives here (not in emit.py) so runpy does not re-execute a module the
package ``__init__`` already imported.
"""
from .emit import main

raise SystemExit(main())
