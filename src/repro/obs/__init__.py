"""repro.obs — unified metrics + request-trace telemetry for the serving
stack (docs/observability.md).

``Obs`` is the bundle the engines thread through: one ``Registry``
(counters/gauges/histograms — the backing store of ``Engine.stats()`` and
``ContinuousEngine.stats()``), one ``TraceStore`` (per-request
enqueue→admit→first-token→retire timelines), and an optional step-driven
JSONL ``Emitter`` (``launch/serve.py --metrics-out``).

``enabled=False`` turns the obs layer into its cheap skeleton: counters
and gauges stay live (they ARE ``stats()``, and a dict bump is the legacy
cost), but traces, histograms, emitter ticks, and the quantized-pool
scale reads are skipped — the engines guard those sites on
``obs.enabled``, and ``bench_serving.py`` records the enabled-vs-disabled
tokens/s delta (``obs_overhead``) so the layer's cost stays measured.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

from .emit import Emitter, validate_jsonl, validate_line
from .health import HealthPlane, ShadowOracle
from .metrics import (BYTES_BUCKETS, RATIO_BUCKETS, SECONDS_BUCKETS,
                      Counter, Gauge, Histogram, Registry, ScopedRegistry,
                      prometheus_text)
from .prof import (DispatchCost, Profiler, ScopedProfiler, aot_compile,
                   resolve_hardware)
from .slo import Rule, SloWatchdog, default_rules
from .trace import RequestTrace, TraceStore

__all__ = ["Obs", "Registry", "ScopedRegistry", "Counter", "Gauge",
           "Histogram", "RequestTrace", "TraceStore", "Emitter",
           "validate_line", "validate_jsonl", "SECONDS_BUCKETS",
           "BYTES_BUCKETS", "RATIO_BUCKETS", "Profiler", "ScopedProfiler",
           "DispatchCost", "aot_compile", "resolve_hardware",
           "prometheus_text", "HealthPlane", "ShadowOracle", "Rule",
           "SloWatchdog", "default_rules"]


class Obs:
    """Registry + traces + optional emitter on one rebased monotonic clock."""

    def __init__(self, *, enabled: bool = True,
                 emit_path: Optional[str] = None,
                 emit_callback: Optional[Callable[[Dict], None]] = None,
                 emit_every: int = 10,
                 hardware=None, slo: Optional[SloWatchdog] = None):
        self.enabled = bool(enabled)
        self.registry = Registry()
        self.traces = TraceStore()
        # dispatch-level roofline attribution (obs/prof.py); engines
        # register compiled executables and stamp fenced dispatches —
        # disabled obs keeps the profiler object (wiring stays uniform)
        # but every on_dispatch is a no-op.  ``hardware`` is a
        # roofline.HardwareSpec; None auto-detects the jax backend.
        self.profiler = Profiler(self.registry, hardware=hardware,
                                 enabled=self.enabled)
        self._t0 = time.perf_counter()
        self._labels: Dict[str, str] = {}
        self._owns_emitter = True
        # SLO watchdog (obs/slo.py): bound to the registry so fired
        # alerts bump labelled slo.alerts counters; with an emitter it
        # evaluates on every snapshot flush (alerts become JSONL lines),
        # without one it runs on the same emit_every tick cadence.
        self.slo = slo
        self._slo_ticks = 0
        self._slo_every = max(1, int(emit_every))
        if slo is not None:
            slo.bind(self.registry)
        self.emitter: Optional[Emitter] = None
        if emit_path is not None or emit_callback is not None:
            self.emitter = Emitter(self.registry, self.traces,
                                   path=emit_path, callback=emit_callback,
                                   every=emit_every, clock=self.now,
                                   watchdog=slo)

    def scoped(self, **labels) -> "Obs":
        """A labelled view sharing this Obs's clock, trace store, emitter,
        and dispatch log — the handle each fleet replica's engine gets.
        Metrics created through the view carry the labels (``replica=r0``),
        traces stamp their ``replica`` field, dispatch kinds are prefixed
        per scope, and ``close()`` on a view only flushes (the owning Obs
        closes the shared emitter exactly once — see docs/observability.md).
        """
        view = Obs.__new__(Obs)
        view.enabled = self.enabled
        view.registry = self.registry.scoped(**labels)
        view.traces = self.traces
        view.profiler = ScopedProfiler(self.profiler, labels)
        view._t0 = self._t0
        merged = dict(self._labels)
        merged.update({k: str(v) for k, v in labels.items()})
        view._labels = merged
        view._owns_emitter = False
        view.emitter = self.emitter
        view.slo = self.slo
        view._slo_ticks = 0
        view._slo_every = self._slo_every
        return view

    def now(self) -> float:
        """Seconds on the obs clock (monotonic, 0 at Obs creation)."""
        return time.perf_counter() - self._t0

    def rebase(self, t_perf: float) -> float:
        """A raw ``time.perf_counter()`` stamp on the obs clock — engines
        time spans on perf_counter and rebase the marks they hand to
        traces, so every trace shares one timeline."""
        return t_perf - self._t0

    # -- trace lifecycle (no-ops when disabled) ---------------------------
    def trace_start(self, id: int, order: int, prompt_len: int,
                    enqueue_s: float) -> Optional[RequestTrace]:
        if not self.enabled:
            return None
        return self.traces.start(id, order, prompt_len, enqueue_s,
                                 replica=self._labels.get("replica"))

    def trace_finish(self, trace: Optional[RequestTrace]) -> None:
        """Validate + complete a trace and fold its derived latencies into
        the standard histograms (one definition of TTFT/TPOT everywhere)."""
        if trace is None or not self.enabled:
            return
        self.traces.finish(trace)
        reg = self.registry
        # unserved terminals (rejected/cancelled in queue, ...) lack some
        # marks; fold only the spans their timeline defines
        for name, v in (("trace.queue_s", trace.queue_s),
                        ("trace.ttft_s", trace.ttft_s),
                        ("trace.latency_s", trace.latency_s),
                        ("trace.tpot_s", trace.tpot_s)):
            if v is not None:
                reg.histogram(name).observe(v)

    # -- emitter cadence --------------------------------------------------
    def tick(self) -> None:
        if not self.enabled:
            return
        if self.emitter is not None:
            self.emitter.tick()
            return
        # no emitter: the owning Obs still drives the SLO watchdog on the
        # same cadence (scoped views defer to their owner's ticks)
        if self.slo is not None and self._owns_emitter:
            self._slo_ticks += 1
            if self._slo_ticks % self._slo_every == 0:
                self._slo_observe()

    def baseline(self) -> None:
        """Emit/observe one snapshot NOW — an engine calls this after
        registering its counters so rate/ratio SLO rules measure their
        first window from a true zero baseline.  Without it, any counter
        activity before the first ``emit_every`` tick (e.g. a NaN-guard
        trip in the opening dispatches) lands inside the skipped first
        snapshot and can never fire the anomaly-burst rule."""
        if not self.enabled:
            return
        if self.emitter is not None:
            self.emitter.flush()
        elif self.slo is not None and self._owns_emitter:
            self._slo_observe()

    def _slo_observe(self) -> None:
        snap = {"type": "snapshot", "seq": None, "t_s": self.now()}
        snap.update(self.registry.snapshot())
        self.slo.observe(snap)

    def close(self) -> None:
        """Flush + close the emitter.  A scoped view only flushes — the
        shared emitter belongs to the base Obs, and a replica draining must
        not cut off its fleet-mates' telemetry."""
        if self.emitter is None:
            # emitterless SLO runs still get a final evaluation so the
            # last inter-snapshot window is not silently dropped
            if self.slo is not None and self._owns_emitter:
                self._slo_observe()
            return
        if self._owns_emitter:
            self.emitter.close()
        else:
            self.emitter.flush()

    # -- human-readable exit summary (launch/serve.py) --------------------
    def summary(self) -> str:
        lines = ["metric                              value"]
        snap = self.registry.snapshot()
        for section in ("counters", "gauges"):
            for name, v in snap[section].items():
                val = f"{v:.6g}" if isinstance(v, float) else str(v)
                lines.append(f"{name:<35} {val}")
        for name, h in snap["histograms"].items():
            if not h["count"]:
                continue
            lines.append(
                f"{name:<35} n={h['count']} p50={h['p50']:.4g} "
                f"p99={h['p99']:.4g} max={h['max']:.4g}")
        return "\n".join(lines)
