"""Periodic JSON-lines emitter: registry snapshots + completed traces.

STEP-DRIVEN, not threaded: the engines call ``tick()`` between device
dispatches (the same boundary the scheduler runs on), and every
``every``-th tick flushes one ``snapshot`` line plus one ``trace`` line per
request completed since the last flush.  No background thread means no
locks on the metric hot path and no emitter work racing a dispatch — the
paper's hierarchical-control idiom: telemetry rides the control-plane
cadence the engine already has.

Line schema (every line is one JSON object; docs/observability.md):

  {"type": "snapshot", "seq": n, "t_s": <obs-clock seconds>,
   "counters": {name: float}, "gauges": {name: float},
   "histograms": {name: {buckets, counts, count, sum, min, max, p50, p99}}}

  {"type": "trace", "t_s": ..., **RequestTrace.to_dict()}

  {"type": "alert", "t_s": ..., "rule": ..., "severity": "warn"|"page",
   "series": ..., "value": ..., "threshold": ..., "op": ...}

Snapshots additionally carry an optional ``gauge_marks`` section
(high/low-water marks per gauge); alert lines come from the SLO
watchdog (``obs/slo.py``) when one is attached.

``validate_line`` / ``validate_jsonl`` check the schema (required keys,
numeric types, histogram bucket conservation, trace span ordering) — the
CI emitter smoke runs ``python -m repro.obs.emit --validate metrics.jsonl``
against a real serve run.

The sink is a file path (append, line-buffered flush per batch) or a
callback receiving each line dict (in-process consumers: tests, benches).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Callable, Dict, List, Optional

from .metrics import Registry
from .trace import TraceStore

SNAPSHOT_KEYS = ("type", "seq", "t_s", "counters", "gauges", "histograms")
# SLO watchdog alert lines (obs/slo.py): one record per rule excursion
ALERT_KEYS = ("type", "t_s", "rule", "severity", "series", "value",
              "threshold", "op")
ALERT_SEVERITIES = ("warn", "page")
ALERT_OPS = (">", ">=", "<", "<=")
TRACE_KEYS = ("type", "t_s", "id", "order", "prompt_len", "decode_len",
              "status", "enqueue_s", "admit_s", "first_token_s", "retire_s",
              "queue_s", "ttft_s", "prefill_s", "decode_s", "tpot_s",
              "latency_s", "chunks", "preemptions", "replica")

# Terminal statuses a trace line may carry (serve/scheduler.py defines the
# canonical constants; the emitter validates against the same literals —
# duplicated here so the SCHEMA has no import edge into the serve stack).
# None = legacy served trace (batch-engine lines predating statuses).
TRACE_STATUSES = (None, "FINISHED_EOS", "FINISHED_BUDGET", "TIMEOUT",
                  "CANCELLED", "REJECTED", "FAILED")
# statuses whose timeline must carry all four marks + >=1 decoded token
_SERVED = (None, "FINISHED_EOS", "FINISHED_BUDGET")


class Emitter:
    def __init__(self, registry: Registry, traces: TraceStore, *,
                 path: Optional[str] = None,
                 callback: Optional[Callable[[Dict], None]] = None,
                 every: int = 1, clock: Callable[[], float] = None,
                 watchdog=None):
        if path is None and callback is None:
            raise ValueError("Emitter needs a path or a callback sink")
        self.registry = registry
        self.traces = traces
        self.path = path
        self.callback = callback
        # optional obs.slo.SloWatchdog: evaluated on every snapshot this
        # emitter writes; fired alerts become JSONL lines right behind it
        self.watchdog = watchdog
        self.every = max(1, int(every))
        self.clock = clock or (lambda: 0.0)
        self.ticks = 0
        self.seq = 0
        self.lines_written = 0
        self._file = None
        self._closed = False

    # -- sink -------------------------------------------------------------
    def _write(self, obj: Dict) -> None:
        if self.callback is not None:
            self.callback(obj)
        if self.path is not None:
            if self._file is None:
                self._file = open(self.path, "a")
            self._file.write(json.dumps(obj) + "\n")
        self.lines_written += 1

    # -- cadence ----------------------------------------------------------
    def tick(self) -> None:
        """Engine heartbeat: flush every ``every``-th call."""
        if self._closed:
            return
        self.ticks += 1
        if self.ticks % self.every == 0:
            self.flush()

    def flush(self) -> None:
        """One snapshot line + all traces completed since the last flush."""
        if self._closed:
            return
        t = self.clock()
        snap = {"type": "snapshot", "seq": self.seq, "t_s": t}
        snap.update(self.registry.snapshot())
        self._write(snap)
        self.seq += 1
        if self.watchdog is not None:
            for alert in self.watchdog.observe(snap):
                self._write(alert)
        for tr in self.traces.drain_pending():
            self._write({"type": "trace", "t_s": t, **tr.to_dict()})
        if self._file is not None:
            self._file.flush()

    def close(self) -> None:
        """Final flush, then stop.  Idempotent: a second close (or a tick/
        flush after close — e.g. ``drain()`` called twice) is a no-op
        instead of reopening the file for a duplicate trailing snapshot."""
        if self._closed:
            return
        self.flush()
        self._closed = True
        if self._file is not None:
            self._file.close()
            self._file = None


# ---------------------------------------------------------------------------
# Schema validation (tests + CI emitter smoke)
# ---------------------------------------------------------------------------
def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate_line(obj: Dict) -> None:
    """Raise ValueError unless ``obj`` is a schema-valid emitter line."""
    if not isinstance(obj, dict):
        raise ValueError(f"line is not an object: {obj!r}")
    kind = obj.get("type")
    if kind == "snapshot":
        missing = [k for k in SNAPSHOT_KEYS if k not in obj]
        if missing:
            raise ValueError(f"snapshot missing keys {missing}")
        for section in ("counters", "gauges"):
            for k, v in obj[section].items():
                if not _num(v):
                    raise ValueError(f"{section}[{k}] not numeric: {v!r}")
        for name, h in obj["histograms"].items():
            if len(h["counts"]) != len(h["buckets"]) + 1:
                raise ValueError(f"histogram {name}: {len(h['counts'])} "
                                 f"counts for {len(h['buckets'])} bounds")
            if sum(h["counts"]) != h["count"]:
                raise ValueError(f"histogram {name}: bucket counts "
                                 f"{sum(h['counts'])} != count {h['count']}")
        # optional section (newer emitters): gauge high/low-water marks
        for name, marks in obj.get("gauge_marks", {}).items():
            if not _num(marks.get("max")):
                raise ValueError(f"gauge_marks[{name}]: non-numeric max "
                                 f"{marks!r}")
            mn = marks.get("min")
            if mn is not None and (not _num(mn) or mn > marks["max"]):
                raise ValueError(f"gauge_marks[{name}]: bad min {marks!r}")
    elif kind == "alert":
        missing = [k for k in ALERT_KEYS if k not in obj]
        if missing:
            raise ValueError(f"alert missing keys {missing}")
        if obj["severity"] not in ALERT_SEVERITIES:
            raise ValueError(f"alert {obj['rule']!r}: unknown severity "
                             f"{obj['severity']!r}")
        if obj["op"] not in ALERT_OPS:
            raise ValueError(f"alert {obj['rule']!r}: unknown op "
                             f"{obj['op']!r}")
        for k in ("t_s", "value", "threshold"):
            if not _num(obj[k]):
                raise ValueError(f"alert {obj['rule']!r}: non-numeric "
                                 f"{k} {obj[k]!r}")
        for k in ("rule", "series"):
            if not isinstance(obj[k], str) or not obj[k]:
                raise ValueError(f"alert: bad {k} {obj.get(k)!r}")
    elif kind == "trace":
        missing = [k for k in TRACE_KEYS if k not in obj]
        if missing:
            raise ValueError(f"trace missing keys {missing}")
        status = obj["status"]
        if status not in TRACE_STATUSES:
            raise ValueError(f"trace {obj['order']}: unknown status "
                             f"{status!r}")
        marks = [obj["enqueue_s"], obj["admit_s"], obj["first_token_s"],
                 obj["retire_s"]]
        served = status in _SERVED
        required = marks if served else [marks[0], marks[3]]
        if any(not _num(t) for t in required):
            raise ValueError(f"trace {obj['order']}: non-numeric marks "
                             f"{marks}")
        present = [t for t in marks if t is not None]
        if any(not _num(t) for t in present):
            raise ValueError(f"trace {obj['order']}: non-numeric marks "
                             f"{marks}")
        if any(b < a for a, b in zip(present, present[1:])):
            raise ValueError(f"trace {obj['order']}: span marks out of "
                             f"order: {marks}")
        if served and obj["decode_len"] < 1:
            raise ValueError(f"trace {obj['order']}: retired with "
                             f"decode_len {obj['decode_len']}")
    else:
        raise ValueError(f"unknown line type {kind!r}")


def validate_jsonl(path: str) -> Dict[str, int]:
    """Validate every line of an emitter file; returns line-type counts."""
    counts = {"snapshot": 0, "trace": 0, "alert": 0}
    with open(path) as f:
        for i, line in enumerate(f):
            if not line.strip():
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not JSON: {e}") from e
            try:
                validate_line(obj)
            except ValueError as e:
                raise ValueError(f"{path}:{i + 1}: {e}") from e
            counts[obj["type"]] += 1
    if not counts["snapshot"]:
        raise ValueError(f"{path}: no snapshot lines")
    return counts


def last_snapshot(path: str) -> Dict:
    """The final (cumulative) snapshot line of an emitter file."""
    snap = None
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            obj = json.loads(line)
            if obj.get("type") == "snapshot":
                snap = obj
    if snap is None:
        raise ValueError(f"{path}: no snapshot lines")
    return snap


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Validate an obs emitter JSONL file (CI smoke) or "
                    "render its last snapshot for a Prometheus scrape.")
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--validate", metavar="FILE",
                      help="schema-check every line of FILE")
    mode.add_argument("--to-prom", metavar="FILE",
                      help="print FILE's last snapshot in Prometheus text "
                           "exposition format (docs/observability.md)")
    ap.add_argument("--min-traces", type=int, default=0,
                    help="with --validate: require at least N trace lines")
    args = ap.parse_args(argv)
    if args.to_prom is not None:
        from .metrics import prometheus_text
        sys.stdout.write(prometheus_text(last_snapshot(args.to_prom)))
        return 0
    counts = validate_jsonl(args.validate)
    if counts["trace"] < args.min_traces:
        print(f"[obs.emit] {args.validate}: {counts['trace']} trace lines "
              f"< required {args.min_traces}", file=sys.stderr)
        return 1
    print(f"[obs.emit] {args.validate}: OK "
          f"({counts['snapshot']} snapshots, {counts['trace']} traces, "
          f"{counts['alert']} alerts)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
