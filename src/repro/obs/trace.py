"""Request lifecycle tracing: enqueue → admit → prefill → first-token →
per-chunk-decode → retire, one timeline per request in both engines.

Every timestamp is seconds on ONE monotonic clock (``time.perf_counter``;
the owning ``Obs`` rebases it to its creation).  Span boundaries are taken
only after the engine has fenced the device (``jax.block_until_ready`` /
a host transfer of the dispatch outputs), so spans measure device work,
not dispatch latency — the engines enforce this, the trace just records.

Derived latencies (the serving headline numbers, computed HERE so the
benchmarks and production telemetry share one definition and can never
drift):

* ``queue_s``   = admit − enqueue          (admission wait)
* ``ttft_s``    = first_token − enqueue    (time to first token)
* ``prefill_s`` = first_token − admit      (engine-side prefill span)
* ``decode_s``  = retire − first_token     (decode span)
* ``tpot_s``    = decode_s / (decode_len − 1)   (per-token decode latency;
  None for single-token requests)
* ``latency_s`` = retire − enqueue         (end-to-end)

Ordering is an invariant, not a convention: ``finish`` raises if the
timeline is not ``enqueue ≤ admit ≤ first_token ≤ retire`` (hypothesis-
swept in tests/test_obs.py).

Lifecycle hardening (see docs/serving.md) adds a terminal ``status`` and
``preemptions`` spans.  A request can now go terminal WITHOUT ever being
served — rejected at submit, cancelled or expired in queue, failed at
prefill — so validation is status-aware: the full four-mark timeline is
required only for the served outcomes (``status`` None — the legacy
engines — or ``FINISHED_*``); other terminals require just
``enqueue ≤ retire`` plus ordering over whichever marks exist, and the
derived spans return None when their marks are missing.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class RequestTrace:
    id: int                       # engine Request.id
    order: int                    # submission order (unique per engine)
    prompt_len: int
    enqueue_s: float
    admit_s: Optional[float] = None
    first_token_s: Optional[float] = None
    retire_s: Optional[float] = None
    decode_len: int = 0
    # (t_end_s, new_tokens) per decode dispatch that advanced this request
    chunks: List = dataclasses.field(default_factory=list)
    # terminal status (serve/scheduler.py constants); None = legacy served
    status: Optional[str] = None
    # (t_s, recompute_tokens) per preemption: the request was evicted and
    # re-queued with recompute_tokens to teacher-force through prefill
    preemptions: List = dataclasses.field(default_factory=list)
    # owning replica name (repro.fleet); None = single-engine serve.  Orders
    # are unique per ENGINE, so (replica, order) is the fleet-wide trace key
    # and the Chrome-trace exporter groups request lanes per replica pid.
    replica: Optional[str] = None

    # -- lifecycle marks --------------------------------------------------
    def mark_admit(self, t: float) -> None:
        self.admit_s = float(t)

    def mark_first_token(self, t: float) -> None:
        self.first_token_s = float(t)
        self.decode_len = 1

    def mark_chunk(self, t: float, new_tokens: int) -> None:
        self.chunks.append((float(t), int(new_tokens)))
        self.decode_len += int(new_tokens)

    def mark_preempt(self, t: float, recompute_tokens: int) -> None:
        self.preemptions.append((float(t), int(recompute_tokens)))

    def mark_retire(self, t: float) -> None:
        self.retire_s = float(t)

    @property
    def served(self) -> bool:
        """Did this request run to a normal finish?  Only then is the full
        four-mark timeline guaranteed (status None = legacy engines)."""
        return self.status is None or self.status.startswith("FINISHED")

    # -- derived spans (None when a required mark is missing) -------------
    @property
    def queue_s(self) -> Optional[float]:
        if self.admit_s is None:
            return None
        return self.admit_s - self.enqueue_s

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.enqueue_s

    @property
    def prefill_s(self) -> Optional[float]:
        if self.first_token_s is None or self.admit_s is None:
            return None
        return self.first_token_s - self.admit_s

    @property
    def decode_s(self) -> Optional[float]:
        if self.retire_s is None or self.first_token_s is None:
            return None
        return self.retire_s - self.first_token_s

    @property
    def tpot_s(self) -> Optional[float]:
        if self.decode_len <= 1 or self.decode_s is None:
            return None
        return self.decode_s / (self.decode_len - 1)

    @property
    def latency_s(self) -> Optional[float]:
        if self.retire_s is None:
            return None
        return self.retire_s - self.enqueue_s

    def validate(self) -> None:
        """Span-ordering invariant; raises ValueError on a broken timeline.

        Served traces (status None / FINISHED_*) must carry all four marks.
        Unserved terminals (TIMEOUT / CANCELLED / REJECTED / FAILED) may
        lack admit/first-token — they still need enqueue + retire and
        ordering over the marks they do have."""
        marks = [("enqueue", self.enqueue_s), ("admit", self.admit_s),
                 ("first_token", self.first_token_s),
                 ("retire", self.retire_s)]
        required = (marks if self.served
                    else [marks[0], marks[3]])
        missing = [n for n, t in required if t is None]
        if missing:
            raise ValueError(f"trace {self.order}: missing marks {missing}")
        present = [(n, t) for n, t in marks if t is not None]
        for (an, at), (bn, bt) in zip(present, present[1:]):
            if bt < at:
                raise ValueError(f"trace {self.order}: {bn} ({bt}) before "
                                 f"{an} ({at})")

    def to_dict(self) -> Dict:
        """The emitter's JSONL trace payload (docs/observability.md)."""
        return {
            "id": self.id,
            "order": self.order,
            "prompt_len": self.prompt_len,
            "decode_len": self.decode_len,
            "status": self.status,
            "enqueue_s": self.enqueue_s,
            "admit_s": self.admit_s,
            "first_token_s": self.first_token_s,
            "retire_s": self.retire_s,
            "queue_s": self.queue_s,
            "ttft_s": self.ttft_s,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "tpot_s": self.tpot_s,
            "latency_s": self.latency_s,
            "chunks": [list(c) for c in self.chunks],
            "preemptions": [list(p) for p in self.preemptions],
            "replica": self.replica,
        }


class TraceStore:
    """Active traces by submission order + a bounded completed buffer.

    ``finish`` validates the timeline and moves the trace to ``completed``
    (a deque capped at ``max_completed`` so an emitterless engine cannot
    grow without bound); the emitter drains ``pending`` — traces completed
    since the last flush — without disturbing ``completed`` readers
    (benches iterate ``completed`` post-hoc).
    """

    def __init__(self, max_completed: int = 100_000):
        # keyed (replica, order): a fleet shares one store and every
        # replica's engine numbers its own submissions from zero
        self.active: Dict[tuple, RequestTrace] = {}
        self.completed: Deque[RequestTrace] = deque(maxlen=max_completed)
        self._pending: Deque[RequestTrace] = deque(maxlen=max_completed)

    def start(self, id: int, order: int, prompt_len: int,
              enqueue_s: float, replica: Optional[str] = None
              ) -> RequestTrace:
        tr = RequestTrace(id=id, order=order, prompt_len=prompt_len,
                          enqueue_s=float(enqueue_s), replica=replica)
        self.active[(replica, order)] = tr
        return tr

    def get(self, order: int,
            replica: Optional[str] = None) -> Optional[RequestTrace]:
        return self.active.get((replica, order))

    def finish(self, trace: RequestTrace) -> RequestTrace:
        trace.validate()
        self.active.pop((trace.replica, trace.order), None)
        self.completed.append(trace)
        self._pending.append(trace)
        return trace

    def drain_pending(self) -> List[RequestTrace]:
        out = list(self._pending)
        self._pending.clear()
        return out

    def clear(self) -> None:
        """Drop completed traces (benches call between warm/timed passes)."""
        self.completed.clear()
        self._pending.clear()
