"""Numerics & quality health plane (docs/observability.md).

The paper's algorithm half is a fine-grained accuracy/compression
tradeoff; this module is the runtime's *accuracy* telemetry — the
counterpart to the latency/roofline planes of ``obs.metrics`` /
``obs.prof``.  Two host-side consumers live here:

* ``HealthPlane`` folds the fixed-shape numerics side-outputs the device
  programs in ``serve/decode.py`` return (logit absmax / entropy /
  top1-margin, non-finite counts, per-layer-group activation absmax)
  into labelled histograms.  The engine's binary NaN guard becomes the
  degenerate case: a guard trip always coincides with a
  ``health.nonfinite_*`` bump in the SAME fenced dispatch, so the plane
  surfaces the anomaly at or before NaN-guard retirement by
  construction.
* ``ShadowOracle`` samples a configurable fraction of FINISHED requests
  and teacher-force replays them through the f32 dense-cache oracle
  (reusing ``quant/calibrate.py``'s harness), publishing online
  ``health.greedy_agreement`` / ``health.logit_drift``.  Replays run
  off the hot path — at most one per engine step, between dispatches —
  and the queue is bounded (drops are counted, never blocking).

Import discipline: this module must NOT import ``repro.serve`` or
``repro.quant`` at module scope — ``quant.calibrate`` imports the serve
package, which imports the engine, which imports ``repro.obs`` — so the
calibrate/params imports happen lazily inside ``ShadowOracle`` methods.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Dict, Optional

import numpy as np

from .metrics import RATIO_BUCKETS

# Log-spaced bucket bounds for the numerics plane.  Activation/logit
# absmax for a healthy f32/int8 smoke model lives in O(0.1..100); the
# overflow bucket is the anomaly bin (an exploding datapath marches up
# the buckets before it hits inf — that drift is the alertable signal).
ABSMAX_BUCKETS = (0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
                  100.0, 250.0, 1000.0, 10000.0)
# entropy of a V-way softmax is [0, ln V]; ~11 covers V up to ~60k
ENTROPY_BUCKETS = (0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 6.0,
                   8.0, 11.0)
# top1-top2 logit margin: small margin = low-confidence greedy pick
MARGIN_BUCKETS = (0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
                  25.0, 100.0)
# KV page scales (absmax/qmax of activations) and logit drift magnitudes
SCALE_BUCKETS = (1e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.25, 0.5, 1.0,
                 2.5, 10.0)
DRIFT_BUCKETS = (1e-4, 1e-3, 1e-2, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
                 5.0, 10.0, 50.0)


class HealthPlane:
    """Host-side fold of the device numerics side-outputs.

    ``serve/decode.py`` packs per-dispatch stats as fixed-shape arrays
    (see ``logit_stats`` there for the column layout): the engine
    fences the dispatch, then hands the stats here.  Rows that never
    took a finite step carry their init sentinels and are skipped; rows
    that produced non-finite logits bump the ``health.nonfinite_*``
    counters instead of polluting the histograms.
    """

    def __init__(self, registry):
        self._h = {}
        for phase in ("prefill", "decode"):
            self._h[phase] = {
                "absmax": registry.histogram("health.logit_absmax",
                                             bounds=ABSMAX_BUCKETS,
                                             phase=phase),
                "entropy": registry.histogram("health.logit_entropy",
                                              bounds=ENTROPY_BUCKETS,
                                              phase=phase),
                "margin": registry.histogram("health.top1_margin",
                                             bounds=MARGIN_BUCKETS,
                                             phase=phase),
            }
        self._h_act = registry.histogram("health.act_absmax",
                                         bounds=ABSMAX_BUCKETS,
                                         phase="prefill")
        self._g_act_peak = registry.gauge("health.act_absmax_peak")
        # nonfinite_logits counts bad VALUES; nonfinite_dispatches counts
        # (slot, dispatch) pairs that produced any — the NaN guard retires
        # at most one request per such pair, so dispatches >= guard trips.
        self._c_nonfinite = registry.counter("health.nonfinite_logits")
        self._c_nonfinite_d = registry.counter("health.nonfinite_dispatches")

    # -- folds -------------------------------------------------------------
    def on_prefill(self, stats: Dict) -> None:
        """Fold one prefill dispatch's stats pytree (device arrays OK)."""
        logit = np.asarray(stats["logit"], dtype=np.float64)
        absmax, ent, margin, nonf = (float(x) for x in logit)
        if nonf > 0 or not np.isfinite(absmax):
            self._c_nonfinite.inc(max(nonf, 1.0))
            self._c_nonfinite_d.inc()
        else:
            h = self._h["prefill"]
            h["absmax"].observe(absmax)
            if np.isfinite(ent):
                h["entropy"].observe(ent)
            if np.isfinite(margin):
                h["margin"].observe(margin)
        act = np.asarray(stats["act_absmax"], dtype=np.float64)
        finite = act[np.isfinite(act)]
        self._h_act.observe_many(finite)
        if finite.size:
            self._g_act_peak.set(max(self._g_act_peak.value,
                                     float(finite.max())))

    def on_decode(self, stats: np.ndarray, steps: np.ndarray) -> None:
        """Fold one decode dispatch's ``(B, 4)`` stats (columns 0-2 a
        first-step sample, column 3 the exact per-step non-finite count
        — see ``make_paged_decode_loop``).

        ``steps[b]`` is how many tokens slot ``b`` advanced this
        dispatch (0 for idle/halted slots — their rows are init
        sentinels or stale and are skipped)."""
        stats = np.asarray(stats, dtype=np.float64)
        steps = np.asarray(steps)
        bad = stats[:, 3] > 0
        if bad.any():
            self._c_nonfinite.inc(float(stats[bad, 3].sum()))
            self._c_nonfinite_d.inc(int(bad.sum()))
        h = self._h["decode"]
        rows = stats[steps > 0]
        for col, name in ((0, "absmax"), (1, "entropy"), (2, "margin")):
            v = rows[:, col]
            h[name].observe_many(v[np.isfinite(v)])

    # -- views -------------------------------------------------------------
    @property
    def nonfinite_dispatches(self) -> int:
        return int(self._c_nonfinite_d.value)

    def stats(self) -> Dict:
        return {
            "nonfinite_logits": int(self._c_nonfinite.value),
            "nonfinite_dispatches": int(self._c_nonfinite_d.value),
            "act_absmax_peak": self._g_act_peak.max_seen,
        }


class ShadowOracle:
    """Online quantization-quality sampling against the f32 oracle.

    A fraction ``sample`` of FINISHED requests is enqueued for
    teacher-forced replay: both the f32 dense-cache oracle and the
    serving (quantized paged) path consume the ORACLE's greedy token
    each step, so per-step greedy agreement and logit drift are
    well-defined — the same harness ``quant/calibrate.parity_report``
    runs offline, which is what pins the online numbers to the offline
    ones within measurement noise.

    ``health.greedy_agreement`` is the steps-weighted running mean
    (matching the offline harness's pooled-steps definition);
    ``health.logit_drift`` is the running max."""

    def __init__(self, cfg, raw_params, *, policy, registry,
                 sample: float, seed: int = 0, page_size: int = 4,
                 max_pending: int = 16):
        self.cfg = cfg
        self._raw = raw_params
        self.policy = policy
        self.sample = float(sample)
        self.page_size = int(page_size)
        self.max_pending = int(max_pending)
        self._rng = np.random.RandomState(int(seed))
        self._queue: deque = deque()
        self._runner = None               # lazy: built on first replay
        self._agree_steps = 0.0
        self._agree_sum = 0.0
        self._drift = 0.0
        self._registry = registry
        self._c_sampled = registry.counter("health.shadow_sampled")
        self._c_replays = registry.counter("health.shadow_replays")
        self._c_dropped = registry.counter("health.shadow_dropped")
        # the agreement/drift gauges are created at the FIRST replay, not
        # here: a gauge born at 0.0 would breach the SLO agreement rule
        # (< 0.5) on every snapshot before any replay ran — absent series
        # never fire (obs/slo.py)
        self._g_agree = None
        self._g_drift = None
        self._h_agree = registry.histogram("health.shadow_agreement",
                                           bounds=RATIO_BUCKETS)
        self._h_drift = registry.histogram("health.shadow_drift",
                                           bounds=DRIFT_BUCKETS)

    # -- sampling ----------------------------------------------------------
    @property
    def pending(self) -> int:
        return len(self._queue)

    def maybe_enqueue(self, prompt, new_tokens: int) -> bool:
        """Coin-flip a finished request into the replay queue.  Bounded:
        a full queue drops (counted) rather than backing up the engine."""
        if self.sample <= 0.0 or self._rng.random_sample() >= self.sample:
            return False
        self._c_sampled.inc()
        if len(self._queue) >= self.max_pending:
            self._c_dropped.inc()
            return False
        self._queue.append((np.asarray(prompt), max(int(new_tokens), 1)))
        return True

    # -- replay ------------------------------------------------------------
    def tick(self) -> bool:
        """Replay at most ONE queued request (the engine calls this
        between dispatches — off the hot path)."""
        if not self._queue:
            return False
        self._replay(*self._queue.popleft())
        return True

    def drain(self) -> int:
        """Flush the whole queue (engine drain/generate exit), so short
        runs still publish agreement/drift."""
        n = 0
        while self._queue:
            self._replay(*self._queue.popleft())
            n += 1
        return n

    def _ensure_runner(self):
        if self._runner is None:
            # lazy: calibrate imports the serve package (import cycle note
            # in the module docstring)
            from ..quant.calibrate import ParityRunner
            from ..serve.params import precompute_serving_params
            params_o = precompute_serving_params(self._raw, self.cfg)
            params_q = precompute_serving_params(self._raw, self.cfg,
                                                 self.policy)
            self._runner = ParityRunner(self.cfg, params_o, params_q,
                                        policy=self.policy,
                                        page_size=self.page_size)
        return self._runner

    def _replay(self, prompt: np.ndarray, new_tokens: int) -> None:
        r = self._ensure_runner().run(prompt, new_tokens)
        if self._g_agree is None:
            self._g_agree = self._registry.gauge("health.greedy_agreement")
            self._g_drift = self._registry.gauge("health.logit_drift")
        steps = max(int(r["steps"]), 1)
        self._agree_steps += steps
        self._agree_sum += float(r["greedy_agreement"]) * steps
        self._g_agree.set(self._agree_sum / self._agree_steps)
        self._drift = max(self._drift, float(r["max_logit_drift"]))
        self._g_drift.set(self._drift)
        self._h_agree.observe(float(r["greedy_agreement"]))
        self._h_drift.observe(float(r["max_logit_drift"]))
        self._c_replays.inc()

    # -- views -------------------------------------------------------------
    def stats(self) -> Dict:
        return {
            "sampled": int(self._c_sampled.value),
            "replays": int(self._c_replays.value),
            "dropped": int(self._c_dropped.value),
            "steps": int(self._agree_steps),
            "greedy_agreement": (self._agree_sum / self._agree_steps
                                 if self._agree_steps else None),
            "logit_drift": self._drift if self._agree_steps else None,
        }
