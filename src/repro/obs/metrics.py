"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

The paper's co-optimization loop is an accounting exercise — per-stage
pipeline occupancy and resource utilization decide where the next cycle or
byte goes.  This module is the software analogue's ledger: a tiny,
dependency-free registry the serving stack bumps on its hot path.

Design constraints (why this is not a metrics framework):

* **Zero hot-path allocation.**  ``Counter.inc`` / ``Gauge.set`` are one
  float add / store on an object the caller holds a direct reference to;
  registry lookups (dict + tuple key) happen once, at wiring time.  No
  locks (engines are single-threaded by design — the emitter is
  step-driven, not a thread), no string formatting, no deps.
* **Fixed buckets.**  ``Histogram`` counts into immutable bucket bounds
  chosen at creation (log-spaced defaults for seconds/bytes/ratios), so
  ``observe`` is a bisect + two adds.  Raw values are additionally retained
  (bounded) so ``percentile`` can answer exactly — the benchmarks'
  p50/p99 come from here instead of hand-rolled ``np.percentile`` copies.
* **Snapshot/delta.**  ``Registry.snapshot()`` returns a plain JSON-able
  dict (the emitter's line payload); ``delta`` subtracts two snapshots'
  counters for rate windows.

Metric names are dotted strings (``sched.admitted``); labels are optional
keyword pairs that become part of the metric identity
(``counter("sched.deferred", reason="pages")`` and ``reason="budget"`` are
distinct series).  The flattened name is ``name{k=v,...}`` with labels
sorted — one documented schema shared by every producer (docs/observability.md).
"""
from __future__ import annotations

from bisect import bisect_right
from typing import Dict, List, Optional, Sequence, Tuple

# Log-spaced defaults covering the ranges the serving stack observes.
SECONDS_BUCKETS = (1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2,
                   5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)
BYTES_BUCKETS = tuple(float(10 ** e) for e in range(3, 13))     # 1KB..1TB
RATIO_BUCKETS = tuple(i / 10 for i in range(1, 11))             # 0.1..1.0


def flat_name(name: str, labels: Tuple[Tuple[str, str], ...]) -> str:
    """``name{k=v,...}`` with labels sorted; bare ``name`` when unlabeled."""
    if not labels:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


class Counter:
    """Monotonic accumulator.  ``inc`` rejects negative deltas — counter
    monotonicity is an invariant the tests (and any rate computation
    downstream) rely on."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter decrement ({n}); use a Gauge")
        self.value += n


class Gauge:
    """Point-in-time value (queue depth, free pages); ``set`` overwrites,
    ``max_seen`` / ``min_seen`` track the high/low-water marks for peak and
    headroom telemetry (``min_seen`` is None until the first ``set`` —
    unlike ``max_seen`` it cannot start at 0.0, or a pool that never drains
    would report zero headroom)."""
    __slots__ = ("value", "max_seen", "min_seen")

    def __init__(self):
        self.value = 0.0
        self.max_seen = 0.0
        self.min_seen: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = float(v)
        if v > self.max_seen:
            self.max_seen = float(v)
        if self.min_seen is None or v < self.min_seen:
            self.min_seen = float(v)


class Histogram:
    """Fixed-bucket histogram + exact percentiles from retained values.

    ``bounds`` are upper-inclusive bucket edges; values above the last edge
    land in the implicit overflow bucket (``counts`` has ``len(bounds)+1``
    entries).  Bucket counts serve the emitter (fixed-size, mergeable);
    the raw values (retained up to ``keep``, FIFO) serve ``percentile``,
    which matches ``numpy.percentile``'s default linear interpolation
    exactly — so trace-derived bench numbers cannot drift from the legacy
    computation they replaced.
    """
    __slots__ = ("bounds", "counts", "count", "sum", "min", "max",
                 "_values", "_keep")

    def __init__(self, bounds: Sequence[float] = SECONDS_BUCKETS,
                 keep: int = 100_000):
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(f"bucket bounds must be strictly increasing: "
                             f"{bounds}")
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._values: List[float] = []
        self._keep = int(keep)

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect_right(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v
        if len(self._values) < self._keep:
            self._values.append(v)

    def observe_many(self, vs) -> None:
        """Bulk ``observe`` over a vector (numpy array OK) — state
        identical to looping ``observe``, but the bucketing is one
        ``searchsorted`` instead of a Python bisect-append per element.
        The numerics health plane folds small per-dispatch vectors on
        the serving hot path, where per-element observe() showed up in
        the ``obs_overhead`` bench."""
        import numpy as np
        vs = np.asarray(vs, dtype=np.float64)
        if vs.size == 0:
            return
        for i in np.searchsorted(self.bounds, vs, side="right"):
            self.counts[i] += 1
        self.count += int(vs.size)
        self.sum += float(vs.sum())
        mn, mx = float(vs.min()), float(vs.max())
        if self.min is None or mn < self.min:
            self.min = mn
        if self.max is None or mx > self.max:
            self.max = mx
        room = self._keep - len(self._values)
        if room > 0:
            self._values.extend(float(v) for v in vs[:room])

    def percentile(self, q: float) -> Optional[float]:
        """q-th percentile (0..100), ``numpy.percentile`` linear-interp
        semantics over the retained values; None when empty.  Falls back
        to bucket-edge interpolation if the retention window overflowed
        (counts beyond ``keep`` raw values)."""
        if not self.count:
            return None
        if self.count <= len(self._values):
            vals = sorted(self._values)
            rank = (q / 100.0) * (len(vals) - 1)
            lo = int(rank)
            hi = min(lo + 1, len(vals) - 1)
            return vals[lo] + (vals[hi] - vals[lo]) * (rank - lo)
        # bucket interpolation: the edge below the target cumulative count
        target = (q / 100.0) * self.count
        seen = 0.0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                return (self.bounds[i] if i < len(self.bounds)
                        else self.max)
        return self.max

    @staticmethod
    def of(values: Sequence[float],
           bounds: Sequence[float] = SECONDS_BUCKETS) -> "Histogram":
        """Histogram over a finished value list (the benches' one-shot
        percentile path: ``Histogram.of(lat).percentile(99)``)."""
        h = Histogram(bounds, keep=max(len(values), 1))
        for v in values:
            h.observe(v)
        return h

    def to_dict(self) -> Dict:
        return {
            "buckets": list(self.bounds),
            "counts": list(self.counts),
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
        }


class Registry:
    """Flat namespace of metrics; get-or-create, so wiring is idempotent.

    The same (name, labels, kind) always returns the same object; asking
    for an existing name as a different kind raises (one schema, no
    shadowing).
    """

    def __init__(self):
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                            object] = {}

    def _get(self, kind, name: str, labels: Dict[str, str], **kw):
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        m = self._metrics.get(key)
        if m is None:
            m = kind(**kw)
            self._metrics[key] = m
        elif not isinstance(m, kind):
            raise TypeError(f"metric {flat_name(*key)!r} already registered "
                            f"as {type(m).__name__}, not {kind.__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, bounds: Sequence[float] = SECONDS_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, bounds=bounds)

    # -- views ------------------------------------------------------------
    def items(self):
        for (name, labels), m in sorted(self._metrics.items()):
            yield flat_name(name, labels), m

    def value(self, name: str, **labels) -> float:
        """Current scalar value of a counter/gauge (stats() convenience)."""
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        return self._metrics[key].value

    def snapshot(self) -> Dict:
        """JSON-able view: {"counters": {...}, "gauges": {...},
        "histograms": {name: Histogram.to_dict()},
        "gauge_marks": {name: {"max": ..., "min": ...}}} — the
        high/low-water marks ride along so peak/headroom telemetry
        (``pool.free_pages`` low-water) survives snapshot consumers like
        the Prometheus renderer."""
        out = {"counters": {}, "gauges": {}, "histograms": {},
               "gauge_marks": {}}
        for fname, m in self.items():
            if isinstance(m, Counter):
                out["counters"][fname] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][fname] = m.value
                out["gauge_marks"][fname] = {"max": m.max_seen,
                                             "min": m.min_seen}
            else:
                out["histograms"][fname] = m.to_dict()
        return out

    @staticmethod
    def delta(new: Dict, old: Dict) -> Dict:
        """Counter deltas between two snapshots (rate windows)."""
        oc = old.get("counters", {})
        return {k: v - oc.get(k, 0.0)
                for k, v in new.get("counters", {}).items()}

    def to_prometheus(self) -> str:
        """This registry, right now, in Prometheus text exposition format
        (see ``prometheus_text``)."""
        return prometheus_text(self.snapshot())

    def scoped(self, **labels) -> "ScopedRegistry":
        """A label-scoped view over this registry: every counter/gauge/
        histogram created through the view carries ``labels`` merged into
        its identity.  This is the per-engine metrics-isolation seam — two
        ``ContinuousEngine``s sharing one registry get distinct
        ``tokens{replica=r0}`` / ``tokens{replica=r1}`` series instead of
        cross-contaminating one unlabeled counter (docs/observability.md)."""
        return ScopedRegistry(self, labels)


class ScopedRegistry:
    """Thin label-injecting facade over a base ``Registry``.

    Producers written against the Registry surface (``counter`` /
    ``gauge`` / ``histogram`` / ``value``) work unchanged; the fixed
    labels are merged under any call-site labels (call-site wins on key
    collision, so a scoped producer can still override deliberately).
    Views (``items`` / ``snapshot`` / ``delta`` / ``to_prometheus``)
    delegate to the base registry — the snapshot is the whole process,
    which is what the emitter wants.  Scopes nest: ``scoped()`` on a view
    merges further labels.
    """

    def __init__(self, base: "Registry", labels: Dict[str, object]):
        self.base = base
        self.labels: Dict[str, str] = {k: str(v) for k, v in labels.items()}

    def _merged(self, labels: Dict[str, object]) -> Dict[str, str]:
        merged = dict(self.labels)
        merged.update({k: str(v) for k, v in labels.items()})
        return merged

    def counter(self, name: str, **labels) -> Counter:
        return self.base.counter(name, **self._merged(labels))

    def gauge(self, name: str, **labels) -> Gauge:
        return self.base.gauge(name, **self._merged(labels))

    def histogram(self, name: str, bounds: Sequence[float] = SECONDS_BUCKETS,
                  **labels) -> Histogram:
        return self.base.histogram(name, bounds=bounds,
                                   **self._merged(labels))

    def value(self, name: str, **labels) -> float:
        return self.base.value(name, **self._merged(labels))

    def scoped(self, **labels) -> "ScopedRegistry":
        return ScopedRegistry(self.base, self._merged(labels))

    # whole-process views (the emitter snapshots everything)
    def items(self):
        return self.base.items()

    def snapshot(self) -> Dict:
        return self.base.snapshot()

    @staticmethod
    def delta(new: Dict, old: Dict) -> Dict:
        return Registry.delta(new, old)

    def to_prometheus(self) -> str:
        return self.base.to_prometheus()


# ---------------------------------------------------------------------------
# Prometheus text exposition (no client library — the format is 14 lines)
# ---------------------------------------------------------------------------
def _prom_split(fname: str) -> Tuple[str, List[Tuple[str, str]]]:
    """Flattened ``name{k=v,...}`` -> (prometheus_name, label pairs).
    Dots (our namespace separator) become underscores — Prometheus metric
    names admit ``[a-zA-Z_:][a-zA-Z0-9_:]*``."""
    labels: List[Tuple[str, str]] = []
    if "{" in fname:
        fname, _, rest = fname.partition("{")
        for pair in rest.rstrip("}").split(","):
            k, _, v = pair.partition("=")
            labels.append((k, v))
    return fname.replace(".", "_").replace("-", "_"), labels


def _prom_labels(labels: List[Tuple[str, str]]) -> str:
    if not labels:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + body + "}"


def prometheus_text(snapshot: Dict) -> str:
    """Render a ``Registry.snapshot()`` dict in Prometheus text exposition
    format (version 0.0.4): counters as ``<name>_total``, gauges verbatim,
    histograms as cumulative ``_bucket{le=...}`` series (including the
    ``+Inf`` overflow) plus ``_sum`` and ``_count``.

    Operating on the *snapshot* (not the live registry) means the JSONL
    sidecar can feed a scrape pipeline after the fact:
    ``python -m repro.obs --to-prom metrics.jsonl`` renders the last
    snapshot line of a serve run.  ``# TYPE`` headers are emitted once per
    metric family, series grouped under them, families sorted by name.
    """
    families: Dict[str, Dict] = {}

    def fam(pname: str, ptype: str) -> List[str]:
        f = families.setdefault(pname, {"type": ptype, "lines": []})
        if f["type"] != ptype:
            raise ValueError(f"metric family {pname!r} seen as both "
                             f"{f['type']} and {ptype}")
        return f["lines"]

    for fname, v in snapshot.get("counters", {}).items():
        pname, labels = _prom_split(fname)
        pname += "_total"
        fam(pname, "counter").append(f"{pname}{_prom_labels(labels)} {v!r}")
    for fname, v in snapshot.get("gauges", {}).items():
        pname, labels = _prom_split(fname)
        fam(pname, "gauge").append(f"{pname}{_prom_labels(labels)} {v!r}")
    # gauge high/low-water marks as companion series: max_seen/min_seen
    # would otherwise be dropped on the Prometheus path (a scrape only
    # sees point-in-time values — pool.free_pages low-water matters)
    for fname, marks in snapshot.get("gauge_marks", {}).items():
        pname, labels = _prom_split(fname)
        ls = _prom_labels(labels)
        fam(pname + "_max", "gauge").append(
            f"{pname}_max{ls} {float(marks['max'])!r}")
        if marks.get("min") is not None:
            fam(pname + "_min", "gauge").append(
                f"{pname}_min{ls} {float(marks['min'])!r}")
    for fname, h in snapshot.get("histograms", {}).items():
        pname, labels = _prom_split(fname)
        lines = fam(pname, "histogram")
        cum = 0
        for bound, c in zip(h["buckets"], h["counts"]):
            cum += c
            ls = _prom_labels(labels + [("le", repr(float(bound)))])
            lines.append(f"{pname}_bucket{ls} {cum}")
        ls = _prom_labels(labels + [("le", "+Inf")])
        lines.append(f"{pname}_bucket{ls} {h['count']}")
        lines.append(f"{pname}_sum{_prom_labels(labels)} {h['sum']!r}")
        lines.append(f"{pname}_count{_prom_labels(labels)} {h['count']}")

    out: List[str] = []
    for pname in sorted(families):
        f = families[pname]
        out.append(f"# TYPE {pname} {f['type']}")
        out.extend(f["lines"])
    return "\n".join(out) + ("\n" if out else "")
