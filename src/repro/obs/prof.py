"""Dispatch-level roofline attribution for the serving engines.

The paper's co-optimization loop budgets every pipeline stage against the
hardware's peak rates; CirCNN's FPGA pipeline is costed stage-by-stage the
same way.  `repro.obs` (PR 6) gave the engines wall-clock spans and
`repro.roofline` gave the dry-run static cost cells — this module connects
them: every engine dispatch kind (per-bucket prefill, ``decode_chunk``)
carries the FLOP and byte counts of its *compiled executable*, captured
ONCE at compile time via ``roofline.CompiledCompat``'s normalized
``cost_analysis()``, and every fenced dispatch then derives

    achieved FLOP/s   = flops / dt
    achieved bytes/s  = bytes_accessed / dt
    roofline fraction = bound_s / dt,   bound_s = max(flops / peak_FLOP/s,
                                                      bytes / HBM_bw)

against a ``roofline.HardwareSpec`` (host-CPU default, TPU presets).  A
fraction of 1.0 means the dispatch ran exactly at the spec's roofline for
its arithmetic intensity; serving dispatches on the host backend sit far
below it, and the *ratio between kinds* (prefill vs decode, bucket vs
bucket) is the attribution signal the one-dispatch-megakernel work needs.

Everything lands in the owning ``Obs`` registry —
``prof.flops_per_s{dispatch=...}`` / ``prof.bytes_per_s{dispatch=...}`` /
``prof.roofline_frac{dispatch=...}`` histograms — so ``stats()`` and the
JSONL emitter surface it with no extra plumbing.  The profiler also keeps
a bounded DISPATCH LOG of (kind, start, end) marks on the obs clock plus
per-dispatch samples of watched gauges (queue depth, free pages): the raw
material `obs/chrometrace.py` renders into Perfetto lanes and counter
tracks.

Cost: one ``cost_analysis()`` per compile (off the hot path), and per
dispatch three histogram observes + one deque append — skipped entirely
when ``Obs(enabled=False)``, so the paired ``obs_overhead`` budget
(<1 % tokens/s, BENCH_serving.json) still holds.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..roofline.analysis import (HARDWARE_PRESETS, HardwareSpec,
                                 CompiledCompat, detect_hardware)
from .metrics import Gauge, Registry, flat_name

# Log-spaced FLOP/s + bytes/s buckets covering host CPUs through TPU pods.
RATE_BUCKETS = tuple(float(10 ** e) for e in range(6, 16))     # 1e6..1e15
# Roofline fractions: log-spaced below 1.0 (host backends sit way down
# here), the overflow bucket catches >1.0 (spec pessimistic for the shape).
FRAC_BUCKETS = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 0.05, 0.1, 0.25, 0.5,
                0.75, 1.0)


@dataclasses.dataclass(frozen=True)
class DispatchCost:
    """Static cost of one compiled executable, captured at compile time.

    ``bound_s`` is the roofline-limited runtime on the profiler's
    ``HardwareSpec`` — the larger of the compute and memory terms — and
    ``bound`` names which side limits (ridge-point comparison)."""
    kind: str
    flops: float
    bytes_accessed: float
    t_compute_s: float
    t_memory_s: float

    @property
    def bound_s(self) -> float:
        return max(self.t_compute_s, self.t_memory_s)

    @property
    def bound(self) -> str:
        return ("compute" if self.t_compute_s >= self.t_memory_s
                else "memory")

    @property
    def intensity(self) -> float:
        """Arithmetic intensity (FLOPs per HBM byte)."""
        return self.flops / max(self.bytes_accessed, 1.0)


class Profiler:
    """Per-dispatch roofline accounting into a ``repro.obs`` Registry.

    ``register(kind, compiled)`` runs once per compile and returns the
    ``DispatchCost`` handle the engine keeps next to the executable;
    ``on_dispatch(cost, t0, t1)`` runs once per fenced dispatch with marks
    on the obs clock.  Dispatch *kinds* are the attribution unit: the
    continuous engine registers ``prefill_{n}p`` per page bucket and one
    ``decode_chunk``; the batch engine tags its shapes
    (``prefill_b{B}_s{S}``, ``decode_loop_s{steps}_b{B}``).
    """

    def __init__(self, registry: Registry, *,
                 hardware: Optional[HardwareSpec] = None,
                 enabled: bool = True, keep_events: int = 100_000):
        self.registry = registry
        self.spec = hardware if hardware is not None else detect_hardware()
        self.enabled = bool(enabled)
        self.costs: Dict[str, DispatchCost] = {}
        # (kind, t_start_s, t_end_s, roofline_frac|None) on the obs clock —
        # bounded, FIFO; the Chrome-trace exporter's dispatch lanes
        self.events: deque = deque(maxlen=int(keep_events))
        # gauge samples taken at each dispatch end: name -> [(t_s, value)]
        self.samples: Dict[str, List[Tuple[float, float]]] = {}
        self._watched: List[Tuple[str, Gauge]] = []
        self._hists: Dict[str, Tuple] = {}

    # -- wiring (compile time / engine init) ------------------------------
    def register(self, kind: str, compiled) -> DispatchCost:
        """Capture a compiled executable's static cost under ``kind``.

        ``cost_analysis()`` is normalized across jax versions by
        ``roofline.CompiledCompat``.  Re-registering a kind (the batch
        engine recompiles per shape) overwrites the static cost; the
        histograms accumulate across shapes of the kind.
        """
        ca = CompiledCompat(compiled).cost_analysis()
        flops = float(ca.get("flops", 0.0))
        nbytes = float(ca.get("bytes accessed", 0.0))
        cost = DispatchCost(
            kind=kind, flops=flops, bytes_accessed=nbytes,
            t_compute_s=flops / self.spec.peak_flops,
            t_memory_s=nbytes / self.spec.hbm_bw)
        self.costs[kind] = cost
        if kind not in self._hists:
            reg = self.registry
            self._hists[kind] = (
                reg.histogram("prof.flops_per_s", bounds=RATE_BUCKETS,
                              dispatch=kind),
                reg.histogram("prof.bytes_per_s", bounds=RATE_BUCKETS,
                              dispatch=kind),
                reg.histogram("prof.roofline_frac", bounds=FRAC_BUCKETS,
                              dispatch=kind),
            )
        return cost

    def watch(self, name: str, **labels) -> None:
        """Sample a registry gauge at every dispatch end (Chrome-trace
        counter tracks: queue depth, free pages, tokens in flight)."""
        if not self.enabled:
            return
        gauge = self.registry.gauge(name, **labels)
        key = flat_name(name, tuple(sorted(
            (k, str(v)) for k, v in labels.items())))
        if all(k != key for k, _ in self._watched):
            self._watched.append((key, gauge))
            self.samples.setdefault(key, [])

    # -- hot path (once per fenced dispatch) ------------------------------
    def on_dispatch(self, cost: Optional[DispatchCost], t0_s: float,
                    t1_s: float) -> None:
        """Record one fenced dispatch: ``t0_s``/``t1_s`` are obs-clock
        marks stamped around the device program (the engines fence with
        ``block_until_ready`` before ``t1``).  ``cost`` None (AOT capture
        unavailable) still logs the timeline event, just uncosted."""
        if not self.enabled:
            return
        frac = None
        if cost is not None:
            dt = max(t1_s - t0_s, 1e-9)
            h_flops, h_bytes, h_frac = self._hists[cost.kind]
            h_flops.observe(cost.flops / dt)
            h_bytes.observe(cost.bytes_accessed / dt)
            frac = cost.bound_s / dt
            h_frac.observe(frac)
            kind = cost.kind
        else:
            kind = "uncosted"
        self.events.append((kind, t0_s, t1_s, frac))
        for key, gauge in self._watched:
            self.samples[key].append((t1_s, gauge.value))

    # -- views ------------------------------------------------------------
    def summary(self) -> Dict[str, Dict]:
        """Per-dispatch-kind achieved rates for ``stats()``: static cost,
        dispatch count, mean/percentile achieved FLOP/s + bytes/s, and the
        roofline fraction against ``self.spec``."""
        out: Dict[str, Dict] = {}
        for kind, cost in sorted(self.costs.items()):
            h_flops, h_bytes, h_frac = self._hists[kind]
            n = h_frac.count
            out[kind] = {
                "dispatches": n,
                "flops": cost.flops,
                "bytes_accessed": cost.bytes_accessed,
                "intensity_flops_per_byte": cost.intensity,
                "bound": cost.bound,
                "bound_s": cost.bound_s,
                "achieved_flops_per_s": (h_flops.sum / n) if n else None,
                "achieved_bytes_per_s": (h_bytes.sum / n) if n else None,
                "roofline_frac": (h_frac.sum / n) if n else None,
                "roofline_frac_p50": h_frac.percentile(50),
                "roofline_frac_max": h_frac.max,
            }
        return out


class ScopedProfiler:
    """Label-scoped facade over a shared ``Profiler`` (``Obs.scoped``).

    A fleet of engines shares one profiler (one dispatch log, one Chrome
    trace) but each engine's view prefixes its dispatch *kinds*
    (``r0:decode_chunk``) and labels its watched gauges, so per-replica
    attribution falls out of the same machinery single-engine serving
    uses.  ``summary()`` filters to this scope's kinds — a replica's
    ``stats()['roofline']`` shows only its own dispatches.
    """

    def __init__(self, base: Profiler, labels: Dict[str, str]):
        self.base = base
        self.labels = {k: str(v) for k, v in labels.items()}
        self.prefix = ",".join(v for _, v in sorted(self.labels.items()))

    @property
    def spec(self) -> HardwareSpec:
        return self.base.spec

    @property
    def enabled(self) -> bool:
        return self.base.enabled

    @property
    def events(self):
        return self.base.events

    @property
    def costs(self):
        return self.base.costs

    @property
    def samples(self):
        return self.base.samples

    def _kind(self, kind: str) -> str:
        return f"{self.prefix}:{kind}" if self.prefix else kind

    def register(self, kind: str, compiled) -> DispatchCost:
        return self.base.register(self._kind(kind), compiled)

    def watch(self, name: str, **labels) -> None:
        merged = dict(self.labels)
        merged.update({k: str(v) for k, v in labels.items()})
        self.base.watch(name, **merged)

    def on_dispatch(self, cost: Optional[DispatchCost], t0_s: float,
                    t1_s: float) -> None:
        self.base.on_dispatch(cost, t0_s, t1_s)

    def summary(self) -> Dict[str, Dict]:
        if not self.prefix:
            return self.base.summary()
        pre = self.prefix + ":"
        return {k[len(pre):]: v for k, v in self.base.summary().items()
                if k.startswith(pre)}


# ---------------------------------------------------------------------------
# AOT capture: compile once, profile forever
# ---------------------------------------------------------------------------
def aot_compile(jitfn, args: Sequence, profiler: Optional[Profiler],
                kind: str) -> Tuple[Callable, Optional[DispatchCost]]:
    """Lower + compile a ``jax.jit`` function for concrete ``args`` and
    register the executable's cost under ``kind``.

    The returned callable is the compiled executable itself — calling it is
    the same one-compile cost path ``jitfn(*args)`` would have taken, but
    the engine now holds the object whose ``cost_analysis()`` the profiler
    read (donation hints survive ``lower``).  If AOT lowering fails (an
    exotic backend / jax version), the jit wrapper is returned unchanged
    and the dispatch kind simply goes uncosted — profiling must never take
    the serving path down.
    """
    try:
        compiled = jitfn.lower(*args).compile()
    except Exception:                                  # pragma: no cover
        return jitfn, None
    cost = None
    if profiler is not None:
        try:
            cost = profiler.register(kind, compiled)
        except Exception:                              # pragma: no cover
            cost = None
    return compiled, cost


def resolve_hardware(name: Optional[str]) -> HardwareSpec:
    """CLI helper: preset by name, ``None``/"auto" detects the backend."""
    if name is None or name == "auto":
        return detect_hardware()
    try:
        return HARDWARE_PRESETS[name]
    except KeyError:
        raise ValueError(f"unknown hardware preset {name!r}: expected one "
                         f"of {sorted(HARDWARE_PRESETS)} or 'auto'")
