"""Declarative SLO watchdog over registry snapshots (docs/observability.md).

A ``Rule`` names a metric pattern (fnmatch over the FLAT series names,
so one rule covers ``engine.anomalies`` and every
``engine.anomalies{replica=rN}``), how to read an observation out of a
snapshot (``kind``), a threshold predicate, and a multi-window
burn-rate condition: the rule fires for a series only when, for EVERY
window ``(n, frac)``, at least ``frac`` of the last ``n`` observations
breach the predicate AND the window is full.  The classic long+short
pairing means a sustained burn alerts while a single flapping snapshot
does not; a latch emits one alert per excursion (re-armed when the
breach clears) instead of one per snapshot.

Observation kinds:

* ``gauge`` / ``counter`` — the series' snapshot value.
* ``histogram`` — a field of the histogram dict (default ``p99``).
* ``rate`` — the counter's delta since the previous snapshot (first
  snapshot contributes no observation).
* ``ratio`` — this counter's delta over ``denom``'s delta, the
  denominator resolved with the SAME labels as the numerator series
  (falling back to the unlabelled denominator); windows with no
  denominator progress contribute no observation.

Alerts are JSONL records (``{"type": "alert", ...}`` — schema in
``obs/emit.py``); the ``Emitter`` evaluates the watchdog on every
snapshot it writes and appends the fired alerts right behind it.  When
bound to a registry, each fired alert also bumps a ``slo.alerts``
counter carrying the offending series' labels — that is the hook
``fleet/replica.py`` consumes: a replica-labelled alert degrades that
replica's health score.

CLI (CI-friendly exit codes)::

    python -m repro.obs.slo METRICS.jsonl [--rules RULES.json]
                                          [--fail-on page|warn]

re-evaluates the rules over the file's snapshot sequence; exit 0 when
no alert at/above the failure severity fired, 1 when one did, 2 on
malformed input.
"""
from __future__ import annotations

import argparse
import dataclasses
import fnmatch
import json
import sys
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

from .metrics import flat_name

SEVERITIES = ("warn", "page")
OPS = {
    ">": lambda v, t: v > t,
    ">=": lambda v, t: v >= t,
    "<": lambda v, t: v < t,
    "<=": lambda v, t: v <= t,
}
ALERT_KEYS = ("type", "t_s", "rule", "severity", "series", "value",
              "threshold", "op")


@dataclasses.dataclass(frozen=True)
class Rule:
    """One declarative SLO rule (see the module docstring for kinds and
    burn-window semantics)."""
    name: str
    metric: str                      # fnmatch pattern over flat series names
    kind: str = "gauge"              # gauge | counter | histogram | rate | ratio
    field: str = "p99"               # histogram field to read
    op: str = ">"
    threshold: float = 0.0
    denom: Optional[str] = None      # ratio: denominator counter base name
    windows: Tuple[Tuple[int, float], ...] = ((1, 1.0),)
    severity: str = "page"

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"rule {self.name!r}: unknown op {self.op!r}")
        if self.severity not in SEVERITIES:
            raise ValueError(f"rule {self.name!r}: severity "
                             f"{self.severity!r} not in {SEVERITIES}")
        if self.kind not in ("gauge", "counter", "histogram", "rate",
                             "ratio"):
            raise ValueError(f"rule {self.name!r}: unknown kind "
                             f"{self.kind!r}")
        if self.kind == "ratio" and not self.denom:
            raise ValueError(f"rule {self.name!r}: ratio needs a denom")
        if not self.windows:
            raise ValueError(f"rule {self.name!r}: needs >=1 window")
        for n, frac in self.windows:
            if n < 1 or not (0.0 < frac <= 1.0):
                raise ValueError(f"rule {self.name!r}: bad window "
                                 f"({n}, {frac})")


def default_rules() -> Tuple[Rule, ...]:
    """The stock ruleset (docs/observability.md "SLO rules").  Thresholds
    are deliberately generous — they pass a healthy smoke serve and fire
    on the failure modes the chaos/CI gates inject (anomaly bursts,
    poisoned drift/agreement)."""
    return (
        # any NaN-guard trip between two snapshots is an instant page —
        # the window (1, 1.0) makes the anomaly rate rule the degenerate
        # "NaN guard" case of the burn framework
        Rule("anomaly-burst", metric="engine.anomalies*", kind="rate",
             op=">", threshold=0.0, windows=((1, 1.0),), severity="page"),
        # quality burn: online shadow-oracle drift/agreement (gauges only
        # exist when --shadow-sample is on; absent series never fire)
        Rule("logit-drift", metric="health.logit_drift*", kind="gauge",
             op=">", threshold=10.0, windows=((2, 1.0),), severity="page"),
        Rule("greedy-agreement", metric="health.greedy_agreement*",
             kind="gauge", op="<", threshold=0.5, windows=((2, 1.0),),
             severity="page"),
        # latency SLO: TTFT p99 sustained over 30s for 3 snapshots
        Rule("ttft-p99", metric="trace.ttft_s*", kind="histogram",
             field="p99", op=">", threshold=30.0, windows=((3, 1.0),),
             severity="page"),
        # goodput stall: no decoded tokens across a long+short window pair
        Rule("goodput-stall", metric="tokens", kind="rate", op="<=",
             threshold=0.0, windows=((8, 1.0), (4, 1.0)),
             severity="warn"),
        # KV write saturation: >50% of page-write values at the int8 rail
        Rule("kv-clip-rate", metric="quant.clip.kv_clipped*", kind="ratio",
             denom="quant.clip.kv_total", op=">", threshold=0.5,
             windows=((3, 1.0),), severity="warn"),
    )


def rules_from_json(path: str) -> Tuple[Rule, ...]:
    """Load rules from a JSON list of Rule-field dicts."""
    with open(path) as f:
        raw = json.load(f)
    if not isinstance(raw, list):
        raise ValueError(f"{path}: expected a JSON list of rule objects")
    rules = []
    for obj in raw:
        obj = dict(obj)
        if "windows" in obj:
            obj["windows"] = tuple((int(n), float(f))
                                   for n, f in obj["windows"])
        rules.append(Rule(**obj))
    return tuple(rules)


def _split_series(fname: str) -> Tuple[str, Dict[str, str]]:
    """Flat ``name{k=v,...}`` -> (base name, labels dict)."""
    if "{" not in fname:
        return fname, {}
    base, _, rest = fname.partition("{")
    labels = {}
    for pair in rest.rstrip("}").split(","):
        k, _, v = pair.partition("=")
        labels[k] = v
    return base, labels


class SloWatchdog:
    """Feed snapshots in emission order via ``observe``; fired alerts
    come back as JSONL-ready dicts (and accumulate on ``.alerts``)."""

    def __init__(self, rules: Optional[Sequence[Rule]] = None,
                 registry=None):
        self.rules: Tuple[Rule, ...] = (tuple(rules) if rules is not None
                                        else default_rules())
        self._registry = registry
        self._hist: Dict[Tuple[str, str], deque] = {}
        self._active: Dict[Tuple[str, str], bool] = {}
        self._prev_counters: Optional[Dict[str, float]] = None
        self.alerts: List[Dict] = []

    def bind(self, registry) -> None:
        """Attach the registry whose ``slo.alerts`` counters fired alerts
        bump (labels copied from the offending series)."""
        self._registry = registry

    # -- observation extraction -------------------------------------------
    def _observations(self, rule: Rule, snap: Dict) -> Dict[str, float]:
        """{series flat name: observation value} for one snapshot."""
        out: Dict[str, float] = {}
        counters = snap.get("counters", {})
        if rule.kind in ("gauge", "counter"):
            section = snap.get("gauges" if rule.kind == "gauge"
                               else "counters", {})
            for fname, v in section.items():
                if fnmatch.fnmatchcase(fname, rule.metric):
                    out[fname] = float(v)
        elif rule.kind == "histogram":
            for fname, h in snap.get("histograms", {}).items():
                if fnmatch.fnmatchcase(fname, rule.metric):
                    v = h.get(rule.field)
                    if v is not None:
                        out[fname] = float(v)
        elif rule.kind in ("rate", "ratio"):
            prev = self._prev_counters
            if prev is None:
                return out
            for fname, v in counters.items():
                if not fnmatch.fnmatchcase(fname, rule.metric):
                    continue
                if fname not in prev:
                    continue          # series born this window: no rate yet
                d = float(v) - float(prev[fname])
                if rule.kind == "rate":
                    out[fname] = d
                    continue
                _, labels = _split_series(fname)
                dname = flat_name(rule.denom,
                                  tuple(sorted(labels.items())))
                if dname not in counters:
                    dname = rule.denom
                if dname not in counters or dname not in prev:
                    continue
                dd = float(counters[dname]) - float(prev[dname])
                if dd > 0:
                    out[fname] = d / dd
        return out

    # -- evaluation --------------------------------------------------------
    def observe(self, snap: Dict) -> List[Dict]:
        """Evaluate every rule against one snapshot; returns the alerts
        fired BY this snapshot (also appended to ``self.alerts``)."""
        fired: List[Dict] = []
        maxwin = {r.name: max(n for n, _ in r.windows) for r in self.rules}
        for rule in self.rules:
            for series, value in self._observations(rule, snap).items():
                key = (rule.name, series)
                hist = self._hist.get(key)
                if hist is None:
                    hist = self._hist[key] = deque(maxlen=maxwin[rule.name])
                hist.append(OPS[rule.op](value, rule.threshold))
                burning = all(
                    len(hist) >= n
                    and sum(list(hist)[-n:]) >= frac * n
                    for n, frac in rule.windows)
                if burning and not self._active.get(key, False):
                    alert = {
                        "type": "alert",
                        "t_s": snap.get("t_s", 0.0),
                        "seq": snap.get("seq"),
                        "rule": rule.name,
                        "severity": rule.severity,
                        "series": series,
                        "value": value,
                        "threshold": rule.threshold,
                        "op": rule.op,
                        "windows": [list(w) for w in rule.windows],
                    }
                    fired.append(alert)
                    self.alerts.append(alert)
                    if self._registry is not None:
                        _, labels = _split_series(series)
                        self._registry.counter("slo.alerts",
                                               **labels).inc()
                self._active[key] = burning
        self._prev_counters = dict(snap.get("counters", {}))
        return fired

    def stats(self) -> Dict:
        by_rule: Dict[str, int] = {}
        for a in self.alerts:
            by_rule[a["rule"]] = by_rule.get(a["rule"], 0) + 1
        return {"alerts": len(self.alerts),
                "page_alerts": sum(1 for a in self.alerts
                                   if a["severity"] == "page"),
                "by_rule": by_rule}


def evaluate_file(path: str,
                  rules: Optional[Sequence[Rule]] = None) -> Dict:
    """Re-evaluate rules over an emitter JSONL file's snapshot sequence.
    Returns {"watchdog": SloWatchdog, "snapshots": n, "embedded_alerts":
    n} — embedded alerts are ``alert`` lines already present in the file
    (written by a live watchdog during the run)."""
    wd = SloWatchdog(rules)
    snapshots = 0
    embedded = 0
    with open(path) as f:
        for i, line in enumerate(f):
            if not line.strip():
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not JSON: {e}") from e
            if obj.get("type") == "snapshot":
                snapshots += 1
                wd.observe(obj)
            elif obj.get("type") == "alert":
                embedded += 1
    if not snapshots:
        raise ValueError(f"{path}: no snapshot lines")
    return {"watchdog": wd, "snapshots": snapshots,
            "embedded_alerts": embedded}


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Evaluate SLO rules over an obs emitter JSONL file "
                    "(docs/observability.md 'Numerics & quality health').")
    ap.add_argument("metrics", metavar="METRICS.jsonl",
                    help="emitter JSONL file (snapshot lines)")
    ap.add_argument("--rules", metavar="RULES.json", default=None,
                    help="JSON list of Rule dicts (default: stock rules)")
    ap.add_argument("--fail-on", choices=SEVERITIES, default="page",
                    help="minimum severity that makes the exit code "
                         "nonzero (default: page)")
    args = ap.parse_args(argv)
    try:
        rules = rules_from_json(args.rules) if args.rules else None
        rep = evaluate_file(args.metrics, rules)
    except (OSError, ValueError) as e:
        print(f"[obs.slo] error: {e}", file=sys.stderr)
        return 2
    wd = rep["watchdog"]
    st = wd.stats()
    fail_severities = (SEVERITIES if args.fail_on == "warn"
                       else ("page",))
    failing = [a for a in wd.alerts if a["severity"] in fail_severities]
    print(f"[obs.slo] {args.metrics}: {rep['snapshots']} snapshots, "
          f"{len(wd.rules)} rules, {st['alerts']} alerts fired "
          f"({st['page_alerts']} page), "
          f"{rep['embedded_alerts']} embedded alert lines")
    for a in wd.alerts:
        print(f"[obs.slo]   {a['severity'].upper()} {a['rule']} "
              f"{a['series']}: {a['value']:.6g} {a['op']} "
              f"{a['threshold']:.6g} (seq {a['seq']})")
    if failing:
        print(f"[obs.slo] FAIL: {len(failing)} alert(s) at/above "
              f"--fail-on={args.fail_on}", file=sys.stderr)
        return 1
    print("[obs.slo] OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
