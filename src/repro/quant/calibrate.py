"""Absmax calibration + fixed-point parity harness.

The paper's accuracy claim for the hardware half is that 12-16-bit fixed
point costs near-zero accuracy ONCE WEIGHTS ARE IN THE FFT DOMAIN; the
reproduction's check of that claim has two parts:

* ``weight_absmax_report`` — the offline calibration pass: per serving
  cache, the absmax / per-block-row scale statistics the codec derives
  (absmax quantization of static weights needs no activation data — the
  "calibration" is reading the weights; this reports what it read, plus
  the bytes the quantized planes will occupy).
* ``parity_report`` / ``servable_parity_sweep`` — the accuracy harness:
  per arch, TEACHER-FORCED decode of the quantized serving stack (int8 KV
  pool and/or fixed-point weight planes) against the f32 dense-cache
  oracle.  Both paths consume the ORACLE's greedy token each step, so the
  metrics measure per-step decision fidelity without compounding
  divergence: ``max_logit_drift`` (worst absolute logit delta over all
  steps) and ``greedy_agreement`` (fraction of steps whose argmax
  matches, prefill's first token included).  Free-running engine-level
  token identity lives in tests/test_quant.py; the methodology note is
  docs/quantization.md.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models.registry import build_model
from ..serve import decode as dec
from ..serve import kvcache as kvc
from ..serve.params import precompute_serving_params
from .codec import QuantPolicy

_PLANES = ("wr", "wi", "ws1", "ws2")


# ---------------------------------------------------------------------------
# Offline calibration report
# ---------------------------------------------------------------------------
def weight_absmax_report(params) -> Dict[str, Dict]:
    """Per serving-cache absmax/scale statistics (the calibration pass).

    Walks a precomputed (and possibly already-quantized) parameter tree;
    for every ``*_cache`` dict reports, per plane: the global absmax, the
    largest and smallest per-block-row scale, and the payload bytes.  On a
    quantized tree the scales are read back rather than re-derived.
    """
    report: Dict[str, Dict] = {}

    def walk(path, node):
        if isinstance(node, dict):
            if "wr" in node:
                entry = {}
                for name in _PLANES:
                    if name not in node:
                        continue
                    plane = node[name]
                    stats = {"bytes": int(plane.size)
                             * np.dtype(plane.dtype).itemsize}
                    if name + "_s" in node:                # quantized tree
                        # uint8 marks int4-packed planes: scale = absmax/7
                        qmax = 7.0 if plane.dtype == np.uint8 else 127.0
                        s = np.asarray(node[name + "_s"], np.float64)
                        stats.update(scale_max=float(s.max()),
                                     scale_min=float(s.min()),
                                     absmax=float(s.max() * qmax))
                    else:
                        a = np.abs(np.asarray(plane, np.float64))
                        rows = a.max(axis=(-2, -1))
                        stats.update(absmax=float(a.max()),
                                     scale_max=float(rows.max() / 127.0),
                                     scale_min=float(rows.min() / 127.0))
                    entry[name] = stats
                report["/".join(path)] = entry
                return
            for k, v in node.items():
                walk(path + (str(k),), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(path + (str(i),), v)

    walk((), params)
    return report


# ---------------------------------------------------------------------------
# Teacher-forced parity harness
# ---------------------------------------------------------------------------
def _prompt_batch(cfg: ArchConfig, toks: np.ndarray) -> Dict:
    batch = {"tokens": jnp.asarray(toks[None])}
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.zeros(
            (1, cfg.num_patches, cfg.d_model), jnp.float32)
    return batch


class ParityRunner:
    """Reusable teacher-forced parity harness over PRECOMPUTED params.

    Holds the model and jitted oracle/quantized step functions so jit
    caches survive across prompts — the online shadow-oracle sampler
    (``obs/health.ShadowOracle``) replays many finished requests through
    one runner; ``parity_report`` wraps a single-shot run.  Distinct
    prompt/budget sizes recompile per page-count bucket, same as the
    serving stack.
    """

    def __init__(self, cfg: ArchConfig, params_o, params_q, *,
                 policy: QuantPolicy, page_size: int = 4):
        self.cfg = cfg
        self.policy = policy
        self.page_size = int(page_size)
        self.params_o = params_o
        self.params_q = params_q
        self.model = build_model(cfg)
        self._step_o = jax.jit(
            lambda p, t, c, pos: self.model.decode_step(p, t, c, pos))
        self._step_q = jax.jit(
            lambda p, t, c, pos, tab: self.model.decode_step(
                p, t, c, pos, block_table=tab))
        self._prefills: Dict[int, object] = {}

    def _prefill(self, n_pages: int):
        fn = self._prefills.get(n_pages)
        if fn is None:
            fn = dec.make_prefill_pack_step(self.cfg, n_pages,
                                            self.page_size)
            self._prefills[n_pages] = fn
        return fn

    def run(self, prompt, new_tokens: int) -> Dict:
        """Teacher-forced decode of ``new_tokens`` steps on one prompt;
        both paths consume the ORACLE's greedy token each step.  Returns
        ``steps`` / ``greedy_agreement`` / ``max_logit_drift``."""
        prompt = np.asarray(prompt, np.int32)
        S = len(prompt)
        new_tokens = max(int(new_tokens), 1)
        model, cfg, page_size = self.model, self.cfg, self.page_size

        # oracle: dense f32 cache
        cache = model.init_cache(1, S + new_tokens, dtype=jnp.float32)
        logits, cache = model.prefill(self.params_o,
                                      _prompt_batch(cfg, prompt), cache)
        tok = int(jnp.argmax(logits[0, -1]))

        # quantized: paged pool, pages 1..maxp of a minimal pool
        maxp = kvc.pages_for(S + new_tokens, page_size)
        pool = kvc.build_pool(cfg, maxp + 1, page_size, self.policy)
        table = jnp.arange(1, maxp + 1, dtype=jnp.int32)[None]
        n_pages = kvc.pages_for(S, page_size)
        spad = n_pages * page_size
        padded = np.zeros(spad, np.int32)
        padded[:S] = prompt
        first_q, _ok, pool, _stats = self._prefill(n_pages)(
            self.params_q, _prompt_batch(cfg, padded), pool,
            table[0, :n_pages], jnp.int32(S))

        agree = [int(first_q) == tok]
        drift = 0.0
        for j in range(new_tokens - 1):
            pos = S + j
            lo, cache = self._step_o(self.params_o,
                                     jnp.asarray([[tok]], jnp.int32),
                                     cache, jnp.int32(pos))
            lq, pool = self._step_q(self.params_q,
                                    jnp.asarray([[tok]], jnp.int32), pool,
                                    jnp.asarray([pos], jnp.int32), table)
            lo32 = np.asarray(lo[0, -1], np.float32)
            lq32 = np.asarray(lq[0, -1], np.float32)
            drift = max(drift, float(np.abs(lq32 - lo32).max()))
            agree.append(int(lq32.argmax()) == int(lo32.argmax()))
            tok = int(lo32.argmax())           # teacher forcing: oracle token
        return {"steps": len(agree),
                "greedy_agreement": float(np.mean(agree)),
                "max_logit_drift": drift}


def parity_report(cfg: ArchConfig, params, *, policy: QuantPolicy,
                  prompt_len: int = 20, new_tokens: int = 16,
                  page_size: int = 4, seed: int = 0) -> Dict:
    """Quantized serving stack vs the f32 dense-cache oracle, one arch.

    Runs B=1 teacher-forced decode: the oracle (f32 planes, f32 dense
    cache) picks every input token greedily; the quantized path (pool per
    ``policy.kv_dtype`` + planes per ``policy.quant_weights``) sees the
    SAME tokens at the same positions through the real paged machinery
    (prefill-pack + block-table decode steps).  Returns ``max_logit_drift``
    (max |logits_q - logits_f32| over every compared step),
    ``greedy_agreement`` in [0, 1], and ``steps``.  The same harness
    (``ParityRunner``) backs the ONLINE shadow-oracle sampling in
    ``obs/health.py`` — one definition of agreement/drift offline and on.
    """
    rng = np.random.RandomState(seed)
    prompt = rng.randint(1, cfg.vocab_size, size=prompt_len).astype(np.int32)
    params_o = precompute_serving_params(params, cfg)
    params_q = precompute_serving_params(params, cfg, policy)
    runner = ParityRunner(cfg, params_o, params_q, policy=policy,
                          page_size=page_size)
    out = {"arch": cfg.name, "policy": policy.describe()}
    out.update(runner.run(prompt, new_tokens))
    return out


def servable_parity_sweep(policy: QuantPolicy, *,
                          archs: Optional[Sequence[str]] = None,
                          prompt_len: int = 20, new_tokens: int = 16,
                          page_size: int = 4, seed: int = 0) -> List[Dict]:
    """``parity_report`` over every continuous-servable registry arch
    (smoke configs, f32 activations so quantization is the only delta)."""
    from ..configs.registry import ARCH_IDS, get_smoke_config
    if archs is None:
        archs = [a for a in ARCH_IDS
                 if not kvc.servable_reasons(get_smoke_config(a))]
    out = []
    for arch in archs:
        cfg = get_smoke_config(arch).replace(dtype="float32")
        model_params = build_model(cfg).init(jax.random.PRNGKey(0))
        out.append(parity_report(cfg, model_params, policy=policy,
                                 prompt_len=prompt_len,
                                 new_tokens=new_tokens,
                                 page_size=page_size, seed=seed))
    return out
