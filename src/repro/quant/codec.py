"""Fixed-point inference codec: symmetric absmax int8 (and packed-int4)
quantization for the two serving-state tensors the paper's hardware keeps
in reduced precision.

The AAAI'18 paper's accelerator half earns its energy-efficiency headline
by running the whole FFT->MAC->IFFT datapath in 12-16-bit fixed point on
top of block-circulant compression; CirCNN (arXiv:1708.08917) makes the
same argument for the quantized-spectral datapath.  This module is that
fixed-point layer for the serving stack:

* **Spectral weight planes** — the offline-FFT'd ``wr/wi/ws1/ws2`` planes
  baked by ``serve/params.py`` are quantized per BLOCK ROW (one scale per
  output block ``p``, the granularity one accelerator PE column owns), so
  the serve-mode contraction reads int8 planes and folds the f32 scale
  into the output once per row: ``y[..., p, f] = s[p] * (x . q[p])``.
* **Paged KV pool** — the ``(num_pages, page_size, Hkv, D)`` pool of
  serve/kvcache.py stores int8 with one scale per (page, kv-head).  Pages
  fill incrementally (one decode token at a time), so the page scale is a
  RUNNING absmax: when a new token's magnitude exceeds the page's scale,
  the resident int8 entries are rescaled in-register to the grown scale
  (``page_scatter``) — dequantization then always uses one scale per page
  and the attention kernels read int8 bytes from HBM.

Everything here is pure jnp (jit/vmap/eval_shape-safe) and standalone —
the codec imports nothing from the rest of the package, so kernels,
layers, and core can all depend on it without cycles.

Quantization convention (symmetric absmax):

    scale = absmax / Q           (Q = 127 for int8, 7 for int4)
    q     = clip(round(x / scale), -Q, Q)
    dq    = q * scale            with  |x - dq| <= scale / 2

A scale of exactly 0 encodes an all-zero block; ``quantize`` maps it to
q = 0 and ``dequantize`` back to 0.0 (no division by zero anywhere).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

INT8_QMAX = 127.0
INT4_QMAX = 7.0
_EPS = 1e-30

# Plane names a spectral serving cache may carry (serve/params.py) and the
# suffix their per-block-row scales use.  `wr_s` etc. live NEXT TO the int8
# plane inside the same `*_cache` dict.
PLANE_NAMES = ("wr", "wi", "ws1", "ws2")
SCALE_SUFFIX = "_s"


# ---------------------------------------------------------------------------
# Policy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """What the serving stack quantizes, threaded through the engine.

    ``kv_dtype`` is the FIRST-CLASS pool storage dtype ("f32" | "bf16" |
    "int8") — `serve/kvcache.build_pool` and `pack_prefill_cache` derive
    everything from it instead of an ad-hoc positional dtype argument.
    ``quant_weights`` switches the precomputed spectral weight planes to
    int8 (or int4-packed with ``weight_bits=4``: two nibbles per byte,
    widened to int8 before the f32-accumulating contraction).
    """
    kv_dtype: str = "f32"
    quant_weights: bool = False
    weight_bits: int = 8

    def __post_init__(self):
        if self.kv_dtype not in ("f32", "bf16", "int8"):
            raise ValueError(f"kv_dtype {self.kv_dtype!r}: "
                             f"expected 'f32', 'bf16' or 'int8'")
        if self.weight_bits not in (8, 4):
            raise ValueError(f"weight_bits {self.weight_bits}: "
                             f"expected 8 or 4")

    @property
    def kv_quantized(self) -> bool:
        return self.kv_dtype == "int8"

    @property
    def pool_dtype(self):
        return {"f32": jnp.float32, "bf16": jnp.bfloat16,
                "int8": jnp.int8}[self.kv_dtype]

    def describe(self) -> Dict:
        """JSON-able form for telemetry (`ContinuousEngine.stats()`)."""
        return {"kv_dtype": self.kv_dtype,
                "quant_weights": bool(self.quant_weights),
                "weight_bits": int(self.weight_bits)}


# ---------------------------------------------------------------------------
# Scalar codec
# ---------------------------------------------------------------------------
def absmax_scale(x: jax.Array, axes, qmax: float = INT8_QMAX) -> jax.Array:
    """Symmetric absmax scale over ``axes`` (reduced away, no keepdims)."""
    return jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axes) / qmax


def quantize(x: jax.Array, scale: jax.Array,
             qmax: float = INT8_QMAX) -> jax.Array:
    """clip(round(x / scale)) as int8; ``scale`` broadcasts against ``x``
    and a zero scale quantizes to 0 (the all-zero block encoding)."""
    q = jnp.round(x.astype(jnp.float32) / jnp.maximum(scale, _EPS))
    return jnp.clip(q, -qmax, qmax).astype(jnp.int8)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def saturation_counts(q: jax.Array,
                      qmax: float = INT8_QMAX) -> Tuple[jax.Array, int]:
    """``(clipped, total)`` for a quantized array: how many entries sit AT
    the ±qmax rail, out of how many.

    With symmetric absmax scaling nothing ever lands OUTSIDE the rail —
    the block-max element maps to exactly ±qmax by construction — so this
    is a saturation-pressure census, not an overflow count: a rising clip
    rate means more of the distribution is crowding the top code, i.e.
    the block's dynamic range is outgrowing the quantization grid.
    ``clipped`` is a device scalar (jit-safe); ``total`` is the static
    element count, so ``clipped + unclipped == total`` is exact."""
    sat = jnp.abs(q.astype(jnp.float32)) >= float(qmax)
    return jnp.sum(sat).astype(jnp.float32), int(q.size)


# ---------------------------------------------------------------------------
# int4 nibble packing (weights-only stretch mode)
# ---------------------------------------------------------------------------
def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int8 values in [-7, 7] two-per-byte along the last axis.

    Odd lengths are zero-padded; the consumer recovers the true length
    from context (the frequency count ``kf`` for spectral planes).  The
    packed array is uint8 — the dtype is the int4 marker downstream.
    """
    n = q.shape[-1]
    if n % 2:
        q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, 1)])
    lo = q[..., 0::2].astype(jnp.uint8) & 0xF        # two's-complement nibble
    hi = q[..., 1::2].astype(jnp.uint8) & 0xF
    return lo | (hi << 4)


def unpack_int4(packed: jax.Array, n: int) -> jax.Array:
    """Inverse of ``pack_int4``: (..., ceil(n/2)) uint8 -> (..., n) int8."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = ((lo ^ 8) - 8).astype(jnp.int8)             # sign-extend nibble
    hi = ((hi ^ 8) - 8).astype(jnp.int8)
    out = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1],
                                               2 * packed.shape[-1])
    return out[..., :n]


# ---------------------------------------------------------------------------
# Spectral weight planes: per-block-row quantization
# ---------------------------------------------------------------------------
def quantize_plane(w: jax.Array, bits: int = 8
                   ) -> Tuple[jax.Array, jax.Array]:
    """One (..., p, q, kf) spectral plane -> (int plane, (..., p, 1) scale).

    The scale reduces over the input-block and frequency dims — one value
    per OUTPUT block row, shaped (..., p, 1) so it right-broadcasts against
    the (..., p, kf) contraction output when folded post-einsum.
    """
    qmax = INT8_QMAX if bits == 8 else INT4_QMAX
    scale = absmax_scale(w, axes=(-2, -1), qmax=qmax)[..., None]  # (..., p, 1)
    q = quantize(w, scale[..., None], qmax)
    if bits == 4:
        q = pack_int4(q)
    return q, scale.astype(jnp.float32)


def quantize_plane_cache(cache: Dict[str, jax.Array],
                         bits: int = 8) -> Dict[str, jax.Array]:
    """Quantize a spectral serving cache dict ({'wr','wi','ws1','ws2'} ->
    same keys as int8/uint8 planes + ``<name>_s`` per-block-row scales).
    Idempotent: an already-quantized dict passes through unchanged."""
    if any(k + SCALE_SUFFIX in cache for k in PLANE_NAMES):
        return dict(cache)
    out = {}
    for name, w in cache.items():
        if name in PLANE_NAMES:
            out[name], out[name + SCALE_SUFFIX] = quantize_plane(w, bits)
        else:
            out[name] = w
    return out


def plane_from_cache(cache: Dict[str, jax.Array], name: str, kf: int
                     ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Fetch one plane ready to contract: (f32 plane, fold-scale or None).

    int8 planes come back cast to f32 with the (..., p, 1) scale returned
    separately (fold AFTER the contraction — the HBM read stays int8);
    int4-packed (uint8) planes are widened to int8 nibbles first, ``kf``
    recovering the true frequency count.  Unquantized caches return the
    plane as-is with scale None.
    """
    w = cache[name]
    scale = cache.get(name + SCALE_SUFFIX)
    if scale is None:
        return w, None
    if w.dtype == jnp.uint8:
        w = unpack_int4(w, kf)
    return w.astype(jnp.float32), scale


def quantize_serving_params(params, bits: int = 8):
    """Quantize every baked spectral serving cache in a parameter tree.

    Pure transform over the tree `serve/params.precompute_serving_params`
    produced: each ``*_cache`` dict gains int planes + per-block-row
    scales; generators (``wc``), dense weights, and everything else pass
    through untouched (training still differentiates through ``wc``).
    Idempotent, and works under ``jax.eval_shape``... except scale values
    (not shapes) obviously need real weights.
    """
    def walk(node):
        if isinstance(node, dict):
            out = {}
            for key, v in node.items():
                if (key.endswith("_cache") and isinstance(v, dict)
                        and "wr" in v):
                    out[key] = quantize_plane_cache(v, bits)
                else:
                    out[key] = walk(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node
    return walk(params)


def plane_clip_report(params) -> Dict[str, int]:
    """Host-side saturation census over every quantized spectral plane in
    a serving parameter tree: ``{"clipped", "total", "planes"}``.

    Weights are static, so this runs ONCE at engine wiring time (not per
    dispatch) and feeds the ``quant.clip.plane_*`` counters.  int4-packed
    (uint8) planes are unpacked to nibbles first and counted against the
    int4 rail; the odd-length zero pad nibble counts as unclipped (a
    <=1-per-row dilution of ``total``, noted so the rate reads exact on
    even frequency counts)."""
    counts = {"clipped": 0, "total": 0, "planes": 0}

    def census(plane):
        if plane.dtype == jnp.uint8:
            q = unpack_int4(plane, 2 * plane.shape[-1])
            qmax = INT4_QMAX
        else:
            q = plane
            qmax = INT8_QMAX
        clipped, total = saturation_counts(q, qmax)
        counts["clipped"] += int(clipped)
        counts["total"] += total
        counts["planes"] += 1

    def walk(node):
        if isinstance(node, dict):
            for key, v in node.items():
                if (key.endswith("_cache") and isinstance(v, dict)
                        and "wr" in v):
                    for name in PLANE_NAMES:
                        if name in v and name + SCALE_SUFFIX in v:
                            census(v[name])
                else:
                    walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    return counts


# ---------------------------------------------------------------------------
# Paged KV pool: per-page-per-head quantization
# ---------------------------------------------------------------------------
def quantize_page_block(vals: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Whole-page quantization for the prefill pack path.

    vals: (..., page, H, D) float -> (int8 same shape, (..., H) scales).
    One scale per (page, head): the reduction spans the in-page offset and
    head_dim axes, never the head axis — heads differ in magnitude by
    design (RoPE'd keys vs values), pages differ over time.
    """
    scale = absmax_scale(vals, axes=(-3, -1))                  # (..., H)
    q = quantize(vals, scale[..., None, :, None])
    return q, scale.astype(jnp.float32)


def page_scatter(pool_q: jax.Array, scales: jax.Array, pid: jax.Array,
                 off: jax.Array, x: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Decode-path write of one token per slot into an int8 page pool.

    pool_q: (P, page, H, D) int8;  scales: (P, H) f32;  pid/off: (B,)
    int32 page id / in-page offset per slot;  x: (B, H, D) new K or V
    rows.  Returns the updated (pool_q, scales).

    Per-page scales must stay valid for values ALREADY in the page, so the
    scale only ever grows: ``s_new = max(s_old, absmax(x)/127)`` per head,
    and when it grows the page's resident int8 entries are requantized to
    the new scale in-register (one extra half-step of rounding error per
    grow event, bounded by page_size growths — see docs/quantization.md).
    The requantizing read-modify-write of the whole page runs only UNDER
    the grow predicate (``lax.cond``): in the steady state — page absmax
    settled, no slot grew this step — the write is the same single-row
    scatter the unquantized pool pays, so int8 decode write traffic stays
    O(token), not O(page).  Idle slots carry pid == 0 (the trash page);
    duplicate trash writes are unordered but trash content and trash
    scale are never read unmasked.

    Because scales only GROW, the serving telemetry can count grow events
    without threading a counter through the jit'd loop: the continuous
    engine diffs host shadows of the scale leaves around decode
    dispatches into the ``quant.scale_growths`` counter
    (docs/observability.md).
    """
    page = pool_q.shape[1]
    s_old = scales[pid]                                        # (B, H)
    s_new = jnp.maximum(s_old, absmax_scale(x, axes=-1))       # (B, H)

    def requant(carry):
        pq, sc = carry
        ratio = s_old / jnp.maximum(s_new, _EPS)               # <= 1
        resident = pq[pid]                                     # (B,page,H,D)
        resident = jnp.round(resident.astype(jnp.float32)
                             * ratio[:, None, :, None]).astype(jnp.int8)
        tok = quantize(x, s_new[..., None])                    # (B, H, D)
        hit = (jnp.arange(page)[None, :] == off[:, None])      # (B, page)
        resident = jnp.where(hit[..., None, None], tok[:, None], resident)
        return pq.at[pid].set(resident), sc.at[pid].set(s_new)

    def fast(carry):
        pq, sc = carry                                         # s_new == s_old
        return pq.at[pid, off].set(quantize(x, s_old[..., None])), sc

    return jax.lax.cond(jnp.any(s_new > s_old), requant, fast,
                        (pool_q, scales))
