"""repro.quant — fixed-point inference quantization for the serving stack.

The paper's algorithm-hardware co-optimization pairs block-circulant
compression with a fixed-point datapath; this package is the fixed-point
half for the reproduction's serving stack: an absmax int8/int4 codec over
the precomputed spectral weight planes and the paged KV-cache pool
(``codec``), and an offline calibration + f32-parity harness
(``calibrate``).  ``QuantPolicy`` is the single config object engines
thread through `serve/kvcache.build_pool`, `serve/params`, and the
attention kernels.
"""
from .codec import (QuantPolicy, absmax_scale, dequantize, pack_int4,
                    page_scatter, plane_clip_report, plane_from_cache,
                    quantize, quantize_page_block, quantize_plane,
                    quantize_plane_cache, quantize_serving_params,
                    saturation_counts, unpack_int4)

__all__ = [
    "QuantPolicy", "absmax_scale", "dequantize", "pack_int4",
    "page_scatter", "plane_clip_report", "plane_from_cache", "quantize",
    "quantize_page_block", "quantize_plane", "quantize_plane_cache",
    "quantize_serving_params", "saturation_counts", "unpack_int4",
]
