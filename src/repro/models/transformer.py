"""Unified decoder-LM covering the dense / MoE / hybrid / SSM assigned archs.

A model is a list of *segments*; each segment is a repeating ``pattern`` of
block kinds scanned ``n`` times (params stacked over the scan axis).  This
keeps HLO size O(pattern) instead of O(layers) while allowing heterogeneous
stacks (gemma2 local/global alternation, recurrentgemma rec-rec-attn,
llama4 dense/MoE interleave, xlstm mlstm/slstm mixes — including non-divisible
tails like recurrentgemma's 26 = 8x(rec,rec,attn) + 1x(rec,rec)).

Block kinds:
  attn        global causal attention + dense MLP
  attn_local  sliding-window attention + dense MLP
  moe         global attention + mixture-of-experts
  moe_swa     sliding-window attention + MoE (mixtral)
  rec         RG-LRU temporal block + dense MLP (recurrentgemma)
  mlstm/slstm xLSTM blocks (self-contained, no separate MLP)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..dist.ctx import shard_act
from ..layers import attention as attn_lib
from ..layers import embeddings as emb_lib
from ..layers import ffn as ffn_lib
from ..layers import norms as norm_lib
from ..layers import recurrent as rec_lib

ATTN_KINDS = ("attn", "attn_local", "moe", "moe_swa")


def segments_for(cfg: ArchConfig) -> List[Tuple[Tuple[str, ...], int]]:
    """Segment plan for an arch (pattern, repeat) — see registry for sources."""
    pat = cfg.recurrent.pattern
    if pat:                                   # hybrid / ssm archs define theirs
        period = len(pat)
        n, rem = divmod(cfg.num_layers, period)
        segs = [(tuple(pat), n)] if n else []
        if rem:
            segs.append((tuple(pat[:rem]), 1))
        return segs
    if cfg.moe.num_experts:
        if cfg.moe.interleave > 1:
            pat = tuple(["attn", "moe"] * (cfg.moe.interleave // 2))
        else:
            pat = ("moe_swa",) if cfg.attention.layout == "sliding" else ("moe",)
    elif cfg.attention.layout == "alternating":
        pat = ("attn_local", "attn")
    elif cfg.attention.layout == "sliding":
        pat = ("attn_local",)
    else:
        pat = ("attn",)
    period = len(pat)
    n, rem = divmod(cfg.num_layers, period)
    segs = [(tuple(pat), n)] if n else []
    if rem:
        segs.append((tuple(pat[:rem]), 1))
    return segs


def _window_for(kind: str, cfg: ArchConfig) -> int:
    if kind in ("attn_local", "moe_swa"):
        return cfg.attention.sliding_window
    return 0


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------
def init_block(key, kind: str, cfg: ArchConfig) -> Dict:
    d, dff = cfg.d_model, cfg.d_ff
    comp = cfg.compression
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": norm_lib.init_norm(cfg.norm, d)}
    if kind in ATTN_KINDS:
        p["attn"] = attn_lib.init_attention(ks[0], cfg, d, comp)
        p["ln2"] = norm_lib.init_norm(cfg.norm, d)
        if kind in ("moe", "moe_swa"):
            p["moe"] = ffn_lib.init_moe(ks[1], d, dff, cfg.moe, comp)
        else:
            p["mlp"] = ffn_lib.init_mlp(ks[1], d, dff, comp)
        if getattr(cfg, "sandwich_norm", False) or cfg.name.startswith("gemma2"):
            p["ln1_post"] = norm_lib.init_norm(cfg.norm, d)
            p["ln2_post"] = norm_lib.init_norm(cfg.norm, d)
    elif kind == "rec":
        width = cfg.recurrent.lru_width or d
        p["rec"] = rec_lib.init_rglru(ks[0], d, width, comp,
                                      cfg.recurrent.conv1d_width)
        p["ln2"] = norm_lib.init_norm(cfg.norm, d)
        p["mlp"] = ffn_lib.init_mlp(ks[1], d, dff, comp)
    elif kind == "mlstm":
        p["cell"] = rec_lib.init_mlstm(ks[0], d, cfg.recurrent.mlstm_heads,
                                       cfg.recurrent.proj_factor, comp)
    elif kind == "slstm":
        p["cell"] = rec_lib.init_slstm(ks[0], d, cfg.recurrent.mlstm_heads, comp)
    else:
        raise ValueError(kind)
    return p


def apply_block(params, x, kind: str, cfg: ArchConfig, *, mode: str,
                cache=None, cache_pos=None, q_chunk: int, kv_chunk: int,
                block_table=None, paged_impl: str = "stream"):
    """Returns (x, new_cache, aux)."""
    comp = cfg.compression
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache
    if kind in ATTN_KINDS:
        h = norm_lib.apply_norm(cfg.norm, params["ln1"], x)
        a, new_cache = attn_lib.attention_block(
            params["attn"], h, cfg=cfg, causal=True,
            window=_window_for(kind, cfg), cache=cache, cache_pos=cache_pos,
            mode=mode, q_chunk=q_chunk, kv_chunk=kv_chunk,
            block_table=block_table, paged_impl=paged_impl)
        if "ln1_post" in params:
            a = norm_lib.apply_norm(cfg.norm, params["ln1_post"], a)
        x = x + a
        h = norm_lib.apply_norm(cfg.norm, params["ln2"], x)
        if kind in ("moe", "moe_swa"):
            f, aux = ffn_lib.moe(params["moe"], h, d_ff=cfg.d_ff,
                                 moe_cfg=cfg.moe, comp=comp,
                                 activation=cfg.ffn_activation, mode=mode)
        else:
            f = ffn_lib.mlp(params["mlp"], h, d_ff=cfg.d_ff, comp=comp,
                            activation=cfg.ffn_activation, mode=mode)
        if "ln2_post" in params:
            f = norm_lib.apply_norm(cfg.norm, params["ln2_post"], f)
        x = x + f
    elif kind == "rec":
        width = cfg.recurrent.lru_width or cfg.d_model
        h = norm_lib.apply_norm(cfg.norm, params["ln1"], x)
        r, new_cache = rec_lib.rglru_block(params["rec"], h, width=width,
                                           comp=comp, mode=mode, state=cache)
        x = x + r
        h = norm_lib.apply_norm(cfg.norm, params["ln2"], x)
        x = x + ffn_lib.mlp(params["mlp"], h, d_ff=cfg.d_ff, comp=comp,
                            activation=cfg.ffn_activation, mode=mode)
    elif kind == "mlstm":
        h = norm_lib.apply_norm(cfg.norm, params["ln1"], x)
        y, new_cache = rec_lib.mlstm_block(
            params["cell"], h, heads=cfg.recurrent.mlstm_heads,
            proj_factor=cfg.recurrent.proj_factor, comp=comp, mode=mode,
            state=cache, chunk=cfg.mlstm_chunk)
        x = x + y
    elif kind == "slstm":
        h = norm_lib.apply_norm(cfg.norm, params["ln1"], x)
        y, new_cache = rec_lib.slstm_block(params["cell"], h, comp=comp,
                                           mode=mode, state=cache)
        x = x + y
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Whole model
# ---------------------------------------------------------------------------
def init_params(key, cfg: ArchConfig) -> Dict:
    segs = segments_for(cfg)
    keys = jax.random.split(key, len(segs) + 2)
    params: Dict[str, Any] = {
        "embed": emb_lib.init_embedding(keys[0], cfg.padded_vocab(), cfg.d_model),
        "final_norm": norm_lib.init_norm(cfg.norm, cfg.d_model),
        "segments": [],
    }
    if cfg.max_position:
        params["pos"] = emb_lib.init_learned_pos(keys[1], cfg.max_position,
                                                 cfg.d_model)
    for si, (pattern, n) in enumerate(segs):
        seg_keys = jax.random.split(keys[2 + si], n)

        def one_group(k):
            ks = jax.random.split(k, len(pattern))
            return tuple(init_block(ks[i], kind, cfg)
                         for i, kind in enumerate(pattern))

        groups = [one_group(k) for k in seg_keys]
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *groups)
        params["segments"].append(stacked)
    return params


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> List:
    """Per-segment stacked caches (leading dim = groups in segment)."""
    segs = segments_for(cfg)
    caches = []
    for pattern, n in segs:
        def one_group():
            out = []
            for kind in pattern:
                if kind in ATTN_KINDS:
                    out.append(attn_lib.init_kv_cache(
                        batch, max_seq, cfg, _window_for(kind, cfg), dtype))
                elif kind == "rec":
                    width = cfg.recurrent.lru_width or cfg.d_model
                    out.append(rec_lib.init_rglru_state(
                        batch, width, cfg.recurrent.conv1d_width))
                elif kind == "mlstm":
                    d_in = int(cfg.d_model * cfg.recurrent.proj_factor)
                    out.append(rec_lib.init_mlstm_state(
                        batch, cfg.recurrent.mlstm_heads,
                        d_in // cfg.recurrent.mlstm_heads))
                elif kind == "slstm":
                    out.append(rec_lib.init_slstm_state(batch, cfg.d_model))
            return tuple(out)
        g = one_group()
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n, *x.shape)), g))
    return caches


def forward(params, tokens, cfg: ArchConfig, *, mode: str = "train",
            cache: Optional[List] = None, cache_pos=None,
            frontend_embeds=None, q_chunk: Optional[int] = None,
            kv_chunk: Optional[int] = None, block_table=None,
            paged_impl: str = "stream"):
    """tokens: (B, S) int32.  Returns (logits, aux, new_cache).

    With ``block_table`` set, ``cache`` is a paged pool tree (attention
    leaves {"k","v"} shaped (n, P, page, Hkv, D)) and ``cache_pos`` is the
    per-slot (B,) position vector — see serve/kvcache.py.  ``paged_impl``
    selects the paged attention lowering ("stream" fused flash-decode /
    "gather" legacy materialized view — see layers/attention.py).
    """
    q_chunk = q_chunk or cfg.attn_q_chunk
    kv_chunk = kv_chunk or cfg.attn_kv_chunk
    segs = segments_for(cfg)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = emb_lib.embed(params["embed"], tokens,
                      scale_by_dim=cfg.name.startswith(("gemma", "recurrent")))
    x = x.astype(dtype)
    if frontend_embeds is not None:
        # modality stub: precomputed patch/frame embeddings replace the first
        # `num_patches` token slots (see DESIGN.md §Arch-applicability).
        np_ = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(dtype), x[:, np_:]], axis=1)
    if "pos" in params:
        pos0 = 0 if cache_pos is None else cache_pos
        S = x.shape[1]
        table = params["pos"]["pos"]
        idx = pos0 + jnp.arange(S)
        x = x + table[idx].astype(dtype)[None]

    aux_total = jnp.zeros((), jnp.float32)
    new_caches: List = []
    for si, (pattern, n) in enumerate(segs):
        seg_params = params["segments"][si]
        seg_cache = None if cache is None else cache[si]

        def group_fn(carry, xs):
            x_, aux_ = carry
            gp, gc = xs
            new_gc = []
            for bi, kind in enumerate(pattern):
                bp = gp[bi]
                c_in = None if gc is None else gc[bi]
                x_ = shard_act(x_)          # block-boundary sharding pin
                x_, c_out, aux_b = apply_block(
                    bp, x_, kind, cfg, mode=mode, cache=c_in,
                    cache_pos=cache_pos, q_chunk=q_chunk, kv_chunk=kv_chunk,
                    block_table=block_table, paged_impl=paged_impl)
                new_gc.append(c_out)
                aux_ = aux_ + aux_b
            x_ = shard_act(x_)
            new_gc = tuple(new_gc) if gc is not None else 0
            return (x_, aux_), new_gc

        if cfg.remat == "full" and mode == "train":
            group_fn = jax.checkpoint(group_fn,
                                      policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.unroll_scan:
            # python loop over groups: exact cost_analysis / collective
            # counts for the roofline lowering (a while body is costed once)
            outs = []
            for g in range(n):
                gp = jax.tree.map(lambda a: a[g], seg_params)
                gc = (None if seg_cache is None else
                      jax.tree.map(lambda a: a[g], seg_cache))
                (x, aux_total), new_gc = group_fn((x, aux_total), (gp, gc))
                outs.append(new_gc)
            new_seg_cache = (jax.tree.map(lambda *a: jnp.stack(a), *outs)
                            if seg_cache is not None else None)
        elif seg_cache is not None:
            (x, aux_total), new_seg_cache = jax.lax.scan(
                group_fn, (x, aux_total), (seg_params, seg_cache))
        else:
            (x, aux_total), _ = jax.lax.scan(
                lambda c, gp: group_fn(c, (gp, None)), (x, aux_total),
                seg_params)
            new_seg_cache = None
        new_caches.append(new_seg_cache)

    x = norm_lib.apply_norm(cfg.norm, params["final_norm"], x)
    logits = emb_lib.logits(params["embed"], x, softcap=cfg.logit_softcap)
    return logits, {"moe_aux": aux_total}, (new_caches if cache is not None
                                            else None)
