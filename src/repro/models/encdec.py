"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, encoder_seq, d_model) directly into the
encoder.  Encoder blocks are bidirectional; decoder blocks are causal
self-attention + cross-attention to the encoder output.  Learned positions
(whisper uses sinusoidal enc / learned dec; we use learned tables for both —
backbone-equivalent compute).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.circulant import LinearSpec, apply_linear
from ..dist.ctx import shard_act
from ..layers import attention as attn_lib
from ..layers import embeddings as emb_lib
from ..layers import ffn as ffn_lib
from ..layers import norms as norm_lib


def _init_enc_block(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": norm_lib.init_norm(cfg.norm, cfg.d_model),
        "attn": attn_lib.init_attention(ks[0], cfg, cfg.d_model, cfg.compression),
        "ln2": norm_lib.init_norm(cfg.norm, cfg.d_model),
        "mlp": ffn_lib.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.compression,
                                gated=False),
    }


def _init_dec_block(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "ln1": norm_lib.init_norm(cfg.norm, cfg.d_model),
        "self": attn_lib.init_attention(ks[0], cfg, cfg.d_model, cfg.compression),
        "ln_x": norm_lib.init_norm(cfg.norm, cfg.d_model),
        "cross": attn_lib.init_attention(ks[1], cfg, cfg.d_model, cfg.compression),
        "ln2": norm_lib.init_norm(cfg.norm, cfg.d_model),
        "mlp": ffn_lib.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.compression,
                                gated=False),
    }


def init_params(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    enc = [_init_enc_block(k, cfg) for k in enc_keys]
    dec = [_init_dec_block(k, cfg) for k in dec_keys]
    return {
        "embed": emb_lib.init_embedding(ks[2], cfg.padded_vocab(), cfg.d_model),
        "enc_pos": emb_lib.init_learned_pos(ks[3], cfg.encoder_seq, cfg.d_model),
        "dec_pos": emb_lib.init_learned_pos(ks[4], cfg.max_position or 4096,
                                            cfg.d_model),
        "enc_blocks": jax.tree.map(lambda *a: jnp.stack(a), *enc),
        "dec_blocks": jax.tree.map(lambda *a: jnp.stack(a), *dec),
        "enc_norm": norm_lib.init_norm(cfg.norm, cfg.d_model),
        "final_norm": norm_lib.init_norm(cfg.norm, cfg.d_model),
    }


def encode(params, frames, cfg: ArchConfig, *, mode="train",
           q_chunk=None, kv_chunk=None):
    q_chunk = q_chunk or cfg.attn_q_chunk
    kv_chunk = kv_chunk or cfg.attn_kv_chunk
    """frames: (B, encoder_seq, d_model) stub embeddings -> encoder states."""
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = frames.astype(dtype) + params["enc_pos"]["pos"].astype(dtype)[None]

    def body(x_, bp):
        x_ = shard_act(x_)                  # block-boundary sharding pin
        h = norm_lib.apply_norm(cfg.norm, bp["ln1"], x_)
        a, _ = attn_lib.attention_block(bp["attn"], h, cfg=cfg, causal=False,
                                        mode=mode, q_chunk=q_chunk,
                                        kv_chunk=kv_chunk)
        x_ = x_ + a
        h = norm_lib.apply_norm(cfg.norm, bp["ln2"], x_)
        x_ = x_ + ffn_lib.mlp(bp["mlp"], h, d_ff=cfg.d_ff, comp=cfg.compression,
                              activation="gelu", mode=mode)
        return x_, None

    if cfg.remat == "full" and mode == "train":
        body = jax.checkpoint(body)
    if cfg.unroll_scan:
        for i in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i],
                                        params["enc_blocks"]))
    else:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return norm_lib.apply_norm(cfg.norm, params["enc_norm"], x)


def _cross_kv(bp, enc_out, cfg, mode):
    """Precompute per-layer cross-attention K/V from encoder states."""
    a = cfg.attention
    spec = LinearSpec.from_config(cfg.compression, "attn", bias=a.qkv_bias)
    B, Senc, _ = enc_out.shape
    k = apply_linear(bp["cross"]["k"], enc_out, spec,
                     a.num_kv_heads * a.head_dim, mode)
    v = apply_linear(bp["cross"]["v"], enc_out, spec,
                     a.num_kv_heads * a.head_dim, mode)
    return (k.reshape(B, Senc, a.num_kv_heads, a.head_dim),
            v.reshape(B, Senc, a.num_kv_heads, a.head_dim))


def decode(params, tokens, enc_out, cfg: ArchConfig, *, mode="train",
           cache=None, cache_pos=None, cross_cache=None,
           q_chunk=None, kv_chunk=None):
    """tokens: (B, S).  Returns (logits, new_cache, cross_cache)."""
    q_chunk = q_chunk or cfg.attn_q_chunk
    kv_chunk = kv_chunk or cfg.attn_kv_chunk
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    B, S = tokens.shape
    x = emb_lib.embed(params["embed"], tokens).astype(dtype)
    pos0 = 0 if cache_pos is None else cache_pos
    idx = pos0 + jnp.arange(S)
    x = x + params["dec_pos"]["pos"][idx].astype(dtype)[None]

    if cross_cache is None:
        cross_cache = _all_cross_kv(params, enc_out, cfg, mode)

    def body(carry, xs):
        x_, = carry
        bp, ckv, c_in = xs
        x_ = shard_act(x_)                  # block-boundary sharding pin
        h = norm_lib.apply_norm(cfg.norm, bp["ln1"], x_)
        a, c_out = attn_lib.attention_block(
            bp["self"], h, cfg=cfg, causal=True, cache=c_in,
            cache_pos=cache_pos, mode=mode, q_chunk=q_chunk, kv_chunk=kv_chunk)
        x_ = x_ + a
        h = norm_lib.apply_norm(cfg.norm, bp["ln_x"], x_)
        a, _ = attn_lib.attention_block(
            bp["cross"], h, cfg=cfg, causal=False, cross_kv=ckv, mode=mode,
            q_chunk=q_chunk, kv_chunk=kv_chunk)
        x_ = x_ + a
        h = norm_lib.apply_norm(cfg.norm, bp["ln2"], x_)
        x_ = x_ + ffn_lib.mlp(bp["mlp"], h, d_ff=cfg.d_ff, comp=cfg.compression,
                              activation="gelu", mode=mode)
        return (x_,), c_out

    fn = body
    if cfg.remat == "full" and mode == "train":
        fn = jax.checkpoint(body)
    if cfg.unroll_scan:
        outs = []
        for i in range(cfg.num_layers):
            xs = jax.tree.map(lambda a: a[i],
                              (params["dec_blocks"], cross_cache,
                               cache if cache is not None else 0))
            if cache is None:
                xs = (xs[0], xs[1], None)
            (x,), c_out = fn((x,), xs)
            outs.append(c_out)
        new_cache = (jax.tree.map(lambda *a: jnp.stack(a), *outs)
                     if cache is not None else None)
    elif cache is not None:
        (x,), new_cache = jax.lax.scan(
            fn, (x,), (params["dec_blocks"], cross_cache, cache))
    else:
        (x,), _ = jax.lax.scan(
            lambda c, xs: fn(c, (*xs, None)), (x,),
            (params["dec_blocks"], cross_cache))
        new_cache = None

    x = norm_lib.apply_norm(cfg.norm, params["final_norm"], x)
    logits = emb_lib.logits(params["embed"], x)
    return logits, new_cache, cross_cache


def _all_cross_kv(params, enc_out, cfg, mode):
    """Stacked cross-KV for all decoder layers (computed once per request)."""
    return jax.vmap(lambda bp: _cross_kv(bp, enc_out, cfg, mode),
                    in_axes=(0,))(params["dec_blocks"])


def forward(params, tokens, cfg: ArchConfig, *, frames=None, mode="train",
            cache=None, cache_pos=None, cross_cache=None, enc_out=None,
            q_chunk=1024, kv_chunk=1024):
    """Full enc-dec forward.  Returns (logits, aux, state-dict)."""
    if enc_out is None:
        enc_out = encode(params, frames, cfg, mode=mode,
                         q_chunk=q_chunk, kv_chunk=kv_chunk)
    logits, new_cache, cross_cache = decode(
        params, tokens, enc_out, cfg, mode=mode, cache=cache,
        cache_pos=cache_pos, cross_cache=cross_cache,
        q_chunk=q_chunk, kv_chunk=kv_chunk)
    aux = {"moe_aux": jnp.zeros((), jnp.float32)}
    return logits, aux, {"cache": new_cache, "cross": cross_cache,
                         "enc_out": enc_out}


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Stacked decoder self-attention caches (L, B, S, Hkv, D)."""
    one = attn_lib.init_kv_cache(batch, max_seq, cfg, 0, dtype)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers, *x.shape)), one)
