"""Unified model API over the decoder-LM and encoder-decoder backbones.

``build_model(cfg)`` returns a ``Model`` with a uniform surface:

    init(key)                                  -> params
    forward_train(params, batch)               -> (logits, aux)
    prefill(params, batch, cache)              -> (logits, cache)
    decode_step(params, tokens, cache, pos)    -> (logits, cache)
    init_cache(batch, max_seq, dtype)          -> cache pytree

``batch`` carries ``tokens``/``labels`` plus the modality-stub inputs
(``frames`` for audio, ``patches`` for vision) per the assignment: frontends
are STUBS — precomputed frame/patch embeddings enter the backbone directly.

``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for every model
input of a workload cell — the dry-run lowers against these (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from . import encdec, transformer


class Model:
    """Thin dispatch over the two backbone kinds; all math lives below."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- params ------------------------------------------------------------
    def init(self, key) -> Dict:
        if self.cfg.is_encoder_decoder:
            return encdec.init_params(key, self.cfg)
        return transformer.init_params(key, self.cfg)

    # -- training ----------------------------------------------------------
    def forward_train(self, params, batch) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            logits, aux, _ = encdec.forward(params, batch["tokens"], cfg,
                                            frames=batch["frames"],
                                            mode="train")
            return logits, aux
        logits, aux, _ = transformer.forward(
            params, batch["tokens"], cfg, mode="train",
            frontend_embeds=batch.get("patches"))
        return logits, aux

    # -- serving -----------------------------------------------------------
    def prefill(self, params, batch, cache) -> Tuple[jax.Array, Any]:
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            enc_out = encdec.encode(params, batch["frames"], cfg, mode="serve")
            cross = encdec._all_cross_kv(params, enc_out, cfg, "serve")
            logits, new_self, _ = encdec.decode(
                params, batch["tokens"], enc_out, cfg, mode="serve",
                cache=cache["self"], cache_pos=0, cross_cache=cross)
            return logits, {"self": new_self, "cross": cross}
        logits, _, new_cache = transformer.forward(
            params, batch["tokens"], cfg, mode="serve", cache=cache,
            cache_pos=0, frontend_embeds=batch.get("patches"))
        return logits, new_cache

    def decode_step(self, params, tokens, cache, cache_pos,
                    block_table=None,
                    paged_impl: str = "stream") -> Tuple[jax.Array, Any]:
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            assert block_table is None, "paged decode is decoder-LM only"
            logits, new_self, _ = encdec.decode(
                params, tokens, None, cfg, mode="serve",
                cache=cache["self"], cache_pos=cache_pos,
                cross_cache=cache["cross"])
            return logits, {"self": new_self, "cross": cache["cross"]}
        logits, _, new_cache = transformer.forward(
            params, tokens, cfg, mode="serve", cache=cache,
            cache_pos=cache_pos, block_table=block_table,
            paged_impl=paged_impl)
        return logits, new_cache

    def init_cache(self, batch: int, max_seq: int, dtype=None):
        cfg = self.cfg
        if dtype is None:
            dtype = jnp.dtype(cfg.kv_cache_dtype)
        if cfg.is_encoder_decoder:
            a = cfg.attention
            self_cache = encdec.init_cache(cfg, batch, max_seq, dtype)
            cross = (jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                                a.num_kv_heads, a.head_dim), dtype),) * 2
            return {"self": self_cache, "cross": cross}
        return transformer.init_cache(cfg, batch, max_seq, dtype)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins for the dry-run (no device allocation).
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32),
             "labels": _sds((B, S), jnp.int32)}
    if cfg.frontend == "audio_stub":
        batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    elif cfg.frontend == "vision_stub":
        batch["patches"] = _sds((B, cfg.num_patches, cfg.d_model), jnp.float32)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.frontend == "audio_stub":
        batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    elif cfg.frontend == "vision_stub":
        batch["patches"] = _sds((B, cfg.num_patches, cfg.d_model), jnp.float32)
    return batch


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int,
                dtype=None) -> Any:
    """Cache pytree as ShapeDtypeStructs (via eval_shape — zero allocation)."""
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(batch, max_seq, dtype))


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict:
    """All inputs of the step a cell lowers, as ShapeDtypeStructs.

    train  -> {"batch": ...}
    prefill-> {"batch": ..., "cache": ...}
    decode -> {"tokens": (B,1), "cache": ..., "cache_pos": scalar}
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape),
                "cache": cache_specs(cfg, B, S)}
    return {"tokens": _sds((B, 1), jnp.int32),
            "cache": cache_specs(cfg, B, S),
            "cache_pos": _sds((), jnp.int32)}
