# The paper's primary contribution: block-circulant weight representation
# with FFT-domain computation, as a first-class composable feature.
from . import bayesian, circulant, compression, conv, theory  # noqa: F401
from .circulant import (  # noqa: F401
    LinearSpec, apply_linear, bc_matmul_direct, bc_matmul_fft,
    bc_matmul_fused, bc_matmul_spectral, fused_spectral_cache,
    init_block_circulant, init_linear, materialize_dense, spectral_cache,
)
