"""Compression accounting + policy helpers (paper Fig. 3 reproduction).

Computes parameter counts, bytes, and FLOPs for a model under a
CompressionConfig, mirroring the paper's storage-reduction table and the
O(n²) -> O(n log n) complexity claim.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

from . import circulant as cc


@dataclass
class LayerCost:
    name: str
    layer_class: str          # ffn | attn | embed | expert | other
    n_in: int
    n_out: int
    count: int = 1            # how many identical instances (layers, experts)

    def dense_params(self) -> int:
        return self.n_in * self.n_out * self.count

    def bc_params(self, k: int) -> int:
        if k <= 0:
            return self.dense_params()
        p, q = cc.num_blocks(self.n_out, k), cc.num_blocks(self.n_in, k)
        return p * q * k * self.count

    def dense_flops(self, batch: int) -> int:
        return cc.dense_flops(batch, self.n_in, self.n_out) * self.count

    def bc_flops(self, batch: int, k: int, gauss: bool = True) -> int:
        if k <= 0:
            return self.dense_flops(batch)
        return cc.bc_flops(batch, self.n_in, self.n_out, k, gauss) * self.count


def summarize(costs: List[LayerCost], comp, batch: int = 1,
              gauss: bool = True) -> Dict[str, float]:
    """Totals + compression/speedup ratios for a layer-cost inventory."""
    dense_p = sum(c.dense_params() for c in costs)
    bc_p = sum(c.bc_params(comp.block_for(c.layer_class)) for c in costs)
    dense_f = sum(c.dense_flops(batch) for c in costs)
    bc_f = sum(c.bc_flops(batch, comp.block_for(c.layer_class), gauss)
               for c in costs)
    return {
        "dense_params": dense_p,
        "bc_params": bc_p,
        "param_compression": dense_p / max(bc_p, 1),
        "dense_flops": dense_f,
        "bc_flops": bc_f,
        "flop_reduction": dense_f / max(bc_f, 1),
    }
