"""Block-circulant CONV layers (paper §Inference and Training for CONV Layers).

The paper generalizes block-circulant structure to the rank-4 CONV weight
tensor F(r, r, C, P): if every slice F(·,·,c,p) is block-circulant, then the
im2col-reshaped matrix F ∈ R^{Cr²×P} is block-circulant, and Y = X·F runs
through the same FFT pipeline as an FC layer.

We implement exactly that: extract patches (im2col) with XLA's native patch
op, then dispatch to the block-circulant linear.  Used by the paper-table
benchmark CNNs (LeNet-like MNIST CNN, CIFAR CNN) and the correctness tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import circulant as cc


def im2col(x: jax.Array, r: int, stride: int = 1, padding: str = "VALID"):
    """x: (B, H, W, C) -> patches (B, Ho, Wo, r*r*C)."""
    patches = jax.lax.conv_general_dilated_patches(
        x, filter_shape=(r, r), window_strides=(stride, stride),
        padding=padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # conv_general_dilated_patches yields channel-major (C*r*r) features;
    # reorder to (r*r*C) so the circulant block structure matches F(Cr², P).
    B, Ho, Wo, _ = patches.shape
    C = x.shape[-1]
    patches = patches.reshape(B, Ho, Wo, C, r * r).swapaxes(-1, -2)
    return patches.reshape(B, Ho, Wo, r * r * C)


def init_conv_circulant(key, r: int, c_in: int, c_out: int, k: int,
                        dtype=jnp.float32):
    """First-row params for the im2col'd (r²·C_in × C_out) weight."""
    return cc.init_block_circulant(key, r * r * c_in, c_out, k, dtype)


def conv2d_block_circulant(x, w, r: int, c_out: int, stride: int = 1,
                           padding: str = "VALID", path: str = "fft"):
    """Block-circulant 2-D convolution via im2col. x: (B,H,W,C) -> (B,Ho,Wo,P)."""
    cols = im2col(x, r, stride, padding)                   # (B,Ho,Wo,r²C)
    fn = {"fft": cc.bc_matmul_fft, "direct": cc.bc_matmul_direct}[path]
    return fn(cols, w, c_out)


def conv2d_dense(x, f, stride: int = 1, padding: str = "VALID"):
    """Reference dense conv. f: (r, r, C_in, C_out)."""
    return jax.lax.conv_general_dilated(
        x, f, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
