"""The paper's theory leg (§Theoretical Foundation): block-circulant — and
generally low-displacement-rank (LDR) — networks retain the universal
approximation property.

The paper's proof hinges on the displacement-rank framework (Pan 2012):
a matrix W has displacement rank γ w.r.t. operator ∇_{A,B}(W) = W − A W B.
Circulant matrices have γ ≤ 2 under the (Z_1, Z_1^T) cyclic-shift operator
pair; block-circulant matrices have bounded γ per block.  We provide the
*computational* counterparts used by tests and docs:

* ``displacement(W)``/``displacement_rank(W)`` — the paper's structure
  certificate.  `test_theory.py` verifies circulant ⇒ rank ≤ 2 (numerical)
  and that a gradient step on first-row generators PRESERVES the
  certificate, while a dense perturbation breaks it — i.e. training stays
  inside the structured class without projection (paper's "no translation
  step" claim).
* ``is_block_circulant(W, k)`` — exact structural check.
* ``universal_approx_demo(...)`` — the empirical face of the theorem: a
  two-layer block-circulant net fits a continuous target on a compact set
  to arbitrary tolerance as width grows (used by tests with a fixed seed
  and modest width; the theorem guarantees the limit).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import circulant as cc


def cyclic_shift(n: int) -> np.ndarray:
    """Z_1: the unit cyclic down-shift matrix (Pan's displacement operator)."""
    Z = np.zeros((n, n))
    Z[np.arange(1, n), np.arange(n - 1)] = 1.0
    Z[0, n - 1] = 1.0
    return Z


def displacement(W: np.ndarray) -> np.ndarray:
    """∇(W) = W − Z_1 W Z_1^T  (square W)."""
    n = W.shape[0]
    Z = cyclic_shift(n)
    return W - Z @ W @ Z.T


def displacement_rank(W: np.ndarray, tol: float = 1e-5) -> int:
    s = np.linalg.svd(displacement(np.asarray(W, np.float64)),
                      compute_uv=False)
    return int((s > tol * max(s[0], 1e-30)).sum())


def is_block_circulant(W: np.ndarray, k: int, tol: float = 1e-5) -> bool:
    """Every k×k block satisfies C[r, c] == C[(r+1)%k, (c+1)%k]."""
    m, n = W.shape
    if m % k or n % k:
        return False
    B = W.reshape(m // k, k, n // k, k)
    rolled = np.roll(np.roll(B, 1, axis=1), 1, axis=3)
    return bool(np.abs(B - rolled).max() <= tol * (np.abs(W).max() + 1e-30))


def universal_approx_demo(
        target: Callable[[np.ndarray], np.ndarray],
        n_in: int = 8, width: int = 256, k: int = 8,
        steps: int = 300, lr: float = 5e-2, seed: int = 0,
        n_train: int = 512) -> Tuple[float, float]:
    """Fit a continuous target with a 2-layer block-circulant MLP.

    Returns (initial_mse, final_mse) on held-out points of the unit cube.
    The universal-approximation theorem for LDR nets guarantees
    final_mse -> 0 as width -> inf; tests check a concrete large drop.
    """
    rng = np.random.RandomState(seed)
    X = jnp.asarray(rng.uniform(-1, 1, size=(n_train, n_in)), jnp.float32)
    Xte = jnp.asarray(rng.uniform(-1, 1, size=(256, n_in)), jnp.float32)
    Y = jnp.asarray(target(np.asarray(X)), jnp.float32).reshape(-1, 1)
    Yte = jnp.asarray(target(np.asarray(Xte)), jnp.float32).reshape(-1, 1)

    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = {
        "w1": cc.init_block_circulant(ks[0], n_in, width, min(k, n_in)),
        "b1": jnp.zeros((width,)),
        "w2": cc.init_block_circulant(ks[1], width, k, k),  # out via first k
        "b2": jnp.zeros((1,)),
    }

    def fwd(p, x):
        h = jnp.tanh(cc.bc_matmul_fft(x, p["w1"], width) + p["b1"])
        return cc.bc_matmul_fft(h, p["w2"], 1) + p["b2"]

    def mse(p, x, y):
        return jnp.mean((fwd(p, x) - y) ** 2)

    init_err = float(mse(params, Xte, Yte))
    grad = jax.jit(jax.grad(mse))
    for _ in range(steps):
        g = grad(params, X, Y)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
    return init_err, float(mse(params, Xte, Yte))
