"""Variational-inference Bayesian training (paper §Algorithm-Hardware
Co-Optimizations, third leg).

Mean-field Gaussian posterior over every weight: q(w) = N(mu, softplus(rho)²).
Training samples w = mu + sigma*eps per step (reparameterization) and
minimizes  E_q[NLL] + KL(q || N(0, prior_sigma²)) / num_examples.
Inference uses the posterior mean (exactly what the paper deploys in
hardware: "using the average estimate of each weight").

Works on *any* param pytree — dense or block-circulant first-row params —
because the circulant structure is preserved under elementwise perturbation
of the first-row generators.
"""
from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_bayesian(params: Any, init_rho: float = -5.0) -> Any:
    """Wrap a deterministic param tree into {mu, rho} leaves."""
    return jax.tree.map(lambda p: {"mu": p, "rho": jnp.full_like(p, init_rho)},
                        params, is_leaf=lambda x: isinstance(x, jax.Array))


def _sigma(rho):
    return jax.nn.softplus(rho)


def sample(key, bparams: Any) -> Any:
    """Draw one weight realization via reparameterization."""
    leaves, treedef = jax.tree.flatten(
        bparams, is_leaf=lambda x: isinstance(x, dict) and "mu" in x)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, leaf in zip(keys, leaves):
        eps = jax.random.normal(k, leaf["mu"].shape, leaf["mu"].dtype)
        out.append(leaf["mu"] + _sigma(leaf["rho"]) * eps)
    return jax.tree.unflatten(treedef, out)


def posterior_mean(bparams: Any) -> Any:
    leaves, treedef = jax.tree.flatten(
        bparams, is_leaf=lambda x: isinstance(x, dict) and "mu" in x)
    return jax.tree.unflatten(treedef, [l["mu"] for l in leaves])


def kl_to_prior(bparams: Any, prior_sigma: float = 1.0) -> jax.Array:
    """Sum of KL(N(mu,s²) || N(0,p²)) over all weights (closed form)."""
    leaves, _ = jax.tree.flatten(
        bparams, is_leaf=lambda x: isinstance(x, dict) and "mu" in x)
    total = jnp.zeros(())
    for l in leaves:
        s = _sigma(l["rho"])
        kl = (jnp.log(prior_sigma / s) +
              (s ** 2 + l["mu"] ** 2) / (2 * prior_sigma ** 2) - 0.5)
        total = total + kl.sum()
    return total


def elbo_loss(key, bparams, nll_fn, num_examples: int,
              prior_sigma: float = 1.0) -> Tuple[jax.Array, Any]:
    """ELBO = E_q[NLL] + KL/num_examples; returns (loss, sampled params)."""
    w = sample(key, bparams)
    nll = nll_fn(w)
    return nll + kl_to_prior(bparams, prior_sigma) / num_examples, w
