"""llama4-maverick-400b-a17b — MoE decoder LM [hf:meta-llama/Llama-4 family].

48 layers alternating dense / MoE, d_model=5120, 40 heads (GQA kv=8,
head_dim=128), expert d_ff=8192, vocab=202048 (padded -> 202112), 128 experts
top-1 routing + a shared expert (llama4-style early-fusion backbone; the
multimodal fusion frontend is out of scope for the LM shapes).  400B total /
~17B active parameters: the per-expert FFNs dominate — exactly the layer
class the paper's block-circulant compression targets (per-expert first-row
generators, (E, p, q, k)).
"""
from .base import (ArchConfig, AttentionConfig, CompressionConfig, MoEConfig)


def get_config(compress: bool = True) -> ArchConfig:
    return ArchConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        d_ff=8192,
        vocab_size=202048,
        attention=AttentionConfig(num_heads=40, num_kv_heads=8, head_dim=128,
                                  rope_theta=5e5),
        moe=MoEConfig(num_experts=128, top_k=1, capacity_factor=1.25,
                      interleave=2, shared_expert=True,
                      router_group_size=512),
        compression=CompressionConfig(enabled=compress, block_ffn=128,
                                      block_attn=128, block_expert=128),
    )
