"""qwen3-4b — dense decoder LM [hf:Qwen/Qwen3-8B family].

36 layers, d_model=2560, 32 heads (GQA kv=8, head_dim=128), d_ff=9728
(swiglu), vocab=151936, per-head q/k RMS-norm (qk_norm), no QKV bias.
"""
from .base import ArchConfig, AttentionConfig, CompressionConfig


def get_config(compress: bool = True) -> ArchConfig:
    return ArchConfig(
        name="qwen3-4b",
        family="dense",
        num_layers=36,
        d_model=2560,
        d_ff=9728,
        vocab_size=151936,
        attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                                  qk_norm=True, rope_theta=1e6),
        compression=CompressionConfig(enabled=compress, block_ffn=128,
                                      block_attn=128),
    )
