"""phi-3-vision-4.2b — VLM backbone [hf:microsoft/Phi-3-vision-128k-instruct].

phi3-mini transformer backbone: 32 layers, d_model=3072, 32 heads (MHA,
kv=32, head_dim=96), d_ff=8192 (swiglu), vocab=32064 (padded 32064->32128).
The CLIP image frontend is a STUB per the assignment: ``input_specs`` feeds
576 precomputed patch embeddings that replace the first 576 token slots.
"""
from .base import ArchConfig, AttentionConfig, CompressionConfig


def get_config(compress: bool = True) -> ArchConfig:
    return ArchConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        num_layers=32,
        d_model=3072,
        d_ff=8192,
        vocab_size=32064,
        frontend="vision_stub",
        num_patches=576,
        attention=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=96),
        compression=CompressionConfig(enabled=compress, block_ffn=128,
                                      block_attn=128),
    )
