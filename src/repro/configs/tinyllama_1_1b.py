"""tinyllama-1.1b — llama2-architecture small LM [arXiv:2401.02385].

22 layers, d_model=2048, 32 heads (GQA kv=4, head_dim=64), d_ff=5632
(swiglu), vocab=32000.
"""
from .base import ArchConfig, AttentionConfig, CompressionConfig


def get_config(compress: bool = True) -> ArchConfig:
    return ArchConfig(
        name="tinyllama-1.1b",
        family="dense",
        num_layers=22,
        d_model=2048,
        d_ff=5632,
        vocab_size=32000,
        attention=AttentionConfig(num_heads=32, num_kv_heads=4, head_dim=64),
        compression=CompressionConfig(enabled=compress, block_ffn=128,
                                      block_attn=128),
    )
