"""Registry of the 10 assigned architectures (+ the paper's own benchmark
models, see benchmarks/).  ``get_config(arch_id)`` returns the full published
config; ``get_smoke_config(arch_id)`` returns a REDUCED config of the same
family for CPU smoke tests (small layers/width, few experts, tiny vocab).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from . import (gemma2_9b, llama4_maverick_400b, mixtral_8x7b, phi3_vision_4_2b,
               qwen2_5_3b, qwen3_4b, recurrentgemma_2b, tinyllama_1_1b,
               whisper_large_v3, xlstm_125m)
from .base import ArchConfig

_MODULES = {
    "whisper-large-v3": whisper_large_v3,
    "gemma2-9b": gemma2_9b,
    "qwen3-4b": qwen3_4b,
    "qwen2.5-3b": qwen2_5_3b,
    "tinyllama-1.1b": tinyllama_1_1b,
    "phi-3-vision-4.2b": phi3_vision_4_2b,
    "llama4-maverick-400b-a17b": llama4_maverick_400b,
    "mixtral-8x7b": mixtral_8x7b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "xlstm-125m": xlstm_125m,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, compress: bool = True) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return _MODULES[arch_id].get_config(compress=compress)


def get_smoke_config(arch_id: str, compress: bool = True) -> ArchConfig:
    """Reduced same-family config: runs a forward/train step on CPU."""
    full = get_config(arch_id, compress=compress)
    a = full.attention
    heads = min(a.num_heads, 4)
    kv = max(1, min(a.num_kv_heads, heads))
    heads = (heads // kv) * kv or kv
    block = 16 if full.compression.enabled else 0
    cfg = full.replace(
        num_layers=min(full.num_layers, 2 * max(
            1, len(full.recurrent.pattern) or (2 if full.moe.num_experts and
                                               full.moe.interleave > 1 else 1))),
        d_model=128,
        d_ff=256 if full.d_ff else 0,
        vocab_size=512,
        max_position=min(full.max_position, 512) if full.max_position else 0,
        encoder_layers=min(full.encoder_layers, 2),
        encoder_seq=min(full.encoder_seq, 16) if full.encoder_seq else 0,
        num_patches=min(full.num_patches, 8) if full.num_patches else 0,
        attention=dataclasses.replace(
            a, num_heads=heads, num_kv_heads=kv, head_dim=32,
            sliding_window=min(a.sliding_window, 16) if a.sliding_window else 0),
        moe=dataclasses.replace(full.moe,
                                num_experts=min(full.moe.num_experts, 4),
                                router_group_size=32,
                                capacity_factor=8.0),  # smoke: no token drops
        recurrent=dataclasses.replace(full.recurrent,
                                      lru_width=128 if full.recurrent.lru_width else 0,
                                      mlstm_heads=min(full.recurrent.mlstm_heads, 2)),
        compression=dataclasses.replace(
            full.compression, block_ffn=block and min(full.compression.block_ffn, block),
            block_attn=block and min(full.compression.block_attn, block),
            block_expert=block and min(full.compression.block_expert, block)),
        remat="none",
    )
    return cfg
