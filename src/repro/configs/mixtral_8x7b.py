"""mixtral-8x7b — MoE decoder LM [arXiv:2401.04088].

32 layers, d_model=4096, 32 heads (GQA kv=8, head_dim=128), expert
d_ff=14336 (swiglu), vocab=32000, 8 experts top-2 routing, sliding-window
attention (4096) on every layer — the SWA ring cache is what makes the
long_500k decode cell O(window) rather than O(seq).
"""
from .base import (ArchConfig, AttentionConfig, CompressionConfig, MoEConfig)


def get_config(compress: bool = True) -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        d_ff=14336,
        vocab_size=32000,
        attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                                  sliding_window=4096, layout="sliding",
                                  rope_theta=1e6),
        moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25,
                      router_group_size=512),
        compression=CompressionConfig(enabled=compress, block_ffn=128,
                                      block_attn=128, block_expert=128),
    )
