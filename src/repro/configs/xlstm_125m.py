"""xlstm-125m — sLSTM + mLSTM block stack [arXiv:2405.04517].

12 layers, d_model=768, 4 heads, vocab=50304, d_ff=0 (the up/down
projections live inside the xLSTM cells; mLSTM uses a 2x up-projection).
Pattern (mlstm, mlstm, slstm) x 4.  Matrix/scalar memories are O(1) state =>
runs the long_500k decode cell.  Gate recurrences are elementwise; the
cells' q/k/v/up/down projections take the paper's block-circulant form.
"""
from .base import (ArchConfig, AttentionConfig, CompressionConfig,
                   RecurrentConfig)


def get_config(compress: bool = True) -> ArchConfig:
    return ArchConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        d_ff=0,
        vocab_size=50304,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=192),
        recurrent=RecurrentConfig(kind="xlstm", mlstm_heads=4,
                                  proj_factor=2.0,
                                  pattern=("mlstm", "mlstm", "slstm")),
        compression=CompressionConfig(enabled=compress, block_ffn=128,
                                      block_attn=128),
    )
