"""qwen2.5-3b — dense decoder LM [hf:Qwen/Qwen2.5 family].

36 layers, d_model=2048, 16 heads (GQA kv=2, head_dim=128), d_ff=11008
(swiglu), vocab=151936, QKV bias enabled (biases stay dense — the circulant
structure acts on the weight matrix only).
"""
from .base import ArchConfig, AttentionConfig, CompressionConfig


def get_config(compress: bool = True) -> ArchConfig:
    return ArchConfig(
        name="qwen2.5-3b",
        family="dense",
        num_layers=36,
        d_model=2048,
        d_ff=11008,
        vocab_size=151936,
        attention=AttentionConfig(num_heads=16, num_kv_heads=2, head_dim=128,
                                  qkv_bias=True, rope_theta=1e6),
        compression=CompressionConfig(enabled=compress, block_ffn=128,
                                      block_attn=128),
    )
