"""whisper-large-v3 — encoder-decoder audio backbone [arXiv:2212.04356].

32 enc + 32 dec layers, d_model=1280, 20 heads (MHA, kv=20), d_ff=5120,
vocab=51866.  The conv/mel frontend is a STUB per the assignment:
``input_specs`` feeds precomputed 1500-frame embeddings to the encoder.
Decoder uses a learned position table sized for the assigned decode_32k
shape (real whisper caps at 448 — backbone-equivalent compute, noted in
DESIGN.md).  Vocab zero-pads 51866 -> 51968 = 406*128 (paper's padding rule).
"""
from .base import ArchConfig, AttentionConfig, CompressionConfig


def get_config(compress: bool = True) -> ArchConfig:
    return ArchConfig(
        name="whisper-large-v3",
        family="audio",
        num_layers=32,                 # decoder
        encoder_layers=32,
        d_model=1280,
        d_ff=5120,
        vocab_size=51866,
        is_encoder_decoder=True,
        encoder_seq=1500,
        frontend="audio_stub",
        max_position=32768,
        norm="layernorm",
        ffn_activation="gelu",
        attention=AttentionConfig(num_heads=20, num_kv_heads=20, head_dim=64,
                                  learned_pos=True),
        compression=CompressionConfig(enabled=compress, block_ffn=128,
                                      block_attn=128),
    )
