"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig``; every workload cell is an
``(ArchConfig, ShapeSpec)`` pair.  Configs are plain frozen dataclasses so they
hash, print, and diff cleanly, and so the launcher can build them from CLI args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Compression (the paper's technique) -- per-layer-class block sizes.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CompressionConfig:
    """Block-circulant compression policy (paper §Algorithm).

    ``block_*`` give the circulant block size k per layer class; 0/None means
    dense.  ``path`` selects the lowering: 'fft' = per-call rfft pipeline,
    'spectral' = cached-Wf frequency domain (decoupled FFT/IFFT, inference),
    'direct' = materialized circulant matmul (oracle / tiny k), 'auto'.
    """
    enabled: bool = False
    block_ffn: int = 0
    block_attn: int = 0
    block_embed: int = 0          # LM head / embedding projection
    block_expert: int = 0         # MoE expert FFNs
    path: str = "auto"
    gauss_trick: bool = True      # 3-mult complex product (beyond-paper opt)
    # fuse q/k/v and gate/up circulant projections sharing an input into one
    # FFT pipeline (beyond-paper; see EXPERIMENTS.md §Perf)
    fuse_projections: bool = False

    def block_for(self, layer_class: str) -> int:
        if not self.enabled:
            return 0
        return {
            "ffn": self.block_ffn,
            "attn": self.block_attn,
            "embed": self.block_embed,
            "expert": self.block_expert,
        }.get(layer_class, 0)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    # every `interleave`-th layer is MoE (1 = every layer, 2 = alternating).
    interleave: int = 1
    shared_expert: bool = False
    router_group_size: int = 512  # tokens per routing group (bounds dispatch mem)


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 128
    rope_theta: float = 10000.0
    qk_norm: bool = False          # qwen3
    qkv_bias: bool = False         # qwen2.5
    logit_softcap: float = 0.0     # gemma2 (50.0)
    sliding_window: int = 0        # mixtral / local layers (0 = global)
    # pattern over layers: 'global', 'local', 'alternating' (gemma2),
    # 'sliding' (mixtral — every layer windowed)
    layout: str = "global"
    learned_pos: bool = False      # whisper (no RoPE)


@dataclass(frozen=True)
class RecurrentConfig:
    kind: str = "none"             # 'rglru' | 'xlstm'
    lru_width: int = 0
    conv1d_width: int = 4
    # block pattern, e.g. ('rec','rec','attn') for recurrentgemma 1:2,
    # ('mlstm','mlstm','mlstm','slstm') for xlstm
    pattern: Tuple[str, ...] = ()
    mlstm_heads: int = 4
    proj_factor: float = 2.0       # xlstm up-projection factor


@dataclass(frozen=True)
class ArchConfig:
    name: str = "arch"
    family: str = "dense"          # dense|moe|hybrid|ssm|audio|vlm
    num_layers: int = 4
    d_model: int = 256
    d_ff: int = 1024
    vocab_size: int = 1024
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    recurrent: RecurrentConfig = field(default_factory=RecurrentConfig)
    compression: CompressionConfig = field(default_factory=CompressionConfig)
    # model-level switches
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0           # whisper: 1500 post-conv frames
    frontend: str = "none"         # 'audio_stub' | 'vision_stub'
    num_patches: int = 0           # vlm stub: patch embeddings prepended
    ffn_activation: str = "silu"   # 'silu'(swiglu) | 'gelu' | 'geglu'
    norm: str = "rmsnorm"          # 'rmsnorm' | 'layernorm'
    logit_softcap: float = 0.0     # gemma2 final-logit softcap (30.0)
    tie_embeddings: bool = True
    max_position: int = 0          # learned-pos table size (0 = rope/none)
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"            # 'none'|'full'  (scan-level remat policy)
    # training
    zloss: float = 1e-4
    # lowering controls (roofline runs unroll scans: XLA cost_analysis counts
    # a while body ONCE regardless of trip count, so scanned lowerings
    # undercount FLOPs/collectives — see roofline/analysis.py)
    unroll_scan: bool = False
    attn_q_chunk: int = 2048
    attn_kv_chunk: int = 1024
    mlstm_chunk: int = 256
    # KV-cache storage dtype ('bfloat16' | 'float8_e4m3fn'): decode is
    # cache-read bound, f8 halves the dominant memory term (§Perf)
    kv_cache_dtype: str = "bfloat16"

    # -- derived ----------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.attention.num_heads * self.attention.head_dim

    @property
    def kv_dim(self) -> int:
        return self.attention.num_kv_heads * self.attention.head_dim

    def padded_vocab(self, multiple: int = 128) -> int:
        v = self.vocab_size
        return ((v + multiple - 1) // multiple) * multiple

    def with_compression(self, **kw) -> "ArchConfig":
        return dataclasses.replace(
            self, compression=dataclasses.replace(self.compression, enabled=True, **kw))

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    """A workload cell: sequence length x global batch, and which step it lowers."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # 'train' | 'prefill' | 'decode'

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}

# Archs for which long_500k is runnable (bounded-state / sub-quadratic).
LONG_CONTEXT_OK = frozenset({"recurrentgemma-2b", "xlstm-125m", "mixtral-8x7b"})


def cell_is_applicable(arch: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, and why not if it doesn't."""
    if shape.name == "long_500k" and arch.name not in LONG_CONTEXT_OK:
        return False, "pure full-attention arch: long_500k needs sub-quadratic attention (skip per assignment; see DESIGN.md)"
    return True, ""
