"""gemma2-9b — dense decoder LM [arXiv:2408.00118].

42 layers, d_model=3584, 16 heads (GQA kv=8, head_dim=256), d_ff=14336
(geglu), vocab=256000.  Local(4096-window)/global alternating attention,
attention-logit softcap 50, final-logit softcap 30, sandwich norms,
sqrt(d_model) embedding scaling.
"""
from .base import ArchConfig, AttentionConfig, CompressionConfig


def get_config(compress: bool = True) -> ArchConfig:
    return ArchConfig(
        name="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        d_ff=14336,
        vocab_size=256000,
        ffn_activation="gelu",
        logit_softcap=30.0,
        attention=AttentionConfig(num_heads=16, num_kv_heads=8, head_dim=256,
                                  logit_softcap=50.0, sliding_window=4096,
                                  layout="alternating"),
        compression=CompressionConfig(enabled=compress, block_ffn=128,
                                      block_attn=128),
    )
