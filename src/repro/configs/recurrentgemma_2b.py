"""recurrentgemma-2b — Griffin hybrid (RG-LRU + local attention) [arXiv:2402.19427].

26 layers in a 1:2 pattern (rec, rec, attn_local), d_model=2560, 10 heads
(MQA kv=1, head_dim=256), d_ff=7680 (geglu), vocab=256000, 2048-token local
attention window, RG-LRU recurrence width 2560.  Bounded state => runs the
long_500k decode cell.  The diagonal RG-LRU recurrence has no weight matrix
to compress (DESIGN.md §Arch-applicability); the block's in/out projections
are block-circulant.
"""
from .base import (ArchConfig, AttentionConfig, CompressionConfig,
                   RecurrentConfig)


def get_config(compress: bool = True) -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        d_ff=7680,
        vocab_size=256000,
        ffn_activation="gelu",
        attention=AttentionConfig(num_heads=10, num_kv_heads=1, head_dim=256,
                                  sliding_window=2048),
        recurrent=RecurrentConfig(kind="rglru", lru_width=2560,
                                  conv1d_width=4,
                                  pattern=("rec", "rec", "attn_local")),
        compression=CompressionConfig(enabled=compress, block_ffn=128,
                                      block_attn=128),
    )
