"""Fleet resilience: goodput before / during / after a replica crash.

A 2-replica fleet (repro.fleet: JSQ router + health machine + recompute
migration) serves a deadline-carrying Poisson workload offered at ~2x the
fleet's measured capacity, and one replica is killed mid-serving.  The
bench timestamps every fleet-level settlement and splits the timeline at
the kill and at the settlement of the last MIGRATED request:

* ``before``         — steady state, both replicas serving
* ``during_crash``   — kill -> last migrated request settles: the fleet is
  re-placing salvaged work on the survivor, goodput dips
* ``after_recovery`` — survivor-only steady state (~half the fleet's
  capacity; under 2x oversubscription the deadline misses climb)

Goodput counts only tokens of requests that FINISHED (deadline expiries
surface as TIMEOUT and contribute nothing a client would read).  The
lifecycle invariant rides along: every request settles in exactly one
terminal status, zero lost, and the survivor's page pool ends restored.

Crash-window numbers are inherently noisy (the kill lands wherever the
scheduler was); gate.py reports them as informational rather than gating.

  PYTHONPATH=src python benchmarks/bench_fleet.py --out BENCH_fleet.json
  PYTHONPATH=src python benchmarks/bench_fleet.py --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.fleet import DOWN, EngineReplica, Router
from repro.models.registry import build_model
from repro.obs import Obs
from repro.serve.engine import ContinuousEngine, Request
from repro.serve.scheduler import FINISHED_STATUSES

try:                                   # package run (python -m benchmarks.run)
    from .common import make_serving_workload
except ImportError:                    # standalone (python benchmarks/...)
    from common import make_serving_workload


def _phase(settles, t0, t1, min_window=1e-3):
    """Goodput over one window of the settlement timeline."""
    window = max(t1 - t0, min_window)
    inside = [(t, res) for t, res in settles if t0 <= t < t1]
    good = [res for _, res in inside if res["status"] in FINISHED_STATUSES]
    return {
        "window_s": window,
        "settled": len(inside),
        "finished": len(good),
        "goodput_tokens_per_s":
            sum(r["decode_len"] for r in good) / window,
    }


def bench_fleet_crash(cfg, params, reqs, *, engine_kw, replicas=2,
                      oversubscription=2.0, seed=0) -> dict:
    """One crash experiment: calibrate capacity, offer 2x, kill replica 0
    mid-serving, phase the goodput timeline around the crash."""
    # -- calibrate: saturated single-engine drain = per-replica capacity
    cal = ContinuousEngine(cfg, params, obs=Obs(), **engine_kw)
    cal.generate(reqs)                                  # compile + warm
    t0 = time.perf_counter()
    cal.generate(reqs)
    makespan_1 = time.perf_counter() - t0
    # generous enough that steady-state requests finish despite 2x
    # oversubscription queueing — the misses concentrate in the crash
    # window and the survivor-only tail
    deadline_s = round(2.0 * makespan_1, 3)
    # offer the whole workload over the span the fleet could drain it in,
    # divided by the oversubscription factor
    span = makespan_1 / replicas / oversubscription
    arrivals = [i * span / len(reqs) for i in range(len(reqs))]
    dl_reqs = [dataclasses.replace(r, deadline_s=deadline_s) for r in reqs]

    # -- fleet under test (each engine warmed so compile stays out of the
    # timed window)
    obs = Obs()
    pool = []
    for i in range(replicas):
        eng = ContinuousEngine(cfg, params, obs=obs.scoped(replica=f"r{i}"),
                               **engine_kw)
        eng.generate(reqs[:2])
        pool.append(EngineReplica(f"r{i}", eng))
    router = Router(pool, policy="jsq", seed=seed, obs=obs)
    victim = pool[0]

    orders = {router.submit(r, arrival_s=a): None
              for r, a in zip(dl_reqs, arrivals)}
    settles = []                        # (router-clock time, result)
    seen = set()
    killed_at = None
    recovered_at = None
    pending_g = obs.registry.gauge("fleet.pending_depth")
    while len(seen) < len(orders):
        if not router.step():
            time.sleep(2e-4)
        now = router.now()
        for o in orders:
            if o not in seen and router.result(o) is not None:
                seen.add(o)
                settles.append((now, router.result(o)))
        if killed_at is None and len(seen) >= max(1, len(orders) // 6) and \
                any(s.tokens for s in victim.engine.scheduler.running):
            victim.force_crash()
            killed_at = router.now()
        elif killed_at is not None and recovered_at is None and \
                victim.salvaged and pending_g.value == 0:
            # every salvaged request is re-placed on the survivor: the
            # fleet is back to (reduced-capacity) steady state
            recovered_at = now
    t_end = router.now()
    assert killed_at is not None, "workload drained before the kill armed"
    if recovered_at is None:
        recovered_at = t_end

    results = [router.result(o, pop=True) for o in orders]
    assert all(r is not None for r in results), "lost requests"
    statuses = {}
    for r in results:
        statuses[r["status"]] = statuses.get(r["status"], 0) + 1
    migrated = [r for r in results if r["migrations"] > 0]
    survivors = [p for p in pool if p.state != DOWN]
    assert survivors and all(
        p.engine.stats()["pages_in_use"] == 0 for p in survivors)
    router.drain()

    rs = router.stats()
    return {
        "deadline_s": deadline_s,
        "oversubscription": oversubscription,
        "single_replica_makespan_s": makespan_1,
        "killed_at_s": killed_at,
        "recovered_at_s": recovered_at,
        "makespan_s": t_end,
        "phases": {
            "before": _phase(settles, 0.0, killed_at),
            "during_crash": _phase(settles, killed_at, recovered_at),
            "after_recovery": _phase(settles, recovered_at,
                                     t_end + 1e-9),
        },
        "statuses": statuses,
        "lost_requests": len(reqs) - sum(statuses.values()),
        "served_frac": sum(statuses.get(s, 0) for s in FINISHED_STATUSES)
        / len(reqs),
        "migrated_requests": len(migrated),
        "migrated_finished": sum(1 for r in migrated
                                 if r["status"] in FINISHED_STATUSES),
        "failovers": rs["failovers"],
        "place_retries": rs["place_retries"],
        "shed": rs["shed"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--oversubscription", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI workload (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = min(args.requests, 12)

    cfg = get_smoke_config(args.arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    reqs, _ = make_serving_workload(
        args.requests, prompt_lens=(8, 12, 16), new_tokens=(8, 12, 16),
        vocab=cfg.vocab_size, seed=args.seed)
    engine_kw = dict(max_slots=4, max_seq=32, page_size=8,
                     decode_chunk=4, admission="optimistic",
                     max_queue=args.requests)

    result = {
        "bench": "fleet",
        "arch": args.arch,
        "requests": args.requests,
        "replicas": args.replicas,
        "device": jax.devices()[0].platform,
        "fleet_crash": bench_fleet_crash(
            cfg, params, reqs, engine_kw=engine_kw,
            replicas=args.replicas,
            oversubscription=args.oversubscription, seed=args.seed),
    }
    fc = result["fleet_crash"]
    print(f"fleet crash bench: {args.requests} reqs over {args.replicas} "
          f"replicas @ {args.oversubscription}x, deadline "
          f"{fc['deadline_s']}s")
    for name, ph in fc["phases"].items():
        print(f"  {name:16s} window={ph['window_s']:.3f}s "
              f"settled={ph['settled']:3d} "
              f"goodput={ph['goodput_tokens_per_s']:8.1f} tok/s")
    print(f"  statuses={fc['statuses']} migrated={fc['migrated_requests']} "
          f"lost={fc['lost_requests']}")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
