"""Perf-regression gate: compare a fresh BENCH_*.json against a baseline.

    python benchmarks/gate.py --baseline BENCH_serving.json \
        --candidate results/BENCH_serving_smoke.json [--tol-scale 3] \
        [--out delta.md]

Both files are flattened to dotted-path -> numeric-leaf maps and every
shared metric is judged by a DIRECTION-AWARE tolerance rule (first
matching pattern wins; patterns are fnmatch'd against the full dotted
path, then the leaf key):

* ``higher`` — throughput-like: the candidate may not DROP more than
  ``tol`` relative (tokens/s, speedups: 10%).  Rising is never a failure.
* ``lower``  — latency-like: the candidate may not RISE more than ``tol``
  relative (p99/p50/makespan: 15%).
* ``exact``  — parity fields that are deterministic functions of the
  workload and pool math (token counts, pool bytes, slot capacities,
  ``lost_requests``): any difference fails.
* ``info``   — reported in the delta table, never gated.  This is the
  DEFAULT for unknown metrics: a new bench field must earn a rule before
  it can break CI, and timing-noisy sections (overload goodput, status
  mixes under deadline pressure, obs overhead) stay visible but neutral.

``--tol-scale`` multiplies every relative tolerance — CI gates a smoke
run against a same-runner self-baseline with ``--tol-scale 3`` (two runs
minutes apart still share no warm caches), while the deliberately
perturbed leg uses the default scale so a synthetic 20% tokens/s
regression must fail.

Output is a markdown delta table (worst offenders first); exit status is
nonzero iff any gated metric failed — the perf trajectory the ROADMAP's
bench-driven items hang off.
"""
from __future__ import annotations

import argparse
import json
import sys
from fnmatch import fnmatch
from typing import Dict, List, Optional, Tuple

# (pattern, kind, tol) — first match wins; kind in higher/lower/exact/info
DEFAULT_RULES: Tuple[Tuple[str, str, float], ...] = (
    # timing-noisy or derived-ratio sections: visible, never gated
    ("*overhead_frac", "info", 0.0),
    ("*health_capture_frac", "info", 0.0),
    ("*overload*", "info", 0.0),
    ("*statuses*", "info", 0.0),
    ("*p99_ratio*", "info", 0.0),
    # fleet crash-window metrics: where the kill lands depends on wall
    # time, so everything phased around it is informational — the
    # exact-zero lost-request invariant below still gates
    ("*during_crash*", "info", 0.0),
    ("*after_recovery*", "info", 0.0),
    ("*killed_at_s", "info", 0.0),
    ("*recovered_at_s", "info", 0.0),
    ("*migrated*", "info", 0.0),
    ("*failovers", "info", 0.0),
    ("*place_retries", "info", 0.0),
    ("*shed*", "info", 0.0),
    ("*served_frac", "info", 0.0),
    ("fleet_crash*goodput_tokens_per_s", "info", 0.0),
    ("fleet_crash*window_s", "info", 0.0),
    ("fleet_crash*settled", "info", 0.0),
    ("fleet_crash*finished", "info", 0.0),
    ("fleet_crash*deadline_s", "info", 0.0),
    ("fleet_crash*makespan_s", "info", 0.0),
    ("fleet_crash*oversubscription", "info", 0.0),
    # numerics & quality health plane (obs/health.py): online shadow-
    # oracle greedy agreement is a deterministic function of (arch, seed,
    # workload, quant policy) — teacher-forced greedy replay — so it
    # gates EXACTLY; drift magnitudes, clip/saturation rates, and
    # requant accounting are hardware/noise-tinged and stay visible-only
    ("*greedy_agreement", "exact", 0.0),
    ("*logit_drift*", "info", 0.0),
    ("*clip_rate*", "info", 0.0),
    ("*clip.*", "info", 0.0),
    ("*requant*", "info", 0.0),
    ("*nonfinite*", "info", 0.0),
    ("*shadow*", "info", 0.0),
    ("*act_absmax*", "info", 0.0),
    # throughput: may not drop
    ("*tokens_per_s", "higher", 0.10),
    ("speedup*", "higher", 0.10),
    ("*speedup*", "higher", 0.10),
    # latency: may not rise
    ("*p99*", "lower", 0.15),
    ("*p50*", "lower", 0.15),
    ("*mean_latency_s", "lower", 0.15),
    ("*makespan_s", "lower", 0.15),
    # deterministic parity: workload token counts, pool math, invariants
    ("*lost_requests", "exact", 0.0),
    ("kv_slots_ratio*", "exact", 0.0),
    ("*.tokens", "exact", 0.0),
    ("*pool_bytes", "exact", 0.0),
    ("*bytes_per_slot", "exact", 0.0),
    ("*usable_pages", "exact", 0.0),
    ("*.slots", "exact", 0.0),
    ("*pad_waste", "exact", 0.0),
)
UNKNOWN_RULE = ("<unknown>", "info", 0.0)


def flatten(obj, prefix: str = "") -> Dict[str, float]:
    """Dotted-path -> numeric leaves; strings/bools/None/lists are config
    echo, not metrics, and are skipped."""
    out: Dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, bool) or obj is None:
        pass
    elif isinstance(obj, (int, float)):
        out[prefix[:-1]] = float(obj)
    return out


def match_rule(path: str, rules=DEFAULT_RULES) -> Tuple[str, str, float]:
    leaf = path.rsplit(".", 1)[-1]
    for pat, kind, tol in rules:
        if fnmatch(path, pat) or fnmatch(leaf, pat):
            return (pat, kind, tol)
    return UNKNOWN_RULE


def judge(path: str, base: float, cand: float, tol_scale: float = 1.0,
          rules=DEFAULT_RULES) -> Dict:
    """One metric's verdict: PASS / FAIL / INFO plus the signed relative
    delta (positive = candidate higher)."""
    pat, kind, tol = match_rule(path, rules)
    rel = (cand - base) / abs(base) if base else (0.0 if cand == base
                                                  else float("inf"))
    verdict = "INFO"
    if kind == "exact":
        verdict = "PASS" if cand == base else "FAIL"
    elif kind == "higher":
        verdict = "FAIL" if rel < -tol * tol_scale else "PASS"
    elif kind == "lower":
        verdict = "FAIL" if rel > tol * tol_scale else "PASS"
    return {"metric": path, "baseline": base, "candidate": cand,
            "rel": rel, "rule": kind, "pattern": pat,
            "tol": tol * tol_scale, "verdict": verdict}


def compare(baseline: Dict, candidate: Dict, tol_scale: float = 1.0,
            rules=DEFAULT_RULES) -> Dict:
    """Flatten + judge every shared metric; keys present on only one side
    are listed (schema drift is worth seeing) but never gated."""
    fb, fc = flatten(baseline), flatten(candidate)
    rows = [judge(p, fb[p], fc[p], tol_scale, rules)
            for p in sorted(set(fb) & set(fc))]
    sev = {"FAIL": 0, "PASS": 1, "INFO": 2}
    rows.sort(key=lambda r: (sev[r["verdict"]], -abs(r["rel"])))
    return {
        "rows": rows,
        "failed": [r for r in rows if r["verdict"] == "FAIL"],
        "only_baseline": sorted(set(fb) - set(fc)),
        "only_candidate": sorted(set(fc) - set(fb)),
    }


def _fmt(v: float) -> str:
    return f"{v:.6g}"


def markdown_table(result: Dict, max_info_rows: int = 20) -> str:
    """The human-facing delta report: failures + gated passes in full,
    informational rows truncated (they dominate by count)."""
    lines = ["| metric | baseline | candidate | Δ | rule | verdict |",
             "|---|---|---|---|---|---|"]
    shown_info = 0
    hidden = 0
    for r in result["rows"]:
        if r["verdict"] == "INFO":
            shown_info += 1
            if shown_info > max_info_rows:
                hidden += 1
                continue
        delta = ("∞" if r["rel"] == float("inf")
                 else f"{r['rel'] * 100:+.1f}%")
        rule = (r["rule"] if r["rule"] in ("exact", "info")
                else f"{r['rule']} ±{r['tol'] * 100:.0f}%")
        mark = {"FAIL": "**FAIL**", "PASS": "PASS",
                "INFO": "info"}[r["verdict"]]
        lines.append(f"| {r['metric']} | {_fmt(r['baseline'])} | "
                     f"{_fmt(r['candidate'])} | {delta} | {rule} | "
                     f"{mark} |")
    if hidden:
        lines.append(f"| … {hidden} more informational rows | | | | | |")
    for label, key in (("baseline only", "only_baseline"),
                       ("candidate only", "only_candidate")):
        if result[key]:
            lines.append("")
            lines.append(f"Metrics in {label} (not gated): "
                         + ", ".join(result[key][:10])
                         + (" …" if len(result[key]) > 10 else ""))
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Direction-aware perf-regression gate over BENCH_*.json "
                    "files (docs/benchmarks.md).")
    ap.add_argument("--baseline", required=True, metavar="FILE",
                    help="the checked-in (or self-baseline) BENCH json")
    ap.add_argument("--candidate", required=True, metavar="FILE",
                    help="the fresh BENCH json to judge")
    ap.add_argument("--tol-scale", type=float, default=1.0,
                    help="multiply every relative tolerance (CI self-"
                         "baseline noise: 3)")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="also write the markdown delta table to FILE")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.candidate) as f:
        candidate = json.load(f)
    result = compare(baseline, candidate, tol_scale=args.tol_scale)
    table = markdown_table(result)
    n_gated = sum(1 for r in result["rows"] if r["verdict"] != "INFO")
    head = (f"## perf gate: `{args.candidate}` vs `{args.baseline}` "
            f"(tol×{args.tol_scale:g})\n\n"
            f"{len(result['rows'])} shared metrics, {n_gated} gated, "
            f"{len(result['failed'])} failed\n")
    report = head + "\n" + table + "\n"
    print(report)
    if args.out:
        with open(args.out, "w") as f:
            f.write(report)
    if result["failed"]:
        for r in result["failed"]:
            print(f"[gate] FAIL {r['metric']}: {_fmt(r['baseline'])} -> "
                  f"{_fmt(r['candidate'])} ({r['rel'] * 100:+.1f}%, rule "
                  f"{r['rule']} ±{r['tol'] * 100:.0f}%)", file=sys.stderr)
        return 1
    print("[gate] PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
