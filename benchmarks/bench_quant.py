"""Fixed-point serving quantization: pool bytes/slot, equal-KV-memory
slot capacity + throughput, and fixed-point accuracy parity.

Three questions, one JSON (the quant half of the paper's co-optimization
story — the algorithm half's compression benches are bench_compression /
bench_accuracy_tradeoff):

* **bytes/slot** — what one decode slot's worst-case KV reservation costs
  per pool dtype (f32 / bf16 / int8+scales), analytic via
  ``kvcache.page_bytes`` (no allocation).
* **equal KV memory** — pools of every dtype sized to the SAME byte
  budget (the f32 pool's footprint): int8 carries ~4x the pages, so
  ~4x the slots (~2x vs bf16); a saturated drain of an oversubscribed
  workload measures what the extra slots buy in tokens/s on this host.
* **parity** — teacher-forced greedy agreement + max logit drift of the
  int8-KV (and int8-weight) stack vs the f32 oracle
  (``quant.calibrate``), per servable arch (tinyllama only in --smoke).

  PYTHONPATH=src python benchmarks/bench_quant.py --out BENCH_quant.json
  PYTHONPATH=src python benchmarks/bench_quant.py --smoke
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs.registry import get_smoke_config
from repro.models.registry import build_model
from repro.quant import QuantPolicy, calibrate
from repro.serve.kvcache import page_bytes, pages_for

try:                                   # package run (python -m benchmarks.run)
    from .common import bench_kv_equal_memory, make_serving_workload
except ImportError:                    # standalone (python benchmarks/...)
    from common import bench_kv_equal_memory, make_serving_workload

DTYPES = ("f32", "bf16", "int8")


def bench_equal_memory(cfg, params, reqs, **kw):
    """Size every dtype's pool to the f32 pool's byte budget; drain the
    same oversubscribed backlog through each and keep the best wall
    (shared core: ``common.bench_kv_equal_memory`` — the same rows feed
    bench_serving's ``kv_equal_memory`` section)."""
    out = bench_kv_equal_memory(cfg, params, reqs, **kw)
    for kv_dtype, row in out.items():
        print(f"[bench_quant] equal-mem {kv_dtype:>5}: {row['slots']:3d} "
              f"slots, {row['kv_pool_bytes'] / 1e6:6.2f}MB pool, "
              f"{row['tokens_per_s']:7.1f} tok/s", flush=True)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="f32 slot count the shared byte budget is sized to")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--parity-tokens", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_quant.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.requests = min(args.requests, 8)
        args.iters = 1
        prompt_lens, new_tokens = (8, 16), (4, 8, 16)
    else:
        prompt_lens, new_tokens = (8, 16, 24, 32, 40), (4, 8, 16, 24, 64)
    max_seq = max(prompt_lens) + max(new_tokens)

    cfg = get_smoke_config(args.arch)
    if not args.smoke:
        cfg = cfg.replace(num_layers=4, d_model=256, d_ff=512)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    reqs, _ = make_serving_workload(args.requests, prompt_lens=prompt_lens,
                                    new_tokens=new_tokens,
                                    vocab=cfg.vocab_size)

    pages_per_slab = pages_for(max_seq, args.page_size)
    pool_rows = {d: {"page_bytes": page_bytes(cfg, args.page_size,
                                              QuantPolicy(kv_dtype=d)),
                     "bytes_per_slot": pages_per_slab * page_bytes(
                         cfg, args.page_size, QuantPolicy(kv_dtype=d))}
                 for d in DTYPES}
    for d, row in pool_rows.items():
        print(f"[bench_quant] bytes/slot {d:>5}: {row['bytes_per_slot']}",
              flush=True)

    equal = bench_equal_memory(
        cfg, params, reqs, budget_pages_f32=args.max_batch * pages_per_slab,
        page_size=args.page_size, max_seq=max_seq,
        decode_chunk=args.decode_chunk, iters=args.iters)

    archs = [args.arch] if args.smoke else None
    parity = []
    for policy in (QuantPolicy(kv_dtype="int8"),
                   QuantPolicy(kv_dtype="int8", quant_weights=True)):
        parity += calibrate.servable_parity_sweep(
            policy, archs=archs, new_tokens=args.parity_tokens)
    for r in parity:
        print(f"[bench_quant] parity {r['arch']:>26} "
              f"kv={r['policy']['kv_dtype']} "
              f"w={'int8' if r['policy']['quant_weights'] else 'f32'}: "
              f"agree {r['greedy_agreement']:.4f} "
              f"drift {r['max_logit_drift']:.4f}", flush=True)

    kv_only = [r for r in parity if not r["policy"]["quant_weights"]]
    result = {
        "arch": args.arch,
        "requests": args.requests,
        "page_size": args.page_size,
        "max_seq": max_seq,
        "backend": jax.default_backend(),
        "pool_bytes": pool_rows,
        "equal_kv_memory": equal,
        "parity": parity,
        "slots_ratio_int8_vs_f32": equal["int8"]["slots"]
        / equal["f32"]["slots"],
        "slots_ratio_int8_vs_bf16": equal["int8"]["slots"]
        / equal["bf16"]["slots"],
        "tokens_ratio_int8_vs_f32": equal["int8"]["tokens_per_s"]
        / equal["f32"]["tokens_per_s"],
        "min_kv_greedy_agreement": min(r["greedy_agreement"]
                                       for r in kv_only),
    }
    print(f"[bench_quant] equal-KV-memory slots: int8/f32 = "
          f"{result['slots_ratio_int8_vs_f32']:.2f}x, int8/bf16 = "
          f"{result['slots_ratio_int8_vs_bf16']:.2f}x; tokens/s int8/f32 = "
          f"{result['tokens_ratio_int8_vs_f32']:.2f}x; min kv-parity "
          f"agreement {result['min_kv_greedy_agreement']:.4f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print("wrote", args.out)
    return result


if __name__ == "__main__":
    main()
