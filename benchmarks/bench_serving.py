"""Serving throughput and latency: batch-synchronous engine (with and
without prompt-length bucketing) vs the continuous-batching engine.

Two regimes over the same mixed-length workload (mixed prompt lengths AND
decode budgets — where the batch engine pays its two synchronization taxes:
every batch decodes until its SLOWEST request finishes, and every prompt
pads to its batch-mates' max length):

* ``saturated`` — every request queued at t=0 (a Poisson process whose rate
  exceeds service capacity degenerates to a standing backlog): tokens/s is
  pure engine throughput.  Deterministic compositions, so the warm pass
  compiles exactly the shapes the timed pass runs.
* ``poisson``   — requests arrive over wall-clock time at the offered rate;
  the batch engine gathers arrival-order chunks (classic static batching),
  the continuous engine admits into freed slots between dispatches.
  Latency (p50/p99, arrival -> completion) is the headline here.

The comparison holds KV MEMORY equal, not batch width: the paged pool is
sized to exactly the dense engine's cache footprint (``max_batch`` slabs of
``max_seq``), and the continuous engine runs ``1.5 x max_batch`` decode
slots over it — paging reserves each request's own worst case instead of a
uniform slab, so the same memory carries more concurrent requests.  On top
of that the continuous engine retires slots individually, admits queued
requests into freed slots between device dispatches, and prefills each
prompt at its own page-bucketed length — so it wins both regimes.

A third section, ``kv_equal_memory``, holds the continuous engine fixed
and varies the POOL DTYPE (repro.quant): f32 / bf16 / int8 pools all
sized to the f32 byte budget, slots scaled to fill it — the int8 pool
(+absmax scales) carries ~4x the f32 slots and ~2x the bf16 slots at
equal memory (bench_quant.py adds the accuracy-parity side of the trade).

A fourth, ``obs_overhead``, prices the repro.obs telemetry layer itself:
the same saturated drain with traces/histograms enabled vs disabled
(the budget is <1% tokens/s).  Poisson latencies are consumed from the
engine's request traces and cross-checked against the legacy per-result
computation.

A fifth, ``overload_goodput``, measures deadline-aware GOODPUT under
overload: every request carries a deadline, and the same Poisson workload
is offered at 1x/2x/4x the base rate.  Goodput counts only tokens of
requests that finished inside their deadline — requests the engine
timed out (in queue or in flight) produced nothing a client would read.
The lifecycle invariant rides along: at every oversubscription the engine
must surface EXACTLY ONE terminal result per request (zero lost).

  PYTHONPATH=src python benchmarks/bench_serving.py --requests 24 \
      --out BENCH_serving.json
  PYTHONPATH=src python benchmarks/bench_serving.py --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models.registry import build_model
from repro.obs import Obs
from repro.obs.metrics import Histogram
from repro.serve.engine import ContinuousEngine, Engine, Request
from repro.serve.kvcache import pages_for

try:                                   # package run (python -m benchmarks.run)
    from .common import bench_kv_equal_memory, make_serving_workload
except ImportError:                    # standalone (python benchmarks/...)
    from common import bench_kv_equal_memory, make_serving_workload


def _metrics(latencies, tokens: int, makespan: float) -> dict:
    """Latency percentiles only when genuine per-request latencies exist
    (Poisson mode); saturated drains report throughput alone.  Percentiles
    come from ``repro.obs.metrics.Histogram`` (numpy linear-interp
    semantics) — the same definition the engines' telemetry uses."""
    out = {
        "tokens": int(tokens),
        "makespan_s": makespan,
        "tokens_per_s": tokens / max(makespan, 1e-9),
    }
    if latencies is not None:
        h = Histogram.of(latencies)
        out.update({
            "p50_latency_s": h.percentile(50),
            "p99_latency_s": h.percentile(99),
            "mean_latency_s": h.sum / h.count,
        })
    return out


def _batch_engine(cfg, params, *, max_batch, max_seq, bucket):
    return Engine(cfg, params, max_batch=max_batch, max_seq=max_seq,
                  bucket_prompts=bucket)


def bench_saturated(cfg, params, reqs, *, max_batch, max_seq, engine_kw,
                    iters) -> dict:
    """Time full-backlog drains of every engine, interleaved round-robin
    (each mode sees the same shared-host noise window) and keep each
    mode's best (min wall — shared-host convention, like bench_decode)."""
    engines = {
        "batch": _batch_engine(cfg, params, max_batch=max_batch,
                               max_seq=max_seq, bucket=False),
        "batch_bucketed": _batch_engine(cfg, params, max_batch=max_batch,
                                        max_seq=max_seq, bucket=True),
        "continuous": ContinuousEngine(cfg, params, **engine_kw),
    }
    best, tokens = {}, {}
    for name, eng in engines.items():
        eng.generate(reqs)                              # compile + warm
    for _ in range(iters):
        for name, eng in engines.items():
            t0 = time.perf_counter()
            out = eng.generate(reqs)
            makespan = time.perf_counter() - t0
            tokens[name] = sum(r["decode_len"] for r in out)
            best[name] = min(best.get(name, makespan), makespan)
    # stats_cumulative spans the warm pass + every iter (engine counters
    # accumulate, incl. compile time) — throughput claims come from
    # tokens_per_s (best timed drain), not from these counters
    return {name: {**_metrics(None, tokens[name], best[name]),
                   "stats_cumulative": engines[name].stats()}
            for name in engines}


def bench_batch_poisson(cfg, params, reqs, arrivals, *, max_batch, max_seq,
                        bucket) -> dict:
    """Static batching online: arrival-order chunks of ``max_batch``; a
    chunk dispatches once its last request arrived and the engine is free.
    Deterministic chunking == warm pass compiles the timed shapes."""
    eng = _batch_engine(cfg, params, max_batch=max_batch, max_seq=max_seq,
                        bucket=bucket)
    order = [int(i) for i in np.argsort(arrivals, kind="stable")]
    chunks = [order[i:i + max_batch] for i in range(0, len(order), max_batch)]
    for chunk in chunks:                                # compile + warm
        eng.generate([reqs[i] for i in chunk])
    t0 = time.perf_counter()
    latencies, tokens = [0.0] * len(reqs), 0
    for chunk in chunks:
        gate = max(arrivals[i] for i in chunk)
        now = time.perf_counter() - t0
        if gate > now:
            time.sleep(gate - now)
        out = eng.generate([reqs[i] for i in chunk])
        finish = time.perf_counter() - t0
        for i, r in zip(chunk, out):
            latencies[i] = finish - arrivals[i]
            tokens += r["decode_len"]
    makespan = time.perf_counter() - t0
    return {**_metrics(latencies, tokens, makespan), "stats": eng.stats()}


def bench_continuous_poisson(cfg, params, reqs, arrivals,
                             *, engine_kw) -> dict:
    """Latencies come from the engine's request TRACES (repro.obs), not a
    bench-side recomputation — cross-checked below against the per-result
    latency fields (numpy percentile), which must agree exactly since the
    engine derives both from the same trace timeline."""
    eng = ContinuousEngine(cfg, params, obs=Obs(), **engine_kw)
    eng.generate(reqs)                                  # compile + warm
    eng.obs.traces.clear()                 # warm-pass traces out of the window
    t0 = time.perf_counter()
    out = eng.generate(reqs, arrival_times=arrivals)
    makespan = time.perf_counter() - t0
    tokens = sum(r["decode_len"] for r in out)
    traces = list(eng.obs.traces.completed)
    assert len(traces) == len(reqs), (len(traces), len(reqs))
    met = _metrics([tr.latency_s for tr in traces], tokens, makespan)
    legacy_p99 = float(np.percentile([r["latency_s"] for r in out], 99))
    assert abs(met["p99_latency_s"] - legacy_p99) <= 1e-9 * max(
        legacy_p99, 1.0), (met["p99_latency_s"], legacy_p99)
    met["p99_latency_s_legacy"] = legacy_p99
    met["p99_ttft_s"] = Histogram.of(
        [tr.ttft_s for tr in traces]).percentile(99)
    tpots = [tr.tpot_s for tr in traces if tr.tpot_s is not None]
    met["p99_tpot_s"] = (Histogram.of(tpots).percentile(99)
                         if tpots else None)
    return {**met, "stats": eng.stats()}


def bench_obs_overhead(cfg, params, reqs, *, engine_kw, iters) -> dict:
    """Saturated continuous drains with the obs layer enabled vs disabled
    (``Obs(enabled=False)``: counters stay live — they back stats() — but
    traces/histograms/scale reads are skipped).  Records the tokens/s
    fraction the full telemetry path costs; the budget is <1%.

    The enabled arm now carries the FULL numerics health plane
    (obs/health.py): device-side capture rides ``obs.enabled``, so the
    prefill/decode programs return their stats side-outputs and the
    engine folds them host-side.  A third arm (enabled obs,
    ``capture=False``) isolates the health plane's INCREMENTAL price
    from the pre-existing telemetry stack: ``health_capture_frac`` is
    enabled/no-capture, ``overhead_frac`` stays the headline
    enabled/disabled number the gate tracks (info-classed — the smoke
    model is so small that fixed host work reads as several percent of
    a drain; the committed full-size number is the budget reference).

    The budget is smaller than this host's run-to-run noise (min-of-N
    drain times swing several percent), so the estimator is PAIRED: each
    round times all arms back-to-back (same noise window) and each
    overhead is the median of the per-round time ratios — slow drift
    cancels instead of landing on whichever mode ran during it.  The
    arm ORDER rotates per round (a fixed order showed a systematic
    position bias bigger than the effect under measurement), and the
    smoke drains are milliseconds, so the round floor is high.  A
    blowout backstop asserts the health plane's incremental median
    stays under 10% — calibrated to the smoke config, where the
    capture's fixed cost (~30 extra cheap ops in a ~1 ms prefill
    program plus one stats transfer per dispatch) reads as several
    percent of a ~30 ms drain; it sits at ~1% on the full-size bench
    model.  The backstop exists to catch regressions like a sort-based
    reduction landing in the decode loop (+36% when ``lax.top_k``
    briefly did)."""
    engines = {
        "enabled": ContinuousEngine(cfg, params, obs=Obs(), **engine_kw),
        "no_capture": ContinuousEngine(cfg, params, obs=Obs(),
                                       capture=False, **engine_kw),
        "disabled": ContinuousEngine(cfg, params, obs=Obs(enabled=False),
                                     **engine_kw),
    }
    assert engines["enabled"]._health is not None, (
        "enabled arm lost the health plane: obs_overhead no longer "
        "prices device-side capture")
    assert engines["no_capture"]._health is None, (
        "capture=False arm grew a health plane: the middle arm no "
        "longer isolates the capture's incremental price")
    assert engines["disabled"]._health is None, (
        "disabled arm grew a health plane: the baseline is no longer "
        "the capture-free program")
    for eng in engines.values():
        eng.generate(reqs)                              # compile + warm
    best, tokens, ratios, hratios = {}, {}, [], []
    order = list(engines)
    for r in range(max(iters, 24)):
        dt = {}
        for mode in order[r % 3:] + order[:r % 3]:      # rotate position
            eng = engines[mode]
            t0 = time.perf_counter()
            res = eng.generate(reqs)
            dt[mode] = time.perf_counter() - t0
            tokens[mode] = sum(r2["decode_len"] for r2 in res)
            best[mode] = min(best.get(mode, dt[mode]), dt[mode])
        ratios.append(dt["enabled"] / dt["disabled"])
        hratios.append(dt["enabled"] / dt["no_capture"])
    out = {mode: _metrics(None, tokens[mode], best[mode])
           for mode in engines}
    out["overhead_frac"] = Histogram.of(ratios).percentile(50) - 1.0
    out["health_capture_frac"] = Histogram.of(hratios).percentile(50) - 1.0
    out["health_capture"] = True
    assert out["health_capture_frac"] < 0.10, (
        f"health-plane blowout: {out['health_capture_frac']:+.2%} median "
        f"over the capture-free telemetry arm (backstop 10%) — the "
        f"device-side capture or host folds regressed the hot path")
    return out


def bench_overload_goodput(cfg, params, reqs, base_arrivals, *, engine_kw,
                           deadline_s, factors=(1, 2, 4)) -> dict:
    """Deadline-aware goodput vs offered load.  One engine serves every
    factor (warm once); arrival times compress by the factor, so 4x offers
    the same requests at 4x the base rate.  Per factor: terminal-status
    census (every request must reach exactly one — zero lost), goodput
    (tokens of in-deadline finishes per second), and the served fraction."""
    from repro.serve.scheduler import FINISHED_STATUSES
    eng = ContinuousEngine(cfg, params, obs=Obs(), **engine_kw)
    eng.generate(reqs)                                  # compile + warm
    if deadline_s is None:
        # self-calibrate to this host: a deadline most requests make at 1x
        # and progressively miss as the offered rate climbs
        t0 = time.perf_counter()
        eng.generate(reqs)                              # post-compile drain
        deadline_s = round(0.75 * (time.perf_counter() - t0), 3)
    dl_reqs = [dataclasses.replace(r, deadline_s=deadline_s) for r in reqs]
    out = {}
    for f in factors:
        arrivals = [t / f for t in base_arrivals]
        t0 = time.perf_counter()
        res = eng.generate(dl_reqs, arrival_times=arrivals)
        makespan = time.perf_counter() - t0
        assert len(res) == len(reqs), "lost requests under overload"
        statuses = {}
        for r in res:
            assert r["status"] is not None
            statuses[r["status"]] = statuses.get(r["status"], 0) + 1
        good = [r for r in res if r["status"] in FINISHED_STATUSES]
        good_tokens = sum(r["decode_len"] for r in good)
        out[f"{f}x"] = {
            "offered_rps": len(reqs) / max(base_arrivals[-1] / f, 1e-9),
            "deadline_s": deadline_s,
            "makespan_s": makespan,
            "statuses": statuses,
            "lost_requests": len(reqs) - sum(statuses.values()),
            "served_frac": len(good) / len(reqs),
            "goodput_tokens_per_s": good_tokens / max(makespan, 1e-9),
        }
        assert out[f"{f}x"]["lost_requests"] == 0
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="batch size / decode slots")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--mean-interarrival", type=float, default=0.02,
                    help="Poisson offered load; the default oversubscribes "
                         "the batch engine so the queue builds")
    ap.add_argument("--iters", type=int, default=3,
                    help="saturated-mode timing repeats (best kept)")
    ap.add_argument("--overload-deadline-s", type=float, default=None,
                    help="overload_goodput: per-request deadline; default "
                         "self-calibrates to 0.75x a saturated drain")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI workload (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.requests = min(args.requests, 8)
        args.iters = 1
        prompt_lens, new_tokens = (8, 16), (4, 8, 16)
    else:
        # heavy-tailed decode budgets: the regime real traffic lives in,
        # and where batch-synchronous decode pays max-over-batch per chunk
        prompt_lens, new_tokens = (8, 16, 24, 32, 40), (4, 8, 16, 24, 64)
    max_seq = max(prompt_lens) + max(new_tokens)

    cfg = get_smoke_config(args.arch)
    if not args.smoke:
        # the 2-layer smoke config is dispatch-overhead-bound on CPU, which
        # mutes the compute-waste signal the engines differ on; scale to a
        # size where a wasted decode step costs real time (still CPU-fast)
        cfg = cfg.replace(num_layers=4, d_model=256, d_ff=512)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    reqs, arrivals = make_serving_workload(
        args.requests, prompt_lens=prompt_lens, new_tokens=new_tokens,
        mean_interarrival_s=args.mean_interarrival, vocab=cfg.vocab_size)
    # EQUAL KV MEMORY: the pool holds exactly the dense engine's cache
    # footprint (max_batch slabs of max_seq).  Paging reserves each
    # request's own worst case instead of a uniform slab, so the same
    # memory carries ~1.5x the concurrent requests — the paged-pool win.
    pages_per_slab = pages_for(max_seq, args.page_size)
    slots = args.max_batch + args.max_batch // 2
    engine_kw = dict(max_slots=slots, max_seq=max_seq,
                     page_size=args.page_size,
                     decode_chunk=args.decode_chunk,
                     num_pages=args.max_batch * pages_per_slab + 1,
                     max_tokens_in_flight=slots * (max_seq + 1))

    rows = {"saturated": bench_saturated(
        cfg, params, reqs, max_batch=args.max_batch, max_seq=max_seq,
        engine_kw=engine_kw, iters=args.iters)}
    # EQUAL KV MEMORY across pool dtypes (repro.quant): the headline is
    # the SLOT ratio (deterministic capacity at one byte budget); tokens/s
    # shows what the extra concurrency buys on this host
    rows["kv_equal_memory"] = bench_kv_equal_memory(
        cfg, params, reqs, budget_pages_f32=args.max_batch * pages_per_slab,
        page_size=args.page_size, max_seq=max_seq,
        decode_chunk=args.decode_chunk, iters=args.iters)
    rows["poisson"] = {
        "batch": bench_batch_poisson(
            cfg, params, reqs, arrivals, max_batch=args.max_batch,
            max_seq=max_seq, bucket=False),
        "continuous": bench_continuous_poisson(
            cfg, params, reqs, arrivals, engine_kw=engine_kw),
    }
    rows["obs_overhead"] = bench_obs_overhead(
        cfg, params, reqs, engine_kw=engine_kw, iters=args.iters)
    rows["overload_goodput"] = bench_overload_goodput(
        cfg, params, reqs, arrivals, engine_kw=engine_kw,
        deadline_s=args.overload_deadline_s)
    for section, modes in rows.items():
        for name, r in modes.items():
            if not isinstance(r, dict) or "tokens_per_s" not in r:
                continue
            lat = ("" if "p50_latency_s" not in r or r["p50_latency_s"] is
                   None else f", p50 {r['p50_latency_s'] * 1e3:6.0f}ms"
                   f", p99 {r['p99_latency_s'] * 1e3:6.0f}ms")
            print(f"[bench_serving] {section:>12}/{name:<15} "
                  f"{r['tokens_per_s']:7.1f} tok/s{lat}", flush=True)

    sat, poi, kvm = rows["saturated"], rows["poisson"], rows["kv_equal_memory"]
    result = {
        "arch": args.arch,
        "requests": args.requests,
        "max_batch": args.max_batch,
        "continuous_slots": slots,
        "kv_pool_pages": args.max_batch * pages_per_slab,
        "page_size": args.page_size,
        "decode_chunk": args.decode_chunk,
        "mean_interarrival_s": args.mean_interarrival,
        "prompt_lens": list(prompt_lens),
        "new_tokens": list(new_tokens),
        "backend": jax.default_backend(),
        "modes": rows,
        "speedup_continuous_vs_batch": (sat["continuous"]["tokens_per_s"]
                                        / sat["batch"]["tokens_per_s"]),
        "speedup_bucketed_vs_batch": (sat["batch_bucketed"]["tokens_per_s"]
                                      / sat["batch"]["tokens_per_s"]),
        "poisson_speedup_continuous_vs_batch": (
            poi["continuous"]["tokens_per_s"] / poi["batch"]["tokens_per_s"]),
        "poisson_p99_ratio_batch_vs_continuous": (
            poi["batch"]["p99_latency_s"]
            / max(poi["continuous"]["p99_latency_s"], 1e-9)),
        "kv_slots_ratio_int8_vs_f32": (kvm["int8"]["slots"]
                                       / kvm["f32"]["slots"]),
        "kv_slots_ratio_int8_vs_bf16": (kvm["int8"]["slots"]
                                        / kvm["bf16"]["slots"]),
        "obs_overhead_frac": rows["obs_overhead"]["overhead_frac"],
        "health_capture_frac": rows["obs_overhead"]["health_capture_frac"],
        "overload_goodput_tokens_per_s": {
            f: rows["overload_goodput"][f]["goodput_tokens_per_s"]
            for f in rows["overload_goodput"]},
        "overload_served_frac": {
            f: rows["overload_goodput"][f]["served_frac"]
            for f in rows["overload_goodput"]},
        "overload_lost_requests": sum(
            r["lost_requests"] for r in rows["overload_goodput"].values()),
    }
    print(f"[bench_serving] saturated: continuous/batch = "
          f"{result['speedup_continuous_vs_batch']:.2f}x tokens/s, "
          f"bucketed/batch = {result['speedup_bucketed_vs_batch']:.2f}x")
    print(f"[bench_serving] poisson:   continuous/batch = "
          f"{result['poisson_speedup_continuous_vs_batch']:.2f}x tokens/s, "
          f"p99 batch/continuous = "
          f"{result['poisson_p99_ratio_batch_vs_continuous']:.1f}x")
    slot_counts = ", ".join("%s: %d" % (d, kvm[d]["slots"]) for d in kvm)
    print(f"[bench_serving] equal KV memory: int8 pool carries "
          f"{result['kv_slots_ratio_int8_vs_f32']:.2f}x the f32 slots / "
          f"{result['kv_slots_ratio_int8_vs_bf16']:.2f}x the bf16 slots "
          f"({slot_counts})")
    print(f"[bench_serving] obs overhead: "
          f"{result['obs_overhead_frac'] * 100:+.2f}% tokens/s "
          f"(enabled vs disabled telemetry; health capture alone "
          f"{result['health_capture_frac'] * 100:+.2f}%)")
    og = rows["overload_goodput"]
    curve = ", ".join(
        f"{f}: {og[f]['goodput_tokens_per_s']:.1f} tok/s "
        f"({og[f]['served_frac'] * 100:.0f}% in-deadline)" for f in og)
    print(f"[bench_serving] overload goodput "
          f"(deadline {next(iter(og.values()))['deadline_s']}s, "
          f"lost={result['overload_lost_requests']}): {curve}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print("wrote", args.out)
    return result


if __name__ == "__main__":
    main()
