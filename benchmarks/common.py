"""Shared benchmark utilities: timing, CSV output, the paper's own models.

The paper's Table 1 models (MNIST/SVHN/CIFAR, small-to-medium DNNs for
embedded FPGA inference) are rebuilt here exactly as layer inventories:
MLP-256 (92.9%), MLP-128 (95.6%), LeNet-5-like CNN (99.0%), SVHN CNN,
CIFAR CNN, and the wide-ResNet-ish CIFAR-2 model are represented by their
FC/CONV layer dims for the ops/storage accounting, and the MLPs + small
CNNs are also run end-to-end for wall-clock dense-vs-circulant timing.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from repro.core.compression import LayerCost
from repro.obs.metrics import Histogram


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-clock µs per call (jit'd, block_until_ready).  The
    percentile comes from ``repro.obs.metrics.Histogram`` — ONE percentile
    definition (numpy linear interpolation) across benches and the serving
    telemetry, instead of per-bench hand-rolled medians."""
    out = fn(*args)
    jax.block_until_ready(out)
    for _ in range(warmup - 1):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return Histogram.of(times).percentile(50) * 1e6


def emit(rows: List[Dict], header: List[str]):
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))
    print()


def make_serving_workload(n: int, *, prompt_lens, new_tokens, vocab: int,
                          mean_interarrival_s: float = 0.0, seed: int = 0):
    """Mixed-length serving workload shared by bench_serving / bench_quant:
    (requests, poisson arrival times) — arrivals degenerate to all-zero
    (a standing backlog) when ``mean_interarrival_s`` is 0."""
    import numpy as np

    from repro.serve.engine import Request
    rng = np.random.RandomState(seed)
    reqs = [Request(prompt=rng.randint(1, vocab, size=int(rng.choice(
        prompt_lens))).astype(np.int32),
        max_new_tokens=int(rng.choice(new_tokens)), id=i)
        for i in range(n)]
    if not mean_interarrival_s:
        return reqs, [0.0] * n
    gaps = rng.exponential(mean_interarrival_s, size=n)
    arrivals = np.cumsum(gaps) - gaps[0]               # first arrives at t=0
    return reqs, arrivals.tolist()


def bench_kv_equal_memory(cfg, params, reqs, *, budget_pages_f32: int,
                          page_size: int, max_seq: int, decode_chunk: int,
                          iters: int) -> Dict[str, Dict]:
    """Continuous engine at EQUAL KV MEMORY across pool dtypes (the shared
    core of bench_serving's ``kv_equal_memory`` section and bench_quant).

    Every pool is sized to the f32 pool's byte budget (``budget_pages_f32``
    f32 pages): bf16 halves the bytes/slot, int8+scales quarters them
    (repro.quant), slot count scales to fill the budget
    (``num_pages = usable + 1`` keeps the trash page outside the budget),
    and the same backlog drains through each engine — warm pass first,
    best-of-``iters`` wall kept (shared-host convention).
    """
    from repro.quant import QuantPolicy
    from repro.serve.engine import ContinuousEngine
    from repro.serve.kvcache import page_bytes, pages_for

    pages_per_slab = pages_for(max_seq, page_size)
    budget = budget_pages_f32 * page_bytes(cfg, page_size)
    out: Dict[str, Dict] = {}
    for kv_dtype in ("f32", "bf16", "int8"):
        policy = QuantPolicy(kv_dtype=kv_dtype)
        usable = budget // page_bytes(cfg, page_size, policy)
        slots = max(1, usable // pages_per_slab)
        eng = ContinuousEngine(
            cfg, params, max_slots=slots, max_seq=max_seq,
            page_size=page_size, decode_chunk=decode_chunk,
            num_pages=usable + 1,
            max_tokens_in_flight=slots * (max_seq + 1), quant=policy)
        eng.generate(reqs)                              # compile + warm
        best, tokens = None, 0
        for _ in range(iters):
            t0 = time.perf_counter()
            res = eng.generate(reqs)
            dt = time.perf_counter() - t0
            tokens = sum(r["decode_len"] for r in res)
            best = dt if best is None else min(best, dt)
        st = eng.stats()
        out[kv_dtype] = {
            "slots": slots,
            "usable_pages": int(usable),
            "kv_pool_bytes": st["kv_pool_bytes"],
            "bytes_per_slot": pages_per_slab * page_bytes(cfg, page_size,
                                                          policy),
            "tokens": int(tokens),
            "makespan_s": best,
            "tokens_per_s": tokens / max(best, 1e-9),
            "attention_bytes_per_token": st["attention_bytes_per_token"],
        }
    return out


# ---------------------------------------------------------------------------
# The paper's benchmark model inventories (layer dims from the described
# structures: prior-pooled MNIST MLPs, LeNet-5-like CNN, small CIFAR CNN).
# ---------------------------------------------------------------------------
PAPER_MODELS: Dict[str, List[LayerCost]] = {
    # input pooled to 256 -> 2 hidden FC layers -> 10 (92.9% model)
    "mnist_mlp1": [
        LayerCost("fc1", "ffn", 256, 256),
        LayerCost("fc2", "ffn", 256, 128),
        LayerCost("out", "other", 128, 10),
    ],
    # input pooled to 128 (95.6% model)
    "mnist_mlp2": [
        LayerCost("fc1", "ffn", 128, 128),
        LayerCost("fc2", "ffn", 128, 128),
        LayerCost("out", "other", 128, 10),
    ],
    # LeNet-5-like CNN (99.0% model): conv counted per output pixel
    "mnist_cnn": [
        LayerCost("conv1", "attn", 25 * 1, 6, count=24 * 24),
        LayerCost("conv2", "attn", 25 * 6, 16, count=8 * 8),
        LayerCost("fc1", "ffn", 400, 120),
        LayerCost("fc2", "ffn", 120, 84),
        LayerCost("out", "other", 84, 10),
    ],
    "svhn_cnn": [
        LayerCost("conv1", "attn", 27, 32, count=32 * 32),
        LayerCost("conv2", "attn", 288, 32, count=16 * 16),
        LayerCost("conv3", "attn", 288, 64, count=8 * 8),
        LayerCost("fc1", "ffn", 1024, 256),
        LayerCost("out", "other", 256, 10),
    ],
    "cifar_cnn1": [
        LayerCost("conv1", "attn", 27, 64, count=32 * 32),
        LayerCost("conv2", "attn", 576, 64, count=16 * 16),
        LayerCost("conv3", "attn", 576, 128, count=8 * 8),
        LayerCost("fc1", "ffn", 2048, 512),
        LayerCost("out", "other", 512, 10),
    ],
    # wide ResNet-ish (94.75% model): dominant 3x3 convs at 3 widths
    "cifar_wrn": [
        LayerCost("g1", "attn", 9 * 160, 160, count=32 * 32 * 8),
        LayerCost("g2", "attn", 9 * 320, 320, count=16 * 16 * 8),
        LayerCost("g3", "attn", 9 * 640, 640, count=8 * 8 * 8),
        LayerCost("out", "other", 640, 10),
    ],
}
