"""Shared benchmark utilities: timing, CSV output, the paper's own models.

The paper's Table 1 models (MNIST/SVHN/CIFAR, small-to-medium DNNs for
embedded FPGA inference) are rebuilt here exactly as layer inventories:
MLP-256 (92.9%), MLP-128 (95.6%), LeNet-5-like CNN (99.0%), SVHN CNN,
CIFAR CNN, and the wide-ResNet-ish CIFAR-2 model are represented by their
FC/CONV layer dims for the ops/storage accounting, and the MLPs + small
CNNs are also run end-to-end for wall-clock dense-vs-circulant timing.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from repro.core.compression import LayerCost


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-clock µs per call (jit'd, block_until_ready)."""
    out = fn(*args)
    jax.block_until_ready(out)
    for _ in range(warmup - 1):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: List[Dict], header: List[str]):
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))
    print()


# ---------------------------------------------------------------------------
# The paper's benchmark model inventories (layer dims from the described
# structures: prior-pooled MNIST MLPs, LeNet-5-like CNN, small CIFAR CNN).
# ---------------------------------------------------------------------------
PAPER_MODELS: Dict[str, List[LayerCost]] = {
    # input pooled to 256 -> 2 hidden FC layers -> 10 (92.9% model)
    "mnist_mlp1": [
        LayerCost("fc1", "ffn", 256, 256),
        LayerCost("fc2", "ffn", 256, 128),
        LayerCost("out", "other", 128, 10),
    ],
    # input pooled to 128 (95.6% model)
    "mnist_mlp2": [
        LayerCost("fc1", "ffn", 128, 128),
        LayerCost("fc2", "ffn", 128, 128),
        LayerCost("out", "other", 128, 10),
    ],
    # LeNet-5-like CNN (99.0% model): conv counted per output pixel
    "mnist_cnn": [
        LayerCost("conv1", "attn", 25 * 1, 6, count=24 * 24),
        LayerCost("conv2", "attn", 25 * 6, 16, count=8 * 8),
        LayerCost("fc1", "ffn", 400, 120),
        LayerCost("fc2", "ffn", 120, 84),
        LayerCost("out", "other", 84, 10),
    ],
    "svhn_cnn": [
        LayerCost("conv1", "attn", 27, 32, count=32 * 32),
        LayerCost("conv2", "attn", 288, 32, count=16 * 16),
        LayerCost("conv3", "attn", 288, 64, count=8 * 8),
        LayerCost("fc1", "ffn", 1024, 256),
        LayerCost("out", "other", 256, 10),
    ],
    "cifar_cnn1": [
        LayerCost("conv1", "attn", 27, 64, count=32 * 32),
        LayerCost("conv2", "attn", 576, 64, count=16 * 16),
        LayerCost("conv3", "attn", 576, 128, count=8 * 8),
        LayerCost("fc1", "ffn", 2048, 512),
        LayerCost("out", "other", 512, 10),
    ],
    # wide ResNet-ish (94.75% model): dominant 3x3 convs at 3 widths
    "cifar_wrn": [
        LayerCost("g1", "attn", 9 * 160, 160, count=32 * 32 * 8),
        LayerCost("g2", "attn", 9 * 320, 320, count=16 * 16 * 8),
        LayerCost("g3", "attn", 9 * 640, 640, count=8 * 8 * 8),
        LayerCost("out", "other", 640, 10),
    ],
}
