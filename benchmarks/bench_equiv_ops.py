"""Paper Fig. 6 / §Experimental Results — equivalent-GOPS accounting.

The paper normalizes all implementations to "equivalent operations" of the
original dense matrix-vector product, then reports GOPS and GOPS/W.  We
reproduce the accounting: equivalent ops per inference (dense convention),
actual ops executed by the block-circulant pipeline, and the derived
equivalent-throughput multiplier (the paper's 5.14 TOPS/W on CyClone V
comes from this multiplier x the FFT pipeline's physical rate).  TPU-side:
the same accounting against v5e peak gives the projected equivalent TOPS.
"""
from __future__ import annotations

from repro.configs.base import CompressionConfig
from repro.core.compression import summarize

from .common import PAPER_MODELS, emit

V5E_PEAK_TOPS = 197.0          # bf16
CYCLONE_GOPS = 25.0            # paper-era small FPGA sustainable GOPS scale


def main():
    print("# bench_equiv_ops (paper Fig. 6 accounting)")
    comp = CompressionConfig(enabled=True, block_ffn=64, block_attn=16)
    rows = []
    for name, costs in PAPER_MODELS.items():
        s = summarize(costs, comp)
        mult = s["flop_reduction"]
        rows.append({
            "model": name,
            "equiv_ops_per_inf": s["dense_flops"],
            "actual_ops_per_inf": s["bc_flops"],
            "equiv_multiplier": round(mult, 1),
            "equiv_TOPS_at_v5e_peak": round(V5E_PEAK_TOPS * mult, 0),
            "equiv_GOPS_at_fpga_scale": round(CYCLONE_GOPS * mult, 0),
        })
    emit(rows, ["model", "equiv_ops_per_inf", "actual_ops_per_inf",
                "equiv_multiplier", "equiv_TOPS_at_v5e_peak",
                "equiv_GOPS_at_fpga_scale"])


if __name__ == "__main__":
    main()
