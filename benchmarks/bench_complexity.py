"""Paper complexity claim — O(n²) -> O(n log n) compute, O(n²) -> O(n)
storage, verified from COMPILED artifacts: jit cost_analysis FLOPs for the
dense vs FFT lowering over a sweep of layer sizes n and block sizes k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import circulant as cc
from repro.roofline.analysis import xla_cost_analysis

from .common import emit


def compiled_flops(fn, *args) -> float:
    compiled = jax.jit(fn).lower(*args).compile()
    return float(xla_cost_analysis(compiled)["flops"])  # loud if XLA omits it


def main():
    print("# bench_complexity (compiled-FLOPs scaling)")
    rows = []
    old = cc.FFT_IMPL
    cc.FFT_IMPL = "xla_fft"            # true FFT: the asymptotic claim
    try:
        for n in (256, 512, 1024, 2048, 4096):
            x = jax.ShapeDtypeStruct((1, n), jnp.float32)
            wd = jax.ShapeDtypeStruct((n, n), jnp.float32)
            f_dense = compiled_flops(lambda x, w: x @ w, x, wd)
            for k in (64, 128, 256):
                wc = jax.ShapeDtypeStruct((n // k, n // k, k), jnp.float32)
                f_bc = compiled_flops(
                    lambda x, w: cc.bc_matmul_fft(x, w, n), x, wc)
                rows.append({
                    "n": n, "k": k,
                    "dense_flops": int(f_dense), "bc_flops": int(f_bc),
                    "reduction": round(f_dense / max(f_bc, 1), 1),
                    "dense_params": n * n, "bc_params": n * n // k,
                    "storage_reduction": k,
                })
    finally:
        cc.FFT_IMPL = old
    emit(rows, ["n", "k", "dense_flops", "bc_flops", "reduction",
                "dense_params", "bc_params", "storage_reduction"])


if __name__ == "__main__":
    main()
