"""Decode tokens/s: seed per-token host loop vs the device-resident scanned
loop vs scanned + offline spectral params (this PR's serve hot path).

The seed engine paid one host round-trip per generated token; the scanned
loop is one dispatch per batch, and the precompute pass removes the weight
FFTs from the decode program on top.  Host-CPU tinyllama smoke config; the
default is the single-request latency-bound case, where dispatch overhead
and the per-step weight FFT are the largest fraction of step time (measured
here: ~4-8x scanned vs seed, scanned+cached above that).

  PYTHONPATH=src python benchmarks/bench_decode.py --new-tokens 48 \
      --requests 4 --out BENCH_decode.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models.registry import build_model
from repro.obs.metrics import Histogram
from repro.serve.engine import Engine, Request
from repro.serve.params import serving_cache_bytes

MODES = {
    # (decode_mode, precompute)
    "seed_loop": ("per_token", False),
    "scanned": ("scan", False),
    "scanned_cached": ("scan", True),
}


def _reqs(n: int, prompt_len: int, new_tokens: int):
    rng = np.random.RandomState(0)
    return [Request(prompt=rng.randint(1, 500, size=prompt_len)
                    .astype(np.int32), max_new_tokens=new_tokens, id=i)
            for i in range(n)]


def bench_mode(cfg, params, *, decode_mode: str, precompute: bool,
               requests: int, prompt_len: int, new_tokens: int,
               iters: int) -> dict:
    eng = Engine(cfg, params, max_batch=requests,
                 max_seq=prompt_len + new_tokens, decode_mode=decode_mode,
                 precompute=precompute)
    reqs = _reqs(requests, prompt_len, new_tokens)
    eng.generate(reqs)                              # compile + warm
    decode_s, prefill_s, toks = [], [], 0
    for _ in range(iters):
        out = eng.generate(reqs)
        decode_s.append(out[0]["decode_s"])         # batch-level split
        prefill_s.append(out[0]["prefill_s"])
        toks = sum(r["decode_len"] for r in out)
    # min over iters: this is a shared host, and the fastest iteration is the
    # one least polluted by scheduler noise (applied to every mode equally)
    best = min(decode_s)
    return {
        "decode_mode": decode_mode,
        "precompute": precompute,
        "tokens_per_batch": toks,
        "decode_s_best": best,
        "decode_s_median": Histogram.of(decode_s).percentile(50),
        "prefill_s_best": min(prefill_s),
        "tokens_per_s": toks / best,
        "spectral_cache_bytes": (serving_cache_bytes(eng.params)
                                 if precompute else 0),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=1)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--out", default="BENCH_decode.json")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    rows = {}
    for name, (mode, pre) in MODES.items():
        t0 = time.time()
        rows[name] = bench_mode(cfg, params, decode_mode=mode,
                                precompute=pre, requests=args.requests,
                                prompt_len=args.prompt_len,
                                new_tokens=args.new_tokens, iters=args.iters)
        print(f"[bench_decode] {name:>15}: "
              f"{rows[name]['tokens_per_s']:8.1f} tok/s "
              f"(decode {rows[name]['decode_s_best'] * 1e3:7.1f} ms, "
              f"wall {time.time() - t0:.1f}s)", flush=True)

    result = {
        "arch": args.arch,
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "new_tokens": args.new_tokens,
        "backend": jax.default_backend(),
        "modes": rows,
        "speedup_scanned_vs_seed": (rows["scanned"]["tokens_per_s"]
                                    / rows["seed_loop"]["tokens_per_s"]),
        "speedup_cached_vs_seed": (rows["scanned_cached"]["tokens_per_s"]
                                   / rows["seed_loop"]["tokens_per_s"]),
    }
    print(f"[bench_decode] scanned/seed = "
          f"{result['speedup_scanned_vs_seed']:.2f}x, "
          f"scanned+cached/seed = {result['speedup_cached_vs_seed']:.2f}x")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print("wrote", args.out)
    return result


if __name__ == "__main__":
    main()
