"""Paper's central accuracy claim, proxied on synthetic data: the
block-size k gives a FINE-GRAINED accuracy/compression tradeoff, and
moderate k matches the dense baseline (paper: 1-2% degradation bands).

Trains the same tiny LM with dense weights and with k ∈ {4, 8, 16, 32}
block-circulant weights on the deterministic bigram task and reports final
loss per compression ratio.  (MNIST/SVHN/CIFAR are not available offline —
DESIGN.md records this substitution.)
"""
from __future__ import annotations

import jax

from repro.configs.base import (ArchConfig, AttentionConfig,
                                CompressionConfig)
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.train import train_step as ts

from .common import emit


def run_one(k: int, steps: int = 60, seed: int = 0):
    comp = (CompressionConfig(enabled=True, block_ffn=k, block_attn=k)
            if k > 1 else CompressionConfig(enabled=False))
    cfg = ArchConfig(
        name=f"tradeoff_k{k}", num_layers=2, d_model=64, d_ff=128,
        vocab_size=128,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
        compression=comp, remat="none")
    opt = adamw.AdamWConfig(lr=3e-3)
    state = ts.init_state(jax.random.PRNGKey(seed), cfg, opt)
    step = jax.jit(ts.make_train_step(cfg, opt), donate_argnums=(0,))
    data = SyntheticLM(cfg, batch=8, seq=32, seed=seed)
    last = []
    for i in range(steps):
        state, m = step(state, data(i))
        if i >= steps - 10:
            last.append(float(m["loss"]))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    return sum(last) / len(last), n_params


def main():
    print("# bench_accuracy_tradeoff (block size vs quality, synthetic LM)")
    rows = []
    base_loss, base_params = run_one(1)
    rows.append({"k": "dense", "final_loss": round(base_loss, 4),
                 "params": base_params, "compression": 1.0,
                 "loss_vs_dense": 0.0})
    for k in (4, 8, 16, 32):
        loss, params = run_one(k)
        rows.append({"k": k, "final_loss": round(loss, 4),
                     "params": params,
                     "compression": round(base_params / params, 2),
                     "loss_vs_dense": round(loss - base_loss, 4)})
    emit(rows, ["k", "final_loss", "params", "compression", "loss_vs_dense"])


if __name__ == "__main__":
    main()
