"""Paper's central accuracy claim, proxied on synthetic data: the
block-size k gives a FINE-GRAINED accuracy/compression tradeoff, and
moderate k matches the dense baseline (paper: 1-2% degradation bands).

Trains the same tiny LM with dense weights and with k ∈ {4, 8, 16, 32}
block-circulant weights on the deterministic bigram task and reports final
loss per compression ratio.  (MNIST/SVHN/CIFAR are not available offline —
DESIGN.md records this substitution.)

The FIXED-POINT axis (the paper's hardware half: 12-16-bit weights in the
FFT domain cost near-zero accuracy) rides on top: each trained circulant
model is re-evaluated through the serve path with its precomputed spectral
planes quantized to int8 and packed-int4 (repro.quant), reporting the
eval-loss delta per (k, weight precision) cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import (ArchConfig, AttentionConfig,
                                CompressionConfig)
from repro.data.pipeline import SyntheticLM
from repro.models import transformer
from repro.optim import adamw
from repro.quant import QuantPolicy
from repro.serve.params import precompute_serving_params
from repro.train import train_step as ts

from .common import emit


def eval_serve_loss(cfg, params, data, policy=None, batches: int = 5):
    """Eval cross-entropy through the SERVE lowering (spectral caches
    consulted), with optionally quantized planes — the fixed-point cell."""
    p = precompute_serving_params(params, cfg, policy)

    @jax.jit
    def loss_of(batch):
        logits, _, _ = transformer.forward(p, batch["tokens"], cfg,
                                           mode="serve")
        return ts.cross_entropy(logits.astype(jnp.float32),
                                batch["labels"])
    return float(sum(loss_of(data(10_000 + i)) for i in range(batches))
                 / batches)


def run_one(k: int, steps: int = 60, seed: int = 0):
    comp = (CompressionConfig(enabled=True, block_ffn=k, block_attn=k)
            if k > 1 else CompressionConfig(enabled=False))
    cfg = ArchConfig(
        name=f"tradeoff_k{k}", num_layers=2, d_model=64, d_ff=128,
        vocab_size=128,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
        compression=comp, remat="none")
    opt = adamw.AdamWConfig(lr=3e-3)
    state = ts.init_state(jax.random.PRNGKey(seed), cfg, opt)
    step = jax.jit(ts.make_train_step(cfg, opt), donate_argnums=(0,))
    data = SyntheticLM(cfg, batch=8, seq=32, seed=seed)
    last = []
    for i in range(steps):
        state, m = step(state, data(i))
        if i >= steps - 10:
            last.append(float(m["loss"]))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    # fixed-point axis: serve-path eval loss with f32 / int8 / int4 planes
    # (dense k=1 has no spectral planes to quantize: None)
    quant = {}
    if k > 1:
        f32 = eval_serve_loss(cfg, state["params"], data)
        i8 = eval_serve_loss(cfg, state["params"], data,
                             QuantPolicy(quant_weights=True))
        i4 = eval_serve_loss(cfg, state["params"], data,
                             QuantPolicy(quant_weights=True, weight_bits=4))
        quant = {"eval_f32": f32, "int8_delta": i8 - f32,
                 "int4_delta": i4 - f32}
    return sum(last) / len(last), n_params, quant


def main():
    print("# bench_accuracy_tradeoff (block size + weight precision vs "
          "quality, synthetic LM)")
    rows = []
    base_loss, base_params, _ = run_one(1)
    rows.append({"k": "dense", "final_loss": round(base_loss, 4),
                 "params": base_params, "compression": 1.0,
                 "loss_vs_dense": 0.0, "int8_loss_delta": "",
                 "int4_loss_delta": ""})
    for k in (4, 8, 16, 32):
        loss, params, quant = run_one(k)
        rows.append({"k": k, "final_loss": round(loss, 4),
                     "params": params,
                     "compression": round(base_params / params, 2),
                     "loss_vs_dense": round(loss - base_loss, 4),
                     "int8_loss_delta": round(quant["int8_delta"], 4),
                     "int4_loss_delta": round(quant["int4_delta"], 4)})
    emit(rows, ["k", "final_loss", "params", "compression", "loss_vs_dense",
                "int8_loss_delta", "int4_loss_delta"])


if __name__ == "__main__":
    main()
