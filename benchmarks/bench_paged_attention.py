"""Paged decode attention: gather-then-attend (PR 3) vs the fused streamed
flash-decode path, at the oversubscribed serving shape where the gather
path's O(B * maxp * page) materialization hurts.

Two axes per impl, on the jitted attention step alone (pool write and the
rest of the decode step are identical between impls):

* ``tokens/s`` — one decode token per live slot per step; min wall over
  iters (shared host, same convention as bench_decode).
* ``peak bytes`` — the compiled step's XLA temp allocation
  (``compiled.memory_analysis().temp_size_in_bytes``: the gathered KV view
  lives here) plus total ``bytes accessed`` from cost analysis, with the
  analytic worst-case estimates from ``serve.kvcache.attention_memory_est``
  alongside.

The oversubscribed setting mirrors bench_serving's continuous engine:
more slots than the dense engine's batch, every slot's table spanning the
full ``max_seq`` reservation — the regime where the gathered view is
``maxp * page`` wide regardless of how short the live history is.

  PYTHONPATH=src python benchmarks/bench_paged_attention.py \
      --out BENCH_paged_attention.json
  PYTHONPATH=src python benchmarks/bench_paged_attention.py --smoke
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.layers.attention import chunked_attention
from repro.roofline.analysis import xla_cost_analysis


def make_case(*, slots, max_seq, page, Hkv, G, D, live_len, seed=0):
    """Random pool sized for ``slots`` full reservations; every slot owns
    its worst case (the scheduler's up-front reservation) but only
    ``live_len`` positions are live — the oversubscribed-decode shape."""
    rng = np.random.RandomState(seed)
    maxp = -(-max_seq // page)
    num_pages = slots * maxp + 1                  # + trash page 0
    pool_k = rng.randn(num_pages, page, Hkv, D).astype(np.float32)
    pool_v = rng.randn(num_pages, page, Hkv, D).astype(np.float32)
    free = list(range(1, num_pages))
    rng.shuffle(free)
    table = np.zeros((slots, maxp), np.int32)
    for b in range(slots):
        for j in range(maxp):
            table[b, j] = free.pop()
    positions = np.full(slots, live_len - 1, np.int32)
    q = rng.randn(slots, Hkv * G, D).astype(np.float32)
    return (jnp.asarray(q), jnp.asarray(pool_k), jnp.asarray(pool_v),
            jnp.asarray(table), jnp.asarray(positions))


def step_fn(impl: str):
    if impl == "stream":
        def f(q, pool_k, pool_v, table, positions):
            return kops.paged_attention(q, pool_k, pool_v, table, positions)
    else:
        def f(q, pool_k, pool_v, table, positions):
            k = kops.paged_gather(pool_k, table)
            v = kops.paged_gather(pool_v, table)
            idx = jnp.arange(k.shape[1])[None, :]
            kvp = jnp.where(idx <= positions[:, None], idx, -1)
            o = chunked_attention(q[:, None], k, v,
                                  q_pos0=jnp.maximum(positions, 0),
                                  kv_positions=kvp)
            return o[:, 0]
    return f


def bench_impl(impl: str, args_dev, iters: int) -> dict:
    fn = jax.jit(step_fn(impl))
    compiled = fn.lower(*args_dev).compile()
    mem = compiled.memory_analysis()
    ca = xla_cost_analysis(compiled)     # list-vs-dict normalized (PR 1)
    jax.block_until_ready(fn(*args_dev))          # warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args_dev))
        best = min(best, time.perf_counter() - t0)
    slots = args_dev[0].shape[0]
    return {
        "step_ms_best": best * 1e3,
        "tokens_per_s": slots / best,
        "peak_temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0) or 0),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=12,
                    help="decode slots (oversubscribed vs a batch-4 engine)")
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kv-heads", type=int, default=4)
    ap.add_argument("--group", type=int, default=4,
                    help="GQA group (Hq = kv_heads * group)")
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--live-len", type=int, default=48,
                    help="live positions per slot (short vs the max_seq "
                         "reservation: the oversubscribed regime)")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI shapes (seconds)")
    ap.add_argument("--out", default="BENCH_paged_attention.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.slots, args.max_seq, args.page_size = 4, 64, 8
        args.kv_heads, args.group, args.head_dim = 2, 2, 16
        args.live_len, args.iters = 20, 5

    case = make_case(slots=args.slots, max_seq=args.max_seq,
                     page=args.page_size, Hkv=args.kv_heads, G=args.group,
                     D=args.head_dim, live_len=args.live_len)
    rows = {}
    for impl in ("gather", "stream"):
        rows[impl] = bench_impl(impl, case, args.iters)
        r = rows[impl]
        print(f"[bench_paged_attention] {impl:>7}: "
              f"{r['tokens_per_s']:9.1f} tok/s  "
              f"temp {r['peak_temp_bytes'] / 1e6:7.2f}MB  "
              f"accessed {r['bytes_accessed'] / 1e6:8.2f}MB", flush=True)

    result = {
        "slots": args.slots,
        "max_seq": args.max_seq,
        "page_size": args.page_size,
        "kv_heads": args.kv_heads,
        "group": args.group,
        "head_dim": args.head_dim,
        "live_len": args.live_len,
        "backend": jax.default_backend(),
        "impls": rows,
        "speedup_stream_vs_gather": (rows["stream"]["tokens_per_s"]
                                     / rows["gather"]["tokens_per_s"]),
        "peak_bytes_gather_over_stream": (
            rows["gather"]["peak_temp_bytes"]
            / max(rows["stream"]["peak_temp_bytes"], 1)),
        "bytes_accessed_gather_over_stream": (
            rows["gather"]["bytes_accessed"]
            / max(rows["stream"]["bytes_accessed"], 1.0)),
    }
    print(f"[bench_paged_attention] stream/gather = "
          f"{result['speedup_stream_vs_gather']:.2f}x tok/s, peak temp "
          f"gather/stream = {result['peak_bytes_gather_over_stream']:.1f}x, "
          f"bytes accessed gather/stream = "
          f"{result['bytes_accessed_gather_over_stream']:.1f}x")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print("wrote", args.out)
    return result


if __name__ == "__main__":
    main()
