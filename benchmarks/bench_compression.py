"""Paper Fig. 3 — weight storage reduction.

Parameter and byte reduction per model under the block-circulant
representation, including the rfft-symmetry spectral store and the 12-bit
quantization the paper combines with it.  Run over the paper's own models
AND the 10 assigned architectures.
"""
from __future__ import annotations

import jax

from repro.configs.base import CompressionConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.core import circulant as cc
from repro.models.registry import build_model
from repro.roofline.analysis import count_params

from .common import PAPER_MODELS, emit
from repro.core.compression import summarize


def paper_fig3_rows(block: int = 64):
    rows = []
    comp = CompressionConfig(enabled=True, block_ffn=block,
                             block_attn=min(block, 16))
    for name, costs in PAPER_MODELS.items():
        s = summarize(costs, comp)
        # paper stacks parameter reduction x bit quantization (32b -> 12b)
        rows.append({
            "model": name,
            "dense_params": s["dense_params"],
            "bc_params": s["bc_params"],
            "param_reduction": round(s["param_compression"], 1),
            "bytes_reduction_12bit": round(
                s["param_compression"] * 32 / 12, 1),
        })
    return rows


def arch_rows():
    rows = []
    for arch in ARCH_IDS:
        dense_cfg = get_config(arch, compress=False)
        bc_cfg = get_config(arch, compress=True)
        n_dense = count_params(jax.eval_shape(
            lambda: build_model(dense_cfg).init(jax.random.PRNGKey(0))))
        n_bc = count_params(jax.eval_shape(
            lambda: build_model(bc_cfg).init(jax.random.PRNGKey(0))))
        k = bc_cfg.compression.block_ffn
        rows.append({
            "model": arch,
            "dense_params": n_dense,
            "bc_params": n_bc,
            "param_reduction": round(n_dense / n_bc, 1),
            "bytes_reduction_12bit": round(n_dense / n_bc * 32 / 12, 1),
        })
    return rows


def main():
    print("# bench_compression (paper Fig. 3)")
    header = ["model", "dense_params", "bc_params", "param_reduction",
              "bytes_reduction_12bit"]
    emit(paper_fig3_rows() + arch_rows(), header)


if __name__ == "__main__":
    main()
