"""Paper §Accelerating Computation — the FFT/IFFT decoupling technique.

Counts FFTs/IFFTs and measures wall-clock for the three formulations the
paper walks through on one FC layer (p x q blocks):

  naive      : p·q FFT(x) + p·q IFFT          (no reuse)
  reuse-x    : q FFT(x), IFFT inside Σ_j      (x-FFT reuse only)
  decoupled  : q FFT(x), 1 IFFT per block-row (paper's final form;
               weights FFT'd offline)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import circulant as cc

from .common import emit, time_fn


def naive(x, w, n_out):
    p, q, k = w.shape
    xb = cc._blockify(x, q, k).astype(jnp.float32)
    outs = []
    for i in range(p):
        acc = 0
        for j in range(q):
            xr, xi = cc.rfft_planes(xb[..., j, :], k)       # p·q FFTs
            wr, wi = cc.rfft_planes(w[i, j], k)
            acc = acc + cc.irfft_planes(xr * wr - xi * wi,
                                        xr * wi + xi * wr, k)  # p·q IFFTs
        outs.append(acc)
    return jnp.concatenate(outs, -1)[..., :n_out]


def reuse_x(x, w, n_out):
    p, q, k = w.shape
    xb = cc._blockify(x, q, k).astype(jnp.float32)
    xr, xi = cc.rfft_planes(xb, k)                          # q FFTs
    wr, wi = cc.rfft_planes(w, k)
    outs = []
    for i in range(p):
        y = 0
        for j in range(q):
            y = y + cc.irfft_planes(xr[..., j, :] * wr[i, j] -
                                    xi[..., j, :] * wi[i, j],
                                    xr[..., j, :] * wi[i, j] +
                                    xi[..., j, :] * wr[i, j], k)  # p·q IFFTs
        outs.append(y)
    return jnp.concatenate(outs, -1)[..., :n_out]


def main(n: int = 1024, k: int = 128, batch: int = 32):
    print("# bench_decoupling (paper's FFT/IFFT decoupling)")
    p = q = n // k
    w = cc.init_block_circulant(jax.random.PRNGKey(0), n, n, k)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, n))
    fns = {
        "naive": (jax.jit(lambda x, w: naive(x, w, n)), p * q, p * q),
        "reuse_x": (jax.jit(lambda x, w: reuse_x(x, w, n)), q, p * q),
        "decoupled": (jax.jit(lambda x, w: cc.bc_matmul_fft(x, w, n)),
                      q, p),
    }
    ref = None
    rows = []
    for name, (fn, nfft, nifft) in fns.items():
        out = fn(x, w)
        if ref is None:
            ref = out
        else:
            assert float(jnp.abs(out - ref).max()) < 1e-2, name
        rows.append({"form": name, "ffts_per_call": nfft,
                     "iffts_per_call": nifft,
                     "us_per_call": round(time_fn(fn, x, w, iters=10), 1)})
    emit(rows, ["form", "ffts_per_call", "iffts_per_call", "us_per_call"])


if __name__ == "__main__":
    main()
