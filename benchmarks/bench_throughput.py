"""Paper Table 1 — throughput of the paper's own small models, dense vs
block-circulant, batched inference (the paper's batch-processing mode).

Wall-clock is CPU here (the FPGA/TPU numbers are derived analytically in
bench_equiv_ops) — what this table demonstrates is the paper's central
claim shape: the block-circulant pipeline is faster than dense *at equal
model function*, and the gap grows with layer size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import circulant as cc

from .common import emit, time_fn


def mlp_pair(key, dims, k):
    """Dense and circulant params for an MLP with the given dims."""
    ks = jax.random.split(key, len(dims))
    dense, circ = [], []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        dense.append(jax.random.normal(ks[i], (a, b)) / jnp.sqrt(a))
        circ.append(cc.init_block_circulant(ks[i], a, b, min(k, a, b)))
    return dense, circ


def run_mlp(ws, x, circ: bool, dims):
    h = x
    for i, w in enumerate(ws):
        if circ:
            h = cc.bc_matmul_fft(h, w, dims[i + 1])
        else:
            h = h @ w
        if i < len(ws) - 1:
            h = jax.nn.relu(h)
    return h


MODELS = {
    "mnist_mlp1": ([256, 256, 128, 10], 64),
    "mnist_mlp2": ([128, 128, 128, 10], 64),
    "fc1024": ([1024, 1024, 1024, 10], 128),
    "fc4096": ([4096, 4096, 4096, 10], 128),
}


def main(batch: int = 64):
    print("# bench_throughput (paper Table 1, CPU wall-clock)")
    rows = []
    for name, (dims, k) in MODELS.items():
        dense, circ = mlp_pair(jax.random.PRNGKey(0), dims, k)
        x = jax.random.normal(jax.random.PRNGKey(1), (batch, dims[0]))
        f_d = jax.jit(lambda ws, x: run_mlp(ws, x, False, dims))
        f_c = jax.jit(lambda ws, x: run_mlp(ws, x, True, dims))
        t_d = time_fn(f_d, dense, x)
        t_c = time_fn(f_c, circ, x)
        n_d = sum(w.size for w in dense)
        n_c = sum(w.size for w in circ)
        rows.append({
            "model": name, "batch": batch,
            "dense_us": round(t_d, 1), "circulant_us": round(t_c, 1),
            "speedup": round(t_d / t_c, 2),
            "param_reduction": round(n_d / n_c, 1),
            "kfps_dense": round(batch / t_d * 1e3, 1),
            "kfps_circulant": round(batch / t_c * 1e3, 1),
        })
    emit(rows, ["model", "batch", "dense_us", "circulant_us", "speedup",
                "param_reduction", "kfps_dense", "kfps_circulant"])


if __name__ == "__main__":
    main()
