"""Run every benchmark (one per paper table/figure).  CSV to stdout.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run compression throughput

Smoke-scale JSON outputs land under ``results/`` (gitignored) — only the
full runs' checked-in BENCH_*.json live at the repo root, as the perf
baselines ``benchmarks/gate.py`` judges against.
"""
from __future__ import annotations

import os
import sys
import time

from . import (bench_accuracy_tradeoff, bench_complexity, bench_compression,
               bench_decoupling, bench_equiv_ops, bench_fleet,
               bench_paged_attention, bench_quant, bench_serving,
               bench_throughput)

RESULTS_DIR = "results"


def _smoke_out(name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, name)


ALL = {
    "compression": bench_compression.main,        # paper Fig. 3
    "throughput": bench_throughput.main,          # paper Table 1
    "equiv_ops": bench_equiv_ops.main,            # paper Fig. 6
    "complexity": bench_complexity.main,          # O(n log n) claim
    "decoupling": bench_decoupling.main,          # FFT/IFFT decoupling
    "accuracy_tradeoff": bench_accuracy_tradeoff.main,  # k-vs-quality
    # serving suite (smoke-scale here; the full runs write the checked-in
    # BENCH_*.json files — see each bench's module docstring)
    "serving": lambda: bench_serving.main(
        ["--smoke", "--out", _smoke_out("BENCH_serving_smoke.json")]),
    "paged_attention": lambda: bench_paged_attention.main(
        ["--smoke", "--out",
         _smoke_out("BENCH_paged_attention_smoke.json")]),
    "quant": lambda: bench_quant.main(
        ["--smoke", "--out", _smoke_out("BENCH_quant_smoke.json")]),
    "fleet": lambda: bench_fleet.main(
        ["--smoke", "--out", _smoke_out("BENCH_fleet_smoke.json")]),
}


def main():
    names = sys.argv[1:] or list(ALL)
    for name in names:
        t0 = time.time()
        ALL[name]()
        print(f"[{name}: {time.time() - t0:.1f}s]\n", flush=True)


if __name__ == "__main__":
    main()
