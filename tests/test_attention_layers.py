"""Chunked online-softmax attention vs the full-materialization oracle,
plus KV-cache semantics (linear and SWA ring buffer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, AttentionConfig
from repro.kernels import ref as kref
from repro.layers import attention as attn


def _qkv(key, B, S, Hq, Hkv, D, Skv=None):
    Skv = Skv or S
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return (jax.random.normal(ks[0], (B, S, Hq, D)),
            jax.random.normal(ks[1], (B, Skv, Hkv, D)),
            jax.random.normal(ks[2], (B, Skv, Hkv, D)))


@pytest.mark.parametrize("causal,window,softcap,Hq,Hkv", [
    (True, 0, 0.0, 4, 4),
    (True, 0, 50.0, 4, 2),
    (True, 24, 0.0, 8, 2),
    (False, 0, 0.0, 4, 1),
])
@pytest.mark.parametrize("chunks", [(16, 16), (64, 32), (128, 128)])
def test_chunked_matches_oracle(causal, window, softcap, Hq, Hkv, chunks):
    B, S, D = 2, 64, 16
    q, k, v = _qkv(0, B, S, Hq, Hkv, D)
    out = attn.chunked_attention(q, k, v, causal=causal, window=window,
                                 softcap=softcap, q_chunk=chunks[0],
                                 kv_chunk=chunks[1])
    ref = kref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=causal,
                             window=window, softcap=softcap)
    np.testing.assert_allclose(out, ref.transpose(0, 2, 1, 3),
                               rtol=2e-3, atol=2e-3)


def test_decode_query_against_cache():
    """Single query at position pos0 attends only cache[: pos0+1]."""
    B, Skv, D = 2, 32, 8
    q, k, v = _qkv(1, B, 1, 2, 2, D, Skv=Skv)
    pos0 = 20
    out = attn.chunked_attention(q, k, v, causal=True, q_pos0=pos0)
    ref = kref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=True,
                             kv_offset=pos0)
    np.testing.assert_allclose(out, ref.transpose(0, 2, 1, 3),
                               rtol=2e-3, atol=2e-3)


def test_ring_buffer_matches_linear_cache():
    """SWA ring-buffer decode == linear-cache decode restricted to window."""
    cfg = ArchConfig(d_model=32, attention=AttentionConfig(
        num_heads=2, num_kv_heads=1, head_dim=16, sliding_window=8))
    params = attn.init_attention(jax.random.PRNGKey(0), cfg, 32, None)
    S_total = 24
    xs = jax.random.normal(jax.random.PRNGKey(1), (1, S_total, 32))

    ring = attn.init_kv_cache(1, S_total, cfg, window=8, dtype=jnp.float32)
    lin = attn.init_kv_cache(1, S_total, cfg, window=0, dtype=jnp.float32)
    outs_ring, outs_lin = [], []
    for t in range(S_total):
        o_r, ring = attn.attention_block(
            params, xs[:, t:t + 1], cfg=cfg, causal=True, window=8,
            cache=ring, cache_pos=t, mode="serve")
        o_l, lin = attn.attention_block(
            params, xs[:, t:t + 1], cfg=cfg, causal=True, window=8,
            cache=lin, cache_pos=t, mode="serve")
        outs_ring.append(o_r)
        outs_lin.append(o_l)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs_ring, 1), np.float32),
        np.asarray(jnp.concatenate(outs_lin, 1), np.float32),
        rtol=3e-3, atol=3e-3)


def test_qk_norm_and_bias_apply():
    cfg = ArchConfig(d_model=32, attention=AttentionConfig(
        num_heads=2, num_kv_heads=2, head_dim=16, qk_norm=True,
        qkv_bias=True))
    params = attn.init_attention(jax.random.PRNGKey(0), cfg, 32, None)
    assert "qn" in params and "kn" in params
    assert "b" in params["q"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    out, _ = attn.attention_block(params, x, cfg=cfg, mode="train")
    assert out.shape == x.shape
    assert not bool(jnp.isnan(out).any())
