"""Fused multi-projection circulant apply (beyond-paper §Perf optimization):
must be numerically equivalent to the unfused per-projection pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, AttentionConfig, CompressionConfig
from repro.core import circulant as cc
from repro.models import transformer as tfm

BASE = CompressionConfig(enabled=True, block_ffn=16, block_attn=16)
CFG0 = ArchConfig(name="t", num_layers=2, d_model=64, d_ff=128,
                  vocab_size=100,
                  attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                            head_dim=16, qkv_bias=True),
                  compression=BASE, remat="none")
CFG1 = CFG0.replace(compression=dataclasses.replace(
    BASE, fuse_projections=True))


def test_fused_matmul_matches_separate():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    ws = [cc.init_block_circulant(k, 64, n, 16) for k, n in
          zip(ks, (64, 32, 32))]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    fused = cc.bc_matmul_fused(x, ws, [64, 32, 32])
    for w, n, f in zip(ws, (64, 32, 32), fused):
        np.testing.assert_allclose(np.asarray(f),
                                   np.asarray(cc.bc_matmul_fft(x, w, n)),
                                   rtol=1e-4, atol=1e-4)


def test_fused_forward_identical():
    params = tfm.init_params(jax.random.PRNGKey(0), CFG0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 100)
    l0, _, _ = tfm.forward(params, toks, CFG0, mode="train")
    l1, _, _ = tfm.forward(params, toks, CFG1, mode="train")
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_fused_grads_close():
    params = tfm.init_params(jax.random.PRNGKey(0), CFG0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 100)

    def loss(p, cfg):
        lg, _, _ = tfm.forward(p, toks, cfg, mode="train")
        return jnp.mean(lg.astype(jnp.float32) ** 2)

    g0 = jax.grad(lambda p: loss(p, CFG0))(params)
    g1 = jax.grad(lambda p: loss(p, CFG1))(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        scale = max(np.abs(a).max(), 1e-6)
        # identical math, different f32 contraction grouping -> tiny noise
        assert np.abs(a - b).max() / scale < 5e-2
