"""Paper §Theoretical Foundation — computational certificates.

Circulant blocks have displacement rank ≤ 2; gradient training on first-row
generators stays inside the structured class (no projection step needed);
and the universal-approximation property shows up empirically.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import circulant as cc
from repro.core import theory


def test_circulant_displacement_rank_le_2():
    w = cc.init_block_circulant(jax.random.PRNGKey(0), 32, 32, 32)
    W = np.asarray(cc.materialize_dense(w, 32, 32))
    assert theory.displacement_rank(W) <= 2
    # a dense random matrix is full displacement rank
    rng = np.random.RandomState(0)
    assert theory.displacement_rank(rng.randn(32, 32)) > 16


def test_training_preserves_structure():
    """Paper: 'the learnt weight matrices naturally follow the
    block-circulant format' — a gradient step keeps the certificate."""
    w = cc.init_block_circulant(jax.random.PRNGKey(0), 32, 16, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    g = jax.grad(lambda w: jnp.sum(cc.bc_matmul_fft(x, w, 16) ** 2))(w)
    w2 = w - 0.05 * g
    W2 = np.asarray(cc.materialize_dense(w2, 16, 32))
    assert theory.is_block_circulant(W2, 8)
    # perturbing the DENSE matrix (not the generators) breaks the class
    W_broken = W2.copy()
    W_broken[0, 0] += 1.0
    assert not theory.is_block_circulant(W_broken, 8)


def test_universal_approximation_demo():
    init_err, final_err = theory.universal_approx_demo(
        target=lambda X: np.sin(X.sum(axis=-1)),
        n_in=8, width=128, k=8, steps=200, seed=0)
    assert final_err < 0.25 * init_err
    assert final_err < 0.05
