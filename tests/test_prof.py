"""repro.obs.prof + chrometrace + benchmarks/gate.py: dispatch-level
roofline attribution invariants, Chrome-trace schema + slice accounting,
the --trace-out round-trip through a real serve, Prometheus exposition,
and the perf-regression gate's direction-aware rules."""
import copy
import importlib.util
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.registry import build_model
from repro.obs import Obs, aot_compile, prometheus_text, resolve_hardware
from repro.obs.chrometrace import (PID_ENGINE, PID_REQUESTS, build_trace,
                                   request_events, validate_trace,
                                   write_trace)
from repro.obs.metrics import Gauge, Registry
from repro.obs.prof import DispatchCost, Profiler
from repro.roofline.analysis import (HARDWARE_PRESETS, HOST_CPU, TPU_V5E,
                                     HardwareSpec, detect_hardware)
from repro.serve.engine import ContinuousEngine, Engine, Request

_GATE_PATH = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                          "gate.py")
_spec = importlib.util.spec_from_file_location("bench_gate", _GATE_PATH)
gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(gate)


# ---------------------------------------------------------------------------
# HardwareSpec + Profiler core
# ---------------------------------------------------------------------------
def test_hardware_presets():
    assert set(HARDWARE_PRESETS) >= {"tpu-v5e", "tpu-v4", "host-cpu",
                                     "gpu-generic"}
    for spec in HARDWARE_PRESETS.values():
        assert spec.peak_flops > 0 and spec.hbm_bw > 0
        assert spec.ridge_flops_per_byte == pytest.approx(
            spec.peak_flops / spec.hbm_bw)
    assert resolve_hardware("tpu-v5e") is TPU_V5E
    assert resolve_hardware("auto") is detect_hardware()
    with pytest.raises(ValueError):
        resolve_hardware("abacus")


def test_dispatch_cost_bound_sides():
    spec = HardwareSpec("toy", peak_flops=100.0, hbm_bw=10.0)
    # intensity above the ridge (10 FLOP/byte) -> compute-bound
    c = DispatchCost("k", flops=1000.0, bytes_accessed=10.0,
                     t_compute_s=10.0, t_memory_s=1.0)
    assert c.bound == "compute" and c.bound_s == 10.0
    c = DispatchCost("k", flops=10.0, bytes_accessed=1000.0,
                     t_compute_s=0.1, t_memory_s=100.0)
    assert c.bound == "memory" and c.bound_s == 100.0
    assert c.intensity == pytest.approx(0.01)
    del spec


def test_profiler_register_and_dispatch():
    reg = Registry()
    prof = Profiler(reg, hardware=HOST_CPU)
    fn = jax.jit(lambda x: (x @ x).sum())
    x = jnp.ones((64, 64), jnp.float32)
    compiled, cost = aot_compile(fn, (x,), prof, "matmul")
    assert cost is not None and cost.kind == "matmul"
    assert cost.flops > 0 and cost.bytes_accessed > 0
    # the compiled executable is callable and agrees with the jit wrapper
    assert float(compiled(x)) == pytest.approx(float(fn(x)))
    prof.on_dispatch(cost, 0.0, 0.5)
    prof.on_dispatch(cost, 0.5, 0.6)
    s = prof.summary()["matmul"]
    assert s["dispatches"] == 2
    # achieved rates are flops/dt means: (f/0.5 + f/0.1)/2
    want = (cost.flops / 0.5 + cost.flops / 0.1) / 2
    assert s["achieved_flops_per_s"] == pytest.approx(want)
    assert s["achieved_bytes_per_s"] > 0
    assert s["roofline_frac"] > 0
    assert s["roofline_frac_max"] >= s["roofline_frac_p50"]
    # events logged on the obs clock for the chrome exporter
    assert [e[0] for e in prof.events] == ["matmul", "matmul"]
    # histograms landed in the registry under dispatch labels
    snap = reg.snapshot()
    assert "prof.roofline_frac{dispatch=matmul}" in snap["histograms"]
    assert "prof.flops_per_s{dispatch=matmul}" in snap["histograms"]


def test_profiler_disabled_is_noop():
    reg = Registry()
    prof = Profiler(reg, hardware=HOST_CPU, enabled=False)
    fn = jax.jit(lambda x: x * 2)
    compiled, cost = aot_compile(fn, (jnp.ones(4),), prof, "x2")
    prof.on_dispatch(cost, 0.0, 1.0)
    prof.watch("some.gauge")
    assert len(prof.events) == 0 and prof.samples == {}
    assert prof.summary()["x2"]["dispatches"] == 0


def test_profiler_watch_samples_gauges():
    reg = Registry()
    prof = Profiler(reg, hardware=HOST_CPU)
    g = reg.gauge("pool.free_pages")
    prof.watch("pool.free_pages")
    prof.watch("pool.free_pages")            # idempotent
    fn = jax.jit(lambda x: x + 1)
    _, cost = aot_compile(fn, (jnp.ones(2),), prof, "inc")
    g.set(7)
    prof.on_dispatch(cost, 0.0, 0.1)
    g.set(3)
    prof.on_dispatch(cost, 0.1, 0.2)
    assert prof.samples["pool.free_pages"] == [(0.1, 7.0), (0.2, 3.0)]


# ---------------------------------------------------------------------------
# Gauge low-water mark
# ---------------------------------------------------------------------------
def test_gauge_min_seen():
    g = Gauge()
    assert g.min_seen is None                # no sample yet != 0 headroom
    for v, lo in [(5, 5), (9, 5), (2, 2), (4, 2)]:
        g.set(v)
        assert g.min_seen == lo
    assert g.max_seen == 9


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------
def test_prometheus_text_sections():
    reg = Registry()
    reg.counter("sched.admitted").inc(3)
    reg.gauge("pool.free_pages", pool="kv").set(11)
    h = reg.histogram("trace.ttft_s", bounds=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    text = reg.to_prometheus()
    lines = text.splitlines()
    assert "# TYPE sched_admitted_total counter" in lines
    assert "sched_admitted_total 3.0" in lines
    assert 'pool_free_pages{pool="kv"} 11.0' in lines
    # cumulative buckets + +Inf + sum/count
    assert 'trace_ttft_s_bucket{le="0.1"} 1' in lines
    assert 'trace_ttft_s_bucket{le="1.0"} 2' in lines
    assert 'trace_ttft_s_bucket{le="+Inf"} 3' in lines
    assert "trace_ttft_s_count 3" in lines
    assert any(l.startswith("trace_ttft_s_sum ") for l in lines)
    # snapshot round-trip gives the identical rendering
    assert prometheus_text(reg.snapshot()) == text


def test_prometheus_cli_reads_last_snapshot(tmp_path):
    from repro.obs.emit import main as emit_main
    path = tmp_path / "m.jsonl"
    reg = Registry()
    reg.counter("tokens").inc(5)
    lines = [{"type": "snapshot", "seq": 0, "t_s": 0.0,
              "counters": {"tokens": 1.0}, "gauges": {}, "histograms": {}},
             {"type": "snapshot", "seq": 1, "t_s": 1.0,
              **reg.snapshot()}]
    path.write_text("\n".join(json.dumps(l) for l in lines) + "\n")
    assert emit_main(["--to-prom", str(path)]) == 0


# ---------------------------------------------------------------------------
# Chrome-trace exporter (unit level)
# ---------------------------------------------------------------------------
def _trace_obs():
    """An Obs with two finished requests + profiled dispatches."""
    obs = Obs()
    prof = obs.profiler
    fn = jax.jit(lambda x: x * 2)
    _, cost = aot_compile(fn, (jnp.ones(3),), prof, "decode_chunk")
    prof.on_dispatch(cost, 0.01, 0.02)
    prof.on_dispatch(cost, 0.03, 0.05)
    for order, (enq, adm, ft, ret) in enumerate(
            [(0.0, 0.01, 0.02, 0.05), (0.005, 0.02, 0.03, 0.06)]):
        tr = obs.trace_start(order, order, 4, enq)
        tr.mark_admit(adm)
        tr.mark_first_token(ft)
        tr.mark_chunk(ret, 2)
        tr.mark_retire(ret)
        obs.trace_finish(tr)
    return obs


def test_chrome_trace_schema_and_monotone_ts(tmp_path):
    obs = _trace_obs()
    path = tmp_path / "trace.json"
    trace = write_trace(obs, str(path))
    validate_trace(trace)                    # monotone non-negative ts
    on_disk = json.loads(path.read_text())   # valid JSON round-trip
    validate_trace(on_disk)
    assert on_disk == trace
    ts = [e["ts"] for e in trace["traceEvents"] if e["ph"] != "M"]
    assert ts == sorted(ts) and all(t >= 0 for t in ts)


def test_chrome_trace_request_slices_exact():
    # a served 4-mark trace contributes EXACTLY queue/prefill/decode
    obs = _trace_obs()
    trace = build_trace(obs)
    for order in (0, 1):
        slices = [e for e in trace["traceEvents"]
                  if e.get("pid") == PID_REQUESTS and e["ph"] == "X"
                  and e.get("tid") == order]
        assert [s["name"] for s in slices] == ["queue", "prefill", "decode"]
        for s in slices:
            assert s["args"]["status"] == "FINISHED"
            assert s["args"]["order"] == order
    kinds = {e["name"] for e in trace["traceEvents"]
             if e.get("pid") == PID_ENGINE and e["ph"] == "X"}
    assert kinds == {"decode_chunk"}


def test_chrome_trace_unserved_and_preempted_slices():
    from repro.obs.trace import RequestTrace
    # cancelled in queue: enqueue + retire only -> one "queue" slice
    tr = RequestTrace(id=0, order=0, prompt_len=4, enqueue_s=0.0)
    tr.status = "CANCELLED"
    tr.mark_retire(0.5)
    ev = request_events(tr)
    assert [e["name"] for e in ev if e["ph"] == "X"] == ["queue"]
    assert ev[0]["args"]["status"] == "CANCELLED"
    # preemptions render as thread-scoped instants
    tr2 = RequestTrace(id=1, order=1, prompt_len=4, enqueue_s=0.0)
    tr2.mark_admit(0.1)
    tr2.mark_first_token(0.2)
    tr2.mark_preempt(0.3, 2)
    tr2.mark_retire(0.4)
    tr2.status = "FINISHED_BUDGET"
    ev2 = request_events(tr2)
    inst = [e for e in ev2 if e["ph"] == "i"]
    assert len(inst) == 1 and inst[0]["name"] == "preempt"
    assert inst[0]["args"]["recompute_tokens"] == 2


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_trace({"events": []})
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [
            {"ph": "X", "pid": 1, "name": "a", "ts": -1.0, "dur": 1.0}]})
    with pytest.raises(ValueError):           # unsorted
        validate_trace({"traceEvents": [
            {"ph": "X", "pid": 1, "name": "a", "ts": 5.0, "dur": 1.0},
            {"ph": "X", "pid": 1, "name": "b", "ts": 1.0, "dur": 1.0}]})


# ---------------------------------------------------------------------------
# Engine integration (smoke model, module-scoped)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _reqs(n, new=5):
    rng = np.random.RandomState(0)
    return [Request(prompt=rng.randint(0, 512, size=rng.randint(3, 12))
                    .astype(np.int32), max_new_tokens=new, id=i)
            for i in range(n)]


@pytest.mark.parametrize("engine_cls", [Engine, ContinuousEngine],
                         ids=["batch", "continuous"])
def test_engine_roofline_stats(setup, engine_cls):
    cfg, params = setup
    kw = (dict(max_batch=2) if engine_cls is Engine
          else dict(max_slots=2, page_size=8))
    eng = engine_cls(cfg, params, max_seq=32, precompute=False, **kw)
    eng.generate(_reqs(3))
    st = eng.stats()
    assert st["hardware"] in HARDWARE_PRESETS
    roof = st["roofline"]
    assert roof, "no dispatch kinds profiled"
    # both engines: every kind reports roofline fraction + achieved bytes/s
    prefill_kinds = [k for k in roof if k.startswith("prefill")]
    decode_kinds = [k for k in roof if "decode" in k]
    assert prefill_kinds and decode_kinds
    for kind, r in roof.items():
        assert r["dispatches"] >= 1, kind
        assert r["flops"] > 0 and r["bytes_accessed"] > 0
        assert r["achieved_flops_per_s"] > 0
        assert r["achieved_bytes_per_s"] > 0
        assert r["roofline_frac"] > 0
        assert r["bound"] in ("compute", "memory")


def test_continuous_min_free_pages(setup):
    cfg, params = setup
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32,
                           page_size=8, precompute=False)
    eng.generate(_reqs(3))
    st = eng.stats()
    # the pool drained below its resting level and refilled at retire
    assert 0 <= st["min_free_pages"] < st["free_pages"]
    # everything returned (num_pages includes the reserved trash page)
    assert st["free_pages"] == eng.num_pages - 1


def test_trace_out_round_trip_real_serve(setup, tmp_path):
    """--trace-out through a real 2-request continuous serve: the file is
    Perfetto-loadable, has one lane per request, engine dispatch lanes,
    and counter tracks."""
    cfg, params = setup
    obs = Obs()
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32,
                           page_size=8, precompute=False, obs=obs)
    results = eng.generate(_reqs(2))
    assert all(r["status"].startswith("FINISHED") for r in results)
    path = tmp_path / "serve_trace.json"
    trace = write_trace(obs, str(path))
    validate_trace(json.loads(path.read_text()))
    req_lanes = {e["tid"] for e in trace["traceEvents"]
                 if e.get("pid") == PID_REQUESTS and e["ph"] == "X"}
    assert req_lanes == {0, 1}
    # every finished request contributes exactly its trace's slices
    for order in req_lanes:
        names = [e["name"] for e in trace["traceEvents"]
                 if e.get("pid") == PID_REQUESTS and e["ph"] == "X"
                 and e["tid"] == order]
        assert names == ["queue", "prefill", "decode"]
    kinds = {e["name"] for e in trace["traceEvents"]
             if e.get("pid") == PID_ENGINE and e["ph"] == "X"}
    assert "decode_chunk" in kinds
    assert any(k.startswith("prefill_") for k in kinds)
    counters = {e["name"] for e in trace["traceEvents"] if e["ph"] == "C"}
    assert {"pool.free_pages", "sched.queue_depth"} <= counters


# ---------------------------------------------------------------------------
# benchmarks/gate.py
# ---------------------------------------------------------------------------
_BENCH = {
    "arch": "tiny", "requests": 4,
    "modes": {
        "poisson": {"continuous": {"tokens": 100, "tokens_per_s": 1000.0,
                                   "p99_latency_s": 0.5,
                                   "makespan_s": 2.0}},
        "obs_overhead": {"overhead_frac": 0.005},
    },
    "speedup_continuous_vs_batch": 2.0,
    "lost_requests": 0,
    "some_new_metric": 42.0,
}


def _gate_rc(baseline, candidate, **kw):
    res = gate.compare(baseline, candidate, **kw)
    return 1 if res["failed"] else 0, res


def test_gate_pass_on_identical():
    rc, res = _gate_rc(_BENCH, copy.deepcopy(_BENCH))
    assert rc == 0
    assert all(r["verdict"] in ("PASS", "INFO") for r in res["rows"])


def test_gate_fails_on_throughput_drop():
    bad = copy.deepcopy(_BENCH)
    bad["modes"]["poisson"]["continuous"]["tokens_per_s"] = 800.0  # -20%
    rc, res = _gate_rc(_BENCH, bad)
    assert rc == 1
    failed = {r["metric"] for r in res["failed"]}
    assert failed == {"modes.poisson.continuous.tokens_per_s"}
    # a throughput RISE never fails
    good = copy.deepcopy(_BENCH)
    good["modes"]["poisson"]["continuous"]["tokens_per_s"] = 2000.0
    assert _gate_rc(_BENCH, good)[0] == 0


def test_gate_fails_on_latency_rise():
    bad = copy.deepcopy(_BENCH)
    bad["modes"]["poisson"]["continuous"]["p99_latency_s"] = 0.6  # +20%
    rc, res = _gate_rc(_BENCH, bad)
    assert rc == 1
    assert res["failed"][0]["metric"] == \
        "modes.poisson.continuous.p99_latency_s"
    # a latency DROP never fails
    good = copy.deepcopy(_BENCH)
    good["modes"]["poisson"]["continuous"]["p99_latency_s"] = 0.1
    assert _gate_rc(_BENCH, good)[0] == 0
    # tol-scale widens the band: +20% passes at scale 3 (45% tolerance)
    assert _gate_rc(_BENCH, bad, tol_scale=3.0)[0] == 0


def test_gate_exact_parity_and_unknown_default():
    bad = copy.deepcopy(_BENCH)
    bad["modes"]["poisson"]["continuous"]["tokens"] = 101   # parity break
    rc, res = _gate_rc(_BENCH, bad)
    assert rc == 1
    assert res["failed"][0]["rule"] == "exact"
    # unknown metrics default to informational: huge swing, no gate
    weird = copy.deepcopy(_BENCH)
    weird["some_new_metric"] = 42000.0
    rc, res = _gate_rc(_BENCH, weird)
    assert rc == 0
    row = next(r for r in res["rows"] if r["metric"] == "some_new_metric")
    assert row["verdict"] == "INFO" and row["pattern"] == "<unknown>"
    # schema drift is surfaced, not gated
    dropped = copy.deepcopy(_BENCH)
    del dropped["some_new_metric"]
    rc, res = _gate_rc(_BENCH, dropped)
    assert rc == 0 and res["only_baseline"] == ["some_new_metric"]


def test_gate_cli_and_markdown(tmp_path):
    b = tmp_path / "base.json"
    c = tmp_path / "cand.json"
    b.write_text(json.dumps(_BENCH))
    bad = copy.deepcopy(_BENCH)
    bad["modes"]["poisson"]["continuous"]["tokens_per_s"] = 700.0
    c.write_text(json.dumps(bad))
    out = tmp_path / "delta.md"
    rc = gate.main(["--baseline", str(b), "--candidate", str(c),
                    "--out", str(out)])
    assert rc == 1
    md = out.read_text()
    assert "| metric |" in md and "**FAIL**" in md
    assert "modes.poisson.continuous.tokens_per_s" in md
    # identical -> rc 0
    rc = gate.main(["--baseline", str(b), "--candidate", str(b)])
    assert rc == 0
