"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracles,
swept over shapes and dtypes (assignment §c)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as kfa
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels import spectral_matmul as ksm


# ---------------------------------------------------------------------------
# spectral_matmul: the paper's frequency-domain MAC phase on the MXU
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("F,B,Q,P", [
    (9, 4, 3, 5), (65, 8, 16, 16), (5, 130, 2, 140), (33, 16, 44, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_spectral_matmul_sweep(F, B, Q, P, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xr = jax.random.normal(ks[0], (F, B, Q), dtype)
    xi = jax.random.normal(ks[1], (F, B, Q), dtype)
    wr = jax.random.normal(ks[2], (F, Q, P), dtype)
    wi = jax.random.normal(ks[3], (F, Q, P), dtype)
    yr0, yi0 = kref.spectral_matmul_ref(xr, xi, wr, wi)
    yr1, yi1 = ksm.spectral_matmul(xr, xi, wr, wi - wr, wr + wi,
                                   block_b=64, block_p=64, interpret=True)
    np.testing.assert_allclose(yr0, yr1, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(yi0, yi1, rtol=2e-4, atol=2e-4)


def test_spectral_matmul_dispatch_modes(monkeypatch):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    xr, xi = (jax.random.normal(k, (5, 4, 3)) for k in ks[:2])
    wr, wi = (jax.random.normal(k, (5, 3, 6)) for k in ks[2:])
    off = kops.spectral_matmul(xr, xi, wr, wi - wr, wr + wi, mode="off")
    interp = kops.spectral_matmul(xr, xi, wr, wi - wr, wr + wi,
                                  mode="interpret")
    np.testing.assert_allclose(off[0], interp[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(off[1], interp[1], rtol=1e-4, atol=1e-4)


def test_spectral_kernel_gauss_vs_naive_flops():
    """Gauss trick: 3 dots instead of 4 — verify identical math."""
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xr, xi = (jax.random.normal(k, (7, 8, 6)) for k in ks[:2])
    wr, wi = (jax.random.normal(k, (7, 6, 9)) for k in ks[2:])
    t1 = jnp.einsum("fbq,fqp->fbp", xr + xi, wr)
    t2 = jnp.einsum("fbq,fqp->fbp", xr, wi - wr)
    t3 = jnp.einsum("fbq,fqp->fbp", xi, wr + wi)
    yr0, yi0 = kref.spectral_matmul_ref(xr, xi, wr, wi)
    np.testing.assert_allclose(t1 - t3, yr0, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(t1 + t2, yi0, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention: causal / window / softcap / GQA / decode offset
# ---------------------------------------------------------------------------
CASES = [
    dict(causal=True, window=0, softcap=0.0, Hq=4, Hkv=4),
    dict(causal=True, window=0, softcap=30.0, Hq=4, Hkv=2),
    dict(causal=True, window=32, softcap=0.0, Hq=8, Hkv=2),
    dict(causal=False, window=0, softcap=0.0, Hq=4, Hkv=1),
    dict(causal=True, window=16, softcap=50.0, Hq=2, Hkv=1),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, Sq, Skv, D = 2, 64, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, case["Hq"], Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, case["Hkv"], Skv, D), dtype)
    v = jax.random.normal(ks[2], (B, case["Hkv"], Skv, D), dtype)
    kw = {kk: case[kk] for kk in ("causal", "window", "softcap")}
    ref = kref.attention_ref(q, k, v, **kw)
    out = kfa.flash_attention(q, k, v, block_q=32, block_k=32,
                              interpret=True, **kw)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(out, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_decode_offset():
    """Sq=1 decode query attending a longer cache with kv_offset."""
    B, Skv, D = 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 4, 1, D))
    k = jax.random.normal(ks[1], (B, 2, Skv, D))
    v = jax.random.normal(ks[2], (B, 2, Skv, D))
    for off in (17, 63):
        ref = kref.attention_ref(q, k, v, causal=True, kv_offset=off)
        out = kfa.flash_attention(q, k, v, causal=True, kv_offset=off,
                                  block_q=1, block_k=32, interpret=True)
        np.testing.assert_allclose(ref, out, rtol=2e-3, atol=2e-3)


def test_flash_attention_odd_shapes():
    """Non-multiple-of-block shapes pad correctly."""
    B, Sq, Skv, D = 1, 48, 80, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, 2, Sq, D))
    k = jax.random.normal(ks[1], (B, 2, Skv, D))
    v = jax.random.normal(ks[2], (B, 2, Skv, D))
    ref = kref.attention_ref(q, k, v, causal=True, kv_offset=Skv - Sq)
    out = kfa.flash_attention(q, k, v, causal=True, kv_offset=Skv - Sq,
                              block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(ref, out, rtol=2e-3, atol=2e-3)
