"""Unit + property tests for the paper's core contribution (core/circulant).

Covers: the three lowerings agree; the hand-derived block-circulant backward
(Eqns. 2-3) matches autodiff through the materialized dense circulant; the
DFT-as-matmul lowering equals true rfft/irfft; padding semantics; structure
preservation (training only ever updates first-row generators); and
compression accounting vs. closed forms.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is an optional (test-extra) dependency: without it only the two
# property-based tests skip — the unit tests below still run everywhere.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade gracefully (pip install -e .[test] for full run)
    HAVE_HYPOTHESIS = False

from repro.core import circulant as cc


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


# ---------------------------------------------------------------------------
# Lowering equivalence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_in,n_out,k", [
    (32, 32, 8), (48, 32, 16), (64, 128, 32), (50, 30, 16), (17, 9, 4),
])
def test_paths_agree(n_in, n_out, k):
    w = cc.init_block_circulant(jax.random.PRNGKey(0), n_in, n_out, k)
    x = _rand(1, 5, n_in)
    yd = cc.bc_matmul_direct(x, w, n_out)
    yf = cc.bc_matmul_fft(x, w, n_out)
    ys = cc.bc_matmul_spectral(x, cc.spectral_cache(w), k, n_out)
    ysn = cc.bc_matmul_spectral(x, cc.spectral_cache(w, gauss=False), k,
                                n_out, gauss=False)
    np.testing.assert_allclose(yd, yf, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(yd, ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(yd, ysn, rtol=2e-4, atol=2e-4)


def test_dft_matmul_equals_true_fft():
    for k in (4, 8, 16, 64, 128, 256):
        x = _rand(k, 3, k)
        xr, xi = cc.rfft_planes(x, k)
        ref = jnp.fft.rfft(x, axis=-1)
        np.testing.assert_allclose(xr, jnp.real(ref), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(xi, jnp.imag(ref), rtol=1e-4, atol=1e-4)
        y = cc.irfft_planes(xr, xi, k)
        np.testing.assert_allclose(y, x, rtol=1e-4, atol=1e-4)


def test_fft_impl_switch_matches():
    w = cc.init_block_circulant(jax.random.PRNGKey(0), 64, 64, 32)
    x = _rand(2, 4, 64)
    y_dft = cc.bc_matmul_fft(x, w, 64)
    old = cc.FFT_IMPL
    try:
        cc.FFT_IMPL = "xla_fft"
        y_fft = cc.bc_matmul_fft(x, w, 64)
    finally:
        cc.FFT_IMPL = old
    np.testing.assert_allclose(y_dft, y_fft, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# The paper's backward pass (Eqns. 2-3) — custom_vjp correctness
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_in,n_out,k", [(48, 32, 16), (40, 56, 8)])
def test_custom_vjp_matches_dense_autodiff(n_in, n_out, k):
    w = cc.init_block_circulant(jax.random.PRNGKey(0), n_in, n_out, k)
    x = _rand(1, 3, 4, n_in)

    def loss_fft(w, x):
        return jnp.sum(jnp.sin(cc.bc_matmul_fft(x, w, n_out)))

    def loss_dir(w, x):
        return jnp.sum(jnp.sin(cc.bc_matmul_direct(x, w, n_out)))

    gf = jax.grad(loss_fft, argnums=(0, 1))(w, x)
    gd = jax.grad(loss_dir, argnums=(0, 1))(w, x)
    np.testing.assert_allclose(gf[0], gd[0], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(gf[1], gd[1], rtol=2e-4, atol=2e-4)


def test_gradient_is_first_row_only():
    """The paper learns first-row generators directly: the gradient exists
    only on the (p, q, k) generators — circulant structure is preserved by
    construction, no projection step."""
    w = cc.init_block_circulant(jax.random.PRNGKey(0), 32, 32, 16)
    x = _rand(1, 4, 32)
    g = jax.grad(lambda w: jnp.sum(cc.bc_matmul_fft(x, w, 32) ** 2))(w)
    assert g.shape == w.shape == (2, 2, 16)
    dense = cc.materialize_dense(w - 0.01 * g, 32, 32)
    # dense result of a gradient step is still exactly block-circulant
    blocks = dense.reshape(2, 16, 2, 16)
    for i in range(2):
        for j in range(2):
            b = blocks[:, :, j, :][i]
            for r in range(1, 16):
                np.testing.assert_allclose(np.roll(np.asarray(b[0]), r),
                                           np.asarray(b[r]), rtol=1e-5,
                                           atol=1e-5)


# ---------------------------------------------------------------------------
# Property-based invariants (skipped without hypothesis)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 6), st.integers(1, 6), st.sampled_from([2, 4, 8, 16]),
           st.integers(0, 2 ** 31 - 1))
    def test_property_matches_dense(p, q, k, seed):
        """∀ shapes: the FFT path equals multiplication by the materialized
        block-circulant matrix (the circulant convolution theorem)."""
        n_in, n_out = q * k, p * k
        w = cc.init_block_circulant(jax.random.PRNGKey(seed), n_in, n_out, k)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, n_in))
        yd = cc.bc_matmul_direct(x, w, n_out)
        yf = cc.bc_matmul_fft(x, w, n_out)
        np.testing.assert_allclose(yd, yf, rtol=5e-3, atol=5e-3)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 64), st.integers(1, 64), st.sampled_from([4, 8, 16]))
    def test_property_linearity(a, b, k):
        """Linearity in both arguments (exercises zero-padding correctness)."""
        n_in, n_out = max(a, 1), max(b, 1)
        w = cc.init_block_circulant(jax.random.PRNGKey(0), n_in, n_out, k)
        x1 = jax.random.normal(jax.random.PRNGKey(1), (3, n_in))
        x2 = jax.random.normal(jax.random.PRNGKey(2), (3, n_in))
        y = cc.bc_matmul_fft(x1 + 2.0 * x2, w, n_out)
        y12 = (cc.bc_matmul_fft(x1, w, n_out) +
               2.0 * cc.bc_matmul_fft(x2, w, n_out))
        np.testing.assert_allclose(y, y12, rtol=5e-3, atol=5e-3)
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -e .[test])")
    def test_property_invariants():
        pass


# ---------------------------------------------------------------------------
# Accounting (paper Fig. 3 / complexity claims)
# ---------------------------------------------------------------------------
def test_param_count_is_k_fold_smaller():
    for (m, n, k) in [(1024, 1024, 128), (4096, 14336, 128)]:
        dense = m * n
        bc = cc.num_blocks(m, k) * cc.num_blocks(n, k) * k
        assert dense / bc == k       # exact k-fold compression

def test_bc_flops_scaling():
    """O(n log n + n²/k): doubling k halves the MAC term."""
    f128 = cc.bc_flops(1, 4096, 4096, 128)
    f64 = cc.bc_flops(1, 4096, 4096, 64)
    assert f64 > f128                # smaller blocks -> more MACs
    dense = cc.dense_flops(1, 4096, 4096)
    assert dense / f128 > 20         # order-of-magnitude acceleration


def test_spectral_cache_storage_halves():
    b_full = cc.bc_param_bytes(1024, 1024, 128, spectral=False)
    b_spec = cc.bc_param_bytes(1024, 1024, 128, spectral=True)
    # 2*(k/2+1) reals vs k reals: ~= parity (the rfft symmetry saving)
    assert b_spec / b_full == pytest.approx(2 * (65) / 128, rel=0.01)
