"""Checkpoint atomicity/integrity/resume + data-pipeline determinism."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, AttentionConfig, CompressionConfig
from repro.data import pipeline
from repro.optim import adamw
from repro.train import checkpoint as ckpt
from repro.train import train_step as ts
from repro.train.trainer import Trainer


@pytest.fixture
def tiny_cfg():
    return ArchConfig(
        name="tiny", num_layers=2, d_model=32, d_ff=64, vocab_size=128,
        attention=AttentionConfig(num_heads=2, num_kv_heads=1, head_dim=16),
        compression=CompressionConfig(enabled=True, block_ffn=8,
                                      block_attn=8),
        remat="none")


def test_roundtrip(tmp_path, tiny_cfg):
    opt = adamw.AdamWConfig()
    state = ts.init_state(jax.random.PRNGKey(0), tiny_cfg, opt)
    ckpt.save(str(tmp_path), 7, state)
    like = ts.init_state(jax.random.PRNGKey(1), tiny_cfg, opt)
    restored, step = ckpt.restore(str(tmp_path), like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_integrity_check(tmp_path, tiny_cfg):
    opt = adamw.AdamWConfig()
    state = ts.init_state(jax.random.PRNGKey(0), tiny_cfg, opt)
    path = ckpt.save(str(tmp_path), 1, state)
    with open(os.path.join(path, "arrays.npz"), "ab") as f:
        f.write(b"corruption")
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), state)


def test_keep_prunes_old(tmp_path, tiny_cfg):
    opt = adamw.AdamWConfig()
    state = ts.init_state(jax.random.PRNGKey(0), tiny_cfg, opt)
    for s in range(5):
        ckpt.save(str(tmp_path), s, state, keep=2)
    assert ckpt.latest_steps(str(tmp_path)) == [3, 4]


def test_trainer_resume(tmp_path, tiny_cfg):
    """Kill after N steps; a fresh Trainer resumes from the checkpoint and
    reaches an identical final state as an uninterrupted run (determinism +
    fault tolerance)."""
    data_kw = dict(batch=2, seq=16, seed=5)

    def make(workdir, total):
        cfg = tiny_cfg
        return Trainer(cfg, adamw.AdamWConfig(lr=1e-3),
                       workdir=str(workdir), total_steps=total,
                       ckpt_every=4, log_every=100,
                       lr_schedule=lambda s: 1e-3,   # step-count independent
                       data_fn=pipeline.SyntheticLM(cfg, **data_kw))

    t_full = make(tmp_path / "full", 8)
    full_state = t_full.run()

    t_a = make(tmp_path / "resume", 4)
    t_a.run()                                   # "preempted" at step 4
    t_b = make(tmp_path / "resume", 8)
    resumed_state = t_b.run()
    assert int(resumed_state["step"]) == 8
    for a, b in zip(jax.tree.leaves(full_state["params"]),
                    jax.tree.leaves(resumed_state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_heartbeat(tmp_path, tiny_cfg):
    t = Trainer(tiny_cfg, adamw.AdamWConfig(), workdir=str(tmp_path),
                total_steps=2, ckpt_every=10, log_every=1,
                data_fn=pipeline.SyntheticLM(tiny_cfg, batch=2, seq=8))
    assert Trainer.heartbeat_age(str(tmp_path)) == float("inf")
    t.run()
    assert Trainer.heartbeat_age(str(tmp_path)) < 60.0


def test_synthetic_determinism(tiny_cfg):
    d1 = pipeline.SyntheticLM(tiny_cfg, batch=4, seq=16, seed=9)
    d2 = pipeline.SyntheticLM(tiny_cfg, batch=4, seq=16, seed=9)
    for step in (0, 3, 1000):
        b1, b2 = d1(step), d2(step)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(d1(0)["tokens"]),
                              np.asarray(d1(1)["tokens"]))


def test_synthetic_has_signal(tiny_cfg):
    """Labels follow the bigram table 90% of the time — learnable."""
    d = pipeline.SyntheticLM(tiny_cfg, batch=8, seq=64, seed=0)
    b = d(0)
    succ = d._succ
    toks = np.asarray(b["tokens"])
    labs = np.asarray(b["labels"])
    match = (succ[toks] == labs).mean()
    assert match > 0.8


def test_file_tokens(tmp_path, tiny_cfg):
    arr = np.arange(10000, dtype=np.uint16)
    path = str(tmp_path / "toks.bin")
    arr.tofile(path)
    d = pipeline.FileTokens(tiny_cfg, path, batch=2, seq=16)
    b0, b0b = d(0), d(0)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(b0b["tokens"]))
    np.testing.assert_array_equal(
        np.asarray(b0["labels"][:, :-1]), np.asarray(b0["tokens"][:, 1:]))


def test_host_sharding(tiny_cfg):
    d = pipeline.SyntheticLM(tiny_cfg, batch=8, seq=8, seed=1)
    b = d(0)
    parts = [pipeline.shard_for_host(b, i, 4) for i in range(4)]
    glued = np.concatenate([np.asarray(p["tokens"]) for p in parts])
    np.testing.assert_array_equal(glued, np.asarray(b["tokens"]))
