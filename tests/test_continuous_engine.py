"""ContinuousEngine vs the batch-engine oracle: greedy token identity for
every servable registry arch, slot recycling, EOS page-freeing, telemetry,
and sampling reproducibility.

The oracle is the batch engine under a single-admission schedule (one
request, B=1): prefill runs at the request's own positions and decode at
its own cache length, so its greedy tokens are the ground truth the
continuous engine must reproduce while serving many requests at once.

The parity sweep runs the smoke configs at float32: with bfloat16
activations, XLA CPU reassociates batched GEMMs across batch widths at
bf16-ulp scale, which flips greedy argmax on near-tied random-init logits
— a dtype artifact, not a control-plane property (the bf16 case is pinned
separately on tinyllama, where logits are well-separated).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models.registry import build_model
from repro.serve.engine import ContinuousEngine, Engine, Request
from repro.serve.kvcache import servable_reasons

SERVABLE = [a for a in ARCH_IDS if not servable_reasons(get_smoke_config(a))]


def _reqs(specs, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(prompt=rng.randint(1, 500, size=s).astype(np.int32),
                    max_new_tokens=n, id=i)
            for i, (s, n) in enumerate(specs)]


def test_servable_set():
    """Exactly the linear-cache decoder LMs are continuous-servable."""
    assert set(SERVABLE) == {"tinyllama-1.1b", "qwen2.5-3b", "qwen3-4b",
                             "llama4-maverick-400b-a17b",
                             "phi-3-vision-4.2b"}
    for arch in set(ARCH_IDS) - set(SERVABLE):
        with pytest.raises(ValueError, match="not continuous-servable"):
            cfg = get_smoke_config(arch)
            params = build_model(cfg).init(jax.random.PRNGKey(0))
            ContinuousEngine(cfg, params)


@pytest.fixture(scope="module", params=SERVABLE)
def arch_setup(request):
    cfg = get_smoke_config(request.param).replace(dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def test_matches_oracle_with_recycling(arch_setup):
    """More requests than slots, mixed unaligned prompt lengths and
    budgets: every request's greedy tokens equal its B=1 oracle run."""
    cfg, params = arch_setup
    reqs = _reqs([(20, 13), (12, 21), (16, 17), (9, 10), (23, 6)])
    oracle = Engine(cfg, params, max_batch=1, max_seq=32)
    want = [oracle.generate([r])[0]["tokens"] for r in reqs]
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32,
                           page_size=4, decode_chunk=5)
    got = eng.generate(reqs)
    assert [g["tokens"] for g in got] == want
    st = eng.stats()
    assert st["pages_in_use"] == 0          # free list fully restored
    assert st["retired"] == len(reqs)


def test_matches_oracle_bf16_tinyllama():
    """Default-dtype pin on the arch whose logits are tie-free."""
    cfg = get_smoke_config("tinyllama-1.1b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    reqs = _reqs([(20, 13), (16, 17), (8, 25), (12, 21)])
    oracle = Engine(cfg, params, max_batch=1, max_seq=32)
    want = [oracle.generate([r])[0]["tokens"] for r in reqs]
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32,
                           page_size=4, decode_chunk=6)
    assert [g["tokens"] for g in eng.generate(reqs)] == want


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_smoke_config("tinyllama-1.1b").replace(dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def test_eos_frees_pages_early_and_matches_oracle(tiny_setup):
    cfg, params = tiny_setup
    reqs = _reqs([(16, 12), (12, 12)])
    ref = Engine(cfg, params, max_batch=1, max_seq=32)
    base = ref.generate([reqs[0]])[0]["tokens"]
    eos = base[3]                           # a token the model emits mid-way
    oracle = Engine(cfg, params, max_batch=1, max_seq=32, eos_id=eos)
    want = [oracle.generate([r])[0]["tokens"] for r in reqs]
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32, page_size=4,
                           decode_chunk=4, eos_id=eos)
    got = eng.generate(reqs)
    assert [g["tokens"] for g in got] == want
    toks = got[0]["tokens"]
    assert toks[-1] == eos and eos not in toks[:-1]
    assert got[0]["decode_len"] < 12        # stopped early
    assert eng.stats()["pages_in_use"] == 0


def test_budget_clamp_matches_batch_engine(tiny_setup):
    """A prompt near max_seq clamps the decode budget like the oracle."""
    cfg, params = tiny_setup
    reqs = _reqs([(20, 16)])                # budget clamps to 24-20+1=5
    oracle = Engine(cfg, params, max_batch=1, max_seq=24)
    want = oracle.generate(reqs)[0]
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=24, page_size=4)
    got = eng.generate(reqs)[0]
    assert want["decode_len"] == got["decode_len"] == 5
    assert got["tokens"] == want["tokens"]


def test_prompt_longer_than_max_seq_raises(tiny_setup):
    cfg, params = tiny_setup
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=16, page_size=4)
    with pytest.raises(ValueError, match="max_seq"):
        eng.generate(_reqs([(20, 4)]))
    # the raise happens BEFORE anything is admitted: the engine stays usable
    # and no pages leaked
    with pytest.raises(ValueError, match="max_seq"):
        eng.generate(_reqs([(8, 4), (20, 4)]))
    assert eng.stats()["pages_in_use"] == 0
    out = eng.generate(_reqs([(8, 4)]))
    assert out[0]["decode_len"] == 4


def test_unsorted_arrival_times(tiny_setup):
    """FIFO admission with out-of-order arrival times must wait for the
    head, not stall (regression: spurious 'scheduler stall' RuntimeError)."""
    cfg, params = tiny_setup
    reqs = _reqs([(12, 4), (12, 4)])
    eng = ContinuousEngine(cfg, params, max_slots=1, max_seq=32, page_size=4)
    out = eng.generate(reqs, arrival_times=[0.3, 0.0])
    assert [r["decode_len"] for r in out] == [4, 4]


def test_arrival_times_and_latency_fields(tiny_setup):
    cfg, params = tiny_setup
    reqs = _reqs([(12, 6), (12, 6), (16, 4)])
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32, page_size=4)
    out = eng.generate(reqs, arrival_times=[0.0, 0.0, 0.2])
    assert [r["id"] for r in out] == [0, 1, 2]
    for r in out:
        assert r["decode_len"] == len(r["tokens"])
        assert r["latency_s"] >= r["queue_s"] >= 0.0
        assert r["tokens_per_s"] > 0
    # the late request cannot complete before it arrived
    assert out[2]["latency_s"] > 0


def test_telemetry(tiny_setup):
    cfg, params = tiny_setup
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32, page_size=4)
    eng.generate(_reqs([(12, 6), (8, 10), (16, 4)]))
    st = eng.stats()
    assert st["requests"] == st["retired"] == 3
    assert st["tokens"] == 6 + 10 + 4
    assert st["queue_depth"] == 0 and st["tokens_in_flight"] == 0
    assert st["peak_pages_in_use"] > 0 and st["pages_in_use"] == 0
    assert st["prefill_s"] > 0 and st["decode_s"] > 0
    assert st["pool_bytes"] > 0
    assert st["prefill_buckets"]            # page-aligned compile buckets
    assert st["prompt_pad_waste"] >= 0


def test_result_status_fields(tiny_setup):
    cfg, params = tiny_setup
    reqs = _reqs([(16, 12), (12, 12)])
    ref = Engine(cfg, params, max_batch=1, max_seq=32)
    eos = ref.generate([reqs[0]])[0]["tokens"][3]
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32, page_size=4,
                           eos_id=eos)
    got = eng.generate(reqs)
    assert got[0]["status"] == "FINISHED_EOS"
    statuses = {g["status"] for g in got}
    assert statuses <= {"FINISHED_EOS", "FINISHED_BUDGET"}
    assert all(g["preemptions"] == 0 for g in got)


def test_preemption_parity_small_pool(tiny_setup):
    """Optimistic admission over an undersized pool: decode-time growth
    preempts, preempted requests recompute-prefill — and every request's
    greedy tokens still equal its B=1 oracle run."""
    cfg, params = tiny_setup
    reqs = _reqs([(16, 12), (14, 12), (15, 10)])
    oracle = Engine(cfg, params, max_batch=1, max_seq=32)
    want = [oracle.generate([r])[0]["tokens"] for r in reqs]
    # 8 usable pages: two 4-page prefills fill the pool; first growth must
    # preempt the younger slot (worst case is 7 pages each)
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32, page_size=4,
                           num_pages=9, decode_chunk=4)
    got = eng.generate(reqs)
    assert [g["tokens"] for g in got] == want
    st = eng.stats()
    assert st["preempted"] > 0
    assert any(g["preemptions"] > 0 for g in got)
    assert st["pages_in_use"] == 0 and st["tokens_in_flight"] == 0
    assert sum(st["statuses"].values()) == len(reqs)


def test_deadline_expires_in_queue(tiny_setup):
    cfg, params = tiny_setup
    reqs = _reqs([(12, 10), (12, 10)])
    reqs[1] = dataclasses.replace(reqs[1], deadline_s=1e-4)
    eng = ContinuousEngine(cfg, params, max_slots=1, max_seq=32, page_size=4)
    out = eng.generate(reqs)
    assert out[0]["status"] == "FINISHED_BUDGET"
    assert out[1]["status"] == "TIMEOUT" and out[1]["decode_len"] == 0
    assert eng.stats()["pages_in_use"] == 0


def test_deadline_expires_in_flight(tiny_setup):
    cfg, params = tiny_setup
    reqs = _reqs([(12, 20)])
    reqs[0] = dataclasses.replace(reqs[0], deadline_s=0.05)
    eng = ContinuousEngine(cfg, params, max_slots=1, max_seq=32, page_size=4,
                           decode_chunk=1)
    out = eng.generate(reqs)                # compile alone blows the budget
    assert out[0]["status"] == "TIMEOUT"
    assert out[0]["decode_len"] < 20
    assert eng.stats()["pages_in_use"] == 0


def test_cancel_and_drain(tiny_setup):
    cfg, params = tiny_setup
    reqs = _reqs([(12, 8), (12, 8), (12, 8)])
    eng = ContinuousEngine(cfg, params, max_slots=1, max_seq=32, page_size=4,
                           decode_chunk=1)
    orders = [eng.submit(r) for r in reqs]
    eng.step()                              # admits + prefills request 0
    assert eng.cancel(reqs[1].id)           # still queued: result now
    assert eng.result(orders[1])["status"] == "CANCELLED"
    assert eng.cancel(reqs[0].id)           # running: retired next boundary
    assert not eng.cancel(999)              # unknown id
    eng.drain()                             # sheds request 2 as REJECTED
    assert eng.result(orders[0])["status"] == "CANCELLED"
    assert eng.result(orders[2])["status"] == "REJECTED"
    st = eng.stats()
    assert st["pages_in_use"] == 0 and st["queue_depth"] == 0
    assert sum(st["statuses"].values()) == 3


def test_drain_idempotent(tiny_setup):
    """drain() is safe to call twice: the second call finds a closed
    intake and an idle scheduler, returns nothing, and leaves the results
    poppable exactly once."""
    cfg, params = tiny_setup
    reqs = _reqs([(12, 6), (12, 6)])
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32, page_size=4,
                           decode_chunk=2)
    orders = [eng.submit(r) for r in reqs]
    eng.step()                              # both admitted + prefilled
    first = eng.drain()
    assert sorted(r["status"] for r in first) == ["FINISHED_BUDGET"] * 2
    assert eng.drain() == []                # idempotent: nothing new, no raise
    for o in orders:
        assert eng.result(o, pop=True)["status"] == "FINISHED_BUDGET"
        assert eng.result(o) is None        # popped exactly once
    st = eng.stats()
    assert st["pages_in_use"] == 0 and st["queue_depth"] == 0
    # a drained engine refuses new work instead of losing it
    o2 = eng.submit(_reqs([(8, 4)], seed=3)[0])
    assert eng.result(o2)["status"] == "REJECTED"


def test_cancel_preempted_resume_entry(tiny_setup):
    """Cancel a request while it sits in the queue as a RESUME entry
    (preempted mid-decode, waiting to recompute-prefill): it settles
    CANCELLED carrying the oracle-prefix tokens it had already generated,
    and the survivor still matches its oracle run."""
    cfg, params = tiny_setup
    reqs = _reqs([(16, 12), (14, 12), (15, 10)])
    oracle = Engine(cfg, params, max_batch=1, max_seq=32)
    want = [oracle.generate([r])[0]["tokens"] for r in reqs]
    # same undersized pool as test_preemption_parity_small_pool: decode-time
    # growth must preempt the younger slot back to the queue
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32, page_size=4,
                           num_pages=9, decode_chunk=4)
    orders = [eng.submit(r) for r in reqs]
    victim = None
    for _ in range(200):
        eng.step()
        resumed = [e for e in eng.scheduler.queue if e.resume_tokens]
        if resumed:
            victim = resumed[0]
            break
    assert victim is not None, "pool never preempted a request to the queue"
    vid = victim.request.id
    assert eng.cancel(vid)
    res = eng.result(orders[vid])
    assert res["status"] == "CANCELLED"
    assert res["preemptions"] >= 1
    assert res["tokens"] == want[vid][:len(res["tokens"])]   # oracle prefix
    assert 0 < len(res["tokens"]) < len(want[vid])
    # run the survivors to terminal before draining: drain() sheds
    # still-fresh queue entries as REJECTED, and whether the last request
    # was admitted yet when the preemption fired is scheduling-dependent
    while eng.step():
        pass
    eng.drain()
    for i, o in enumerate(orders):
        if i == vid:
            continue
        out = eng.result(o)
        assert out["status"] in ("FINISHED_BUDGET", "FINISHED_EOS")
        assert out["tokens"] == want[i]
    st = eng.stats()
    assert st["pages_in_use"] == 0 and st["tokens_in_flight"] == 0
    assert sum(st["statuses"].values()) == len(reqs)


def test_bounded_queue_rejects_at_submit(tiny_setup):
    cfg, params = tiny_setup
    reqs = _reqs([(12, 4), (12, 4)])
    eng = ContinuousEngine(cfg, params, max_slots=1, max_seq=32, page_size=4,
                           max_queue=1)
    o0 = eng.submit(reqs[0])
    o1 = eng.submit(reqs[1])                # queue full (nothing stepped yet)
    assert eng.result(o1)["status"] == "REJECTED"
    while eng.step():                       # request 0 runs to completion
        pass
    eng.drain()
    assert eng.result(o0)["status"] == "FINISHED_BUDGET"
    assert eng.stats()["queue_depth"] == 0


def test_sampling_reproducible_and_seed_distinct(tiny_setup):
    cfg, params = tiny_setup
    reqs = _reqs([(12, 12), (16, 12)])
    def run(seed):
        eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32,
                               page_size=4, sample=True, seed=seed)
        return [r["tokens"] for r in eng.generate(reqs)]
    a, b, c = run(1), run(1), run(2)
    assert a == b                           # reproducible per seed
    assert a != c                           # distinct across seeds
