"""Batched serving engine: shapes, determinism, and left-pad handling."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.registry import build_model
from repro.serve.engine import Engine, Request


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(cfg, params, max_batch=4, max_seq=64)


def _reqs(n, seed=0, new=6):
    rng = np.random.RandomState(seed)
    return [Request(prompt=rng.randint(0, 512, size=rng.randint(3, 12))
                    .astype(np.int32), max_new_tokens=new, id=i)
            for i in range(n)]


def test_generate_shapes(engine):
    out = engine.generate(_reqs(6))
    assert len(out) == 6
    for r in out:
        assert len(r["tokens"]) == 6
        assert all(isinstance(t, int) for t in r["tokens"])


def test_generate_deterministic(engine):
    a = engine.generate(_reqs(3, seed=1))
    b = engine.generate(_reqs(3, seed=1))
    assert [r["tokens"] for r in a] == [r["tokens"] for r in b]


def test_batching_invariance(engine):
    """A request's output does not depend on its batch-mates (greedy).

    Prompts share a length so left-padding is identical batched vs solo
    (pad-token masking inside prefill is a known engine limitation, noted
    in DESIGN.md).
    """
    rng = np.random.RandomState(2)
    reqs = [Request(prompt=rng.randint(0, 512, size=8).astype(np.int32),
                    max_new_tokens=6, id=i) for i in range(2)]
    both = engine.generate(reqs)
    solo = engine.generate([reqs[0]])
    assert both[0]["tokens"] == solo[0]["tokens"]


def test_bucketing_preserves_request_order(engine):
    """Bucketed generate returns results in request order, and matches the
    unbucketed engine when every bucket holds same-length prompts."""
    rng = np.random.RandomState(3)
    # two length classes -> bucketing regroups across the max_batch chunks
    reqs = [Request(prompt=rng.randint(0, 512, size=(4 if i % 2 else 10))
                    .astype(np.int32), max_new_tokens=5, id=100 + i)
            for i in range(8)]
    out = engine.generate(reqs)
    assert [r["id"] for r in out] == [100 + i for i in range(8)]
    # same-length buckets: identical tokens to serving each class alone
    evens = engine.generate([r for i, r in enumerate(reqs) if i % 2 == 0])
    assert [r["tokens"] for i, r in enumerate(out) if i % 2 == 0] == \
        [r["tokens"] for r in evens]


def test_bucketing_cuts_prompt_padding(engine):
    """The stats counter shows the padding the bucketing satellite removes."""
    rng = np.random.RandomState(4)
    reqs = [Request(prompt=rng.randint(0, 512, size=s).astype(np.int32),
                    max_new_tokens=4, id=i)
            for i, s in enumerate([4, 32, 4, 32, 4, 32, 4, 32])]

    def pad_waste(bucket):
        eng = Engine(engine.cfg, engine.params, max_batch=4, max_seq=64,
                     precompute=False, bucket_prompts=bucket)
        eng.generate(reqs)
        return eng.stats()["prompt_pad_waste"]

    assert pad_waste(True) == 0             # perfect buckets: no padding
    assert pad_waste(False) == 4 * 28       # arrival order pads 4 -> 32


def test_engine_stats(engine):
    before = engine.stats()
    out = engine.generate(_reqs(3))
    after = engine.stats()
    assert after["requests"] - before["requests"] == 3
    assert after["tokens"] - before["tokens"] == sum(
        r["decode_len"] for r in out)
    assert after["decode_s"] > before["decode_s"]
    assert after["tokens_per_s"] > 0


def test_sampling_seed_reproducible_and_distinct():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def run(seed):
        eng = Engine(cfg, params, max_batch=4, max_seq=64, sample=True,
                     seed=seed, precompute=False)
        return [r["tokens"] for r in eng.generate(_reqs(2, new=10))]

    a, b, c = run(5), run(5), run(6)
    assert a == b                           # reproducible per seed
    assert a != c                           # distinct across engines
