"""Batched serving engine: shapes, determinism, and left-pad handling."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.registry import build_model
from repro.serve.engine import Engine, Request


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(cfg, params, max_batch=4, max_seq=64)


def _reqs(n, seed=0, new=6):
    rng = np.random.RandomState(seed)
    return [Request(prompt=rng.randint(0, 512, size=rng.randint(3, 12))
                    .astype(np.int32), max_new_tokens=new, id=i)
            for i in range(n)]


def test_generate_shapes(engine):
    out = engine.generate(_reqs(6))
    assert len(out) == 6
    for r in out:
        assert len(r["tokens"]) == 6
        assert all(isinstance(t, int) for t in r["tokens"])


def test_generate_deterministic(engine):
    a = engine.generate(_reqs(3, seed=1))
    b = engine.generate(_reqs(3, seed=1))
    assert [r["tokens"] for r in a] == [r["tokens"] for r in b]


def test_batching_invariance(engine):
    """A request's output does not depend on its batch-mates (greedy).

    Prompts share a length so left-padding is identical batched vs solo
    (pad-token masking inside prefill is a known engine limitation, noted
    in DESIGN.md).
    """
    rng = np.random.RandomState(2)
    reqs = [Request(prompt=rng.randint(0, 512, size=8).astype(np.int32),
                    max_new_tokens=6, id=i) for i in range(2)]
    both = engine.generate(reqs)
    solo = engine.generate([reqs[0]])
    assert both[0]["tokens"] == solo[0]["tokens"]
