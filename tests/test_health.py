"""Numerics & quality health plane (docs/observability.md):
device-side capture folds, quant clip/saturation accounting, the
shadow-oracle sampler, SLO watchdog burn-rate semantics, and the
acceptance bar — online shadow greedy agreement pinned to the offline
``quant/calibrate.py`` harness within one percentage point."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.registry import build_model
from repro.obs import Obs, validate_line
from repro.obs.health import HealthPlane, ShadowOracle
from repro.obs.metrics import Registry
from repro.obs.slo import Rule, SloWatchdog, default_rules
from repro.quant.codec import (INT8_QMAX, QuantPolicy, absmax_scale,
                               plane_clip_report, quantize,
                               saturation_counts)
from repro.serve.engine import ContinuousEngine, Request
from repro.serve.faults import FaultConfig, FaultInjector

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Clip / saturation accounting (quant/codec.py)
# ---------------------------------------------------------------------------
def _clip_conserves(x: np.ndarray):
    """clipped + unclipped == total, exactly, and splitting the array
    never changes the totals (the counters are pure sums)."""
    x = jnp.asarray(x, jnp.float32)
    scale = absmax_scale(x, axes=None)
    q = quantize(x, scale)
    clipped, total = saturation_counts(q)
    clipped = int(clipped)
    assert total == x.size
    assert 0 <= clipped <= total
    unclipped = int(jnp.sum(jnp.abs(q.astype(jnp.float32)) < INT8_QMAX))
    assert clipped + unclipped == total
    if x.size and float(jnp.max(jnp.abs(x))) > 0:
        # absmax scaling puts the block max AT the rail by construction
        assert clipped >= 1
    # split-invariance: per-half censuses sum to the whole
    if x.size >= 2:
        h = x.size // 2
        flat = q.reshape(-1)
        c0, t0 = saturation_counts(flat[:h])
        c1, t1 = saturation_counts(flat[h:])
        assert int(c0) + int(c1) == clipped and t0 + t1 == total


def test_clip_conservation_deterministic():
    rng = np.random.RandomState(0)
    _clip_conserves(rng.randn(37))
    _clip_conserves(rng.randn(8, 16) * 100.0)
    _clip_conserves(np.zeros(5))           # all-zero block: nothing clips
    _clip_conserves(np.ones(9))            # uniform block: EVERYTHING rails


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 128), st.floats(1e-3, 1e3), st.integers(0, 999))
    def test_clip_conservation_swept(n, mag, seed):
        rng = np.random.RandomState(seed)
        _clip_conserves(rng.randn(n) * mag)
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -e .[test])")
    def test_clip_conservation_swept():
        pass


def test_plane_clip_report_on_quantized_params():
    """Every quantized spectral plane contributes >=1 railed code (absmax
    puts the plane max there), and the census stays in [0, total]."""
    from repro.serve.params import precompute_serving_params
    cfg = get_smoke_config("tinyllama-1.1b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    qp = precompute_serving_params(params, cfg,
                                   QuantPolicy(quant_weights=True))
    rep = plane_clip_report(qp)
    assert rep["planes"] > 0
    assert 0 < rep["clipped"] <= rep["total"]
    assert rep["clipped"] >= rep["planes"]


# ---------------------------------------------------------------------------
# HealthPlane folds (host side of the device capture)
# ---------------------------------------------------------------------------
def test_health_plane_skips_idle_rows():
    reg = Registry()
    hp = HealthPlane(reg)
    hp.on_decode(np.array([[3.0, 1.2, 0.4, 0.0],
                           [9.9, 9.9, 9.9, 0.0]]),
                 steps=np.array([2, 0]))
    h = reg.histogram("health.logit_absmax", phase="decode")
    assert h.count == 1 and h.max == 3.0
    assert hp.nonfinite_dispatches == 0
    hp.on_decode(np.array([[1.0, 1.0, 1.0, 3.0]]), steps=np.array([1]))
    assert hp.nonfinite_dispatches == 1
    assert reg.value("health.nonfinite_logits") == 3.0


def test_health_plane_prefill_fold():
    reg = Registry()
    hp = HealthPlane(reg)
    hp.on_prefill({"logit": np.array([2.5, 1.0, 0.3, 0.0]),
                   "act_absmax": np.array([1.0, 4.0, 2.0])})
    assert reg.histogram("health.logit_absmax", phase="prefill").count == 1
    assert reg.histogram("health.act_absmax", phase="prefill").count == 3
    assert hp.stats()["act_absmax_peak"] == 4.0
    hp.on_prefill({"logit": np.array([np.nan, 1.0, 0.3, 2.0]),
                   "act_absmax": np.array([])})
    assert hp.stats()["nonfinite_dispatches"] == 1


# ---------------------------------------------------------------------------
# ShadowOracle sampling mechanics (no model needed)
# ---------------------------------------------------------------------------
def test_shadow_oracle_gauges_are_lazy():
    """No agreement/drift gauge may exist before the first replay — a
    gauge born at 0.0 would breach the SLO agreement rule on every
    snapshot of a run whose replays simply haven't happened yet."""
    reg = Registry()
    ShadowOracle(None, None, policy=QuantPolicy(), registry=reg,
                 sample=1.0)
    snap = reg.snapshot()
    assert "health.greedy_agreement" not in snap["gauges"]
    assert "health.logit_drift" not in snap["gauges"]


def test_shadow_oracle_bounded_queue_drops():
    reg = Registry()
    so = ShadowOracle(None, None, policy=QuantPolicy(), registry=reg,
                      sample=1.0, max_pending=2)
    for _ in range(5):
        so.maybe_enqueue(np.array([1, 2, 3]), 4)
    st = so.stats()
    assert so.pending == 2
    assert st["sampled"] == 5 and st["dropped"] == 3
    assert st["greedy_agreement"] is None            # nothing replayed yet


def test_shadow_oracle_sample_zero_never_enqueues():
    reg = Registry()
    so = ShadowOracle(None, None, policy=QuantPolicy(), registry=reg,
                      sample=0.0)
    assert not so.maybe_enqueue(np.array([1]), 1)
    assert so.stats()["sampled"] == 0 and so.pending == 0


# ---------------------------------------------------------------------------
# SLO watchdog rule evaluation
# ---------------------------------------------------------------------------
def _snap(seq, gauges=None, counters=None, hists=None):
    return {"type": "snapshot", "seq": seq, "t_s": float(seq),
            "counters": counters or {}, "gauges": gauges or {},
            "histograms": hists or {}}


def test_slo_gauge_burn_fires_once_and_rearms():
    """Sustained breach fires ONE alert (latch); clearing re-arms."""
    wd = SloWatchdog([Rule("drift", metric="health.logit_drift",
                           kind="gauge", op=">", threshold=10.0,
                           windows=((2, 1.0),))])
    assert wd.observe(_snap(0, {"health.logit_drift": 50.0})) == []
    fired = wd.observe(_snap(1, {"health.logit_drift": 60.0}))
    assert len(fired) == 1 and fired[0]["rule"] == "drift"
    # still burning: latched, no duplicate alert
    assert wd.observe(_snap(2, {"health.logit_drift": 70.0})) == []
    # clears, then burns again -> second excursion, second alert
    wd.observe(_snap(3, {"health.logit_drift": 1.0}))
    wd.observe(_snap(4, {"health.logit_drift": 99.0}))
    fired = wd.observe(_snap(5, {"health.logit_drift": 99.0}))
    assert len(fired) == 1
    assert wd.stats() == {"alerts": 2, "page_alerts": 2,
                          "by_rule": {"drift": 2}}


def test_slo_no_burn_and_flapping_stay_silent():
    wd = SloWatchdog([Rule("drift", metric="health.logit_drift",
                           kind="gauge", op=">", threshold=10.0,
                           windows=((2, 1.0),))])
    # healthy values never fire
    for i in range(4):
        assert wd.observe(_snap(i, {"health.logit_drift": 1.0})) == []
    # flapping (breach, clear, breach, clear) never fills the 2-window
    for i, v in enumerate([50.0, 1.0, 50.0, 1.0, 50.0]):
        assert wd.observe(_snap(10 + i, {"health.logit_drift": v})) == []
    assert wd.alerts == []


def test_slo_absent_series_never_fires():
    """A run without --shadow-sample has NO agreement gauge: the rule must
    contribute no observation (instead of reading an implicit 0.0)."""
    wd = SloWatchdog([Rule("agree", metric="health.greedy_agreement",
                           kind="gauge", op="<", threshold=0.5,
                           windows=((1, 1.0),))])
    for i in range(3):
        assert wd.observe(_snap(i, {"other.gauge": 0.0})) == []
    assert wd.alerts == []


def test_slo_rate_rule_skips_first_snapshot():
    wd = SloWatchdog([Rule("anom", metric="engine.anomalies*", kind="rate",
                           op=">", threshold=0.0, windows=((1, 1.0),))])
    # first snapshot: no previous counters, no observation even at 5
    assert wd.observe(_snap(0, counters={"engine.anomalies": 5.0})) == []
    # no delta -> no fire; delta of 2 -> fire
    assert wd.observe(_snap(1, counters={"engine.anomalies": 5.0})) == []
    fired = wd.observe(_snap(2, counters={"engine.anomalies": 7.0}))
    assert len(fired) == 1 and fired[0]["value"] == 2.0


def test_baseline_snapshot_catches_pre_tick_anomaly(tmp_path):
    """A guard trip BEFORE the first cadence tick must still fire the
    anomaly-burst rate rule: the engine's birth ``Obs.baseline()``
    snapshot gives the rule a zero baseline, so the bump lands in a
    visible inter-snapshot delta (chaos invariant 4 in serve/faults.py)."""
    wd = SloWatchdog()
    obs = Obs(emit_path=str(tmp_path / "m.jsonl"), emit_every=5, slo=wd)
    c = obs.registry.counter("engine.anomalies")
    obs.baseline()                  # what ContinuousEngine.__init__ does
    c.inc()                         # anomaly before any tick
    for _ in range(5):
        obs.tick()
    obs.close()
    assert wd.stats()["by_rule"].get("anomaly-burst", 0) == 1
    # emitterless Obs: baseline + the final close() evaluation suffice
    wd2 = SloWatchdog()
    obs2 = Obs(slo=wd2)
    obs2.registry.counter("engine.anomalies")
    obs2.baseline()
    obs2.registry.counter("engine.anomalies").inc()
    obs2.close()
    assert wd2.stats()["by_rule"].get("anomaly-burst", 0) == 1


def test_slo_ratio_rule_and_labelled_denominator():
    wd = SloWatchdog([Rule("clip", metric="quant.clip.kv_clipped*",
                           kind="ratio", denom="quant.clip.kv_total",
                           op=">", threshold=0.5, windows=((1, 1.0),),
                           severity="warn")])
    c0 = {"quant.clip.kv_clipped": 0.0, "quant.clip.kv_total": 100.0}
    wd.observe(_snap(0, counters=c0))
    # 10/100 new values clipped: below threshold
    c1 = {"quant.clip.kv_clipped": 10.0, "quant.clip.kv_total": 200.0}
    assert wd.observe(_snap(1, counters=c1)) == []
    # 90/100 clipped: ratio 0.9 > 0.5 fires at warn severity
    c2 = {"quant.clip.kv_clipped": 100.0, "quant.clip.kv_total": 300.0}
    fired = wd.observe(_snap(2, counters=c2))
    assert len(fired) == 1 and fired[0]["severity"] == "warn"
    assert fired[0]["value"] == pytest.approx(0.9)
    # stalled denominator: no observation, no spurious division
    assert wd.observe(_snap(3, counters=c2)) == []


def test_slo_alert_record_validates_and_bumps_registry():
    reg = Registry()
    wd = SloWatchdog([Rule("drift", metric="health.logit_drift*",
                           kind="gauge", op=">", threshold=10.0,
                           windows=((1, 1.0),))], registry=reg)
    fired = wd.observe(_snap(0, {"health.logit_drift{replica=r1}": 99.0}))
    assert len(fired) == 1
    validate_line(fired[0])                # schema-valid JSONL record
    # labels of the offending series carry onto the slo.alerts counter
    assert reg.value("slo.alerts", replica="r1") == 1
    bad = dict(fired[0])
    bad["severity"] = "catastrophic"
    with pytest.raises(ValueError):
        validate_line(bad)
    bad = dict(fired[0])
    del bad["threshold"]
    with pytest.raises(ValueError):
        validate_line(bad)


def test_default_rules_pass_healthy_snapshot():
    """The stock ruleset must be quiet on a healthy-looking snapshot —
    thresholds are generous by design (docs/observability.md)."""
    wd = SloWatchdog(default_rules())
    healthy = _snap(
        0,
        gauges={"health.logit_drift": 0.06, "health.greedy_agreement": 1.0},
        counters={"engine.anomalies": 0.0, "tokens": 100.0,
                  "quant.clip.kv_clipped": 5.0,
                  "quant.clip.kv_total": 1000.0},
        hists={"trace.ttft_s": {"p99": 2.0}})
    for i in range(10):
        healthy["seq"] = i
        healthy["counters"]["tokens"] += 50.0
        healthy["counters"]["quant.clip.kv_total"] += 100.0
        assert wd.observe(healthy) == []
    assert wd.alerts == []


def test_replica_degrades_on_slo_alert():
    """fleet/replica.py consumes slo.alerts deltas exactly like NaN-guard
    anomalies: one fired alert -> DEGRADED."""
    import collections

    from repro.fleet.replica import DEGRADED, HEALTHY, EngineReplica

    class _Eng:
        def __init__(self):
            self.obs = Obs()
            self.anomalies = 0
            self.max_seq = None

            class _Sched:
                queue_depth = 0
                running = ()
                queue = collections.deque()

                def drain_doomed(self):
                    return []

            self.scheduler = _Sched()

        def step(self):
            return True

        def stats(self):
            return {}

    eng = _Eng()
    rep = EngineReplica("r0", eng, step_timeout_s=10.0)
    rep.step()
    assert rep.state == HEALTHY
    wd = SloWatchdog([Rule("drift", metric="health.logit_drift",
                           kind="gauge", op=">", threshold=10.0,
                           windows=((1, 1.0),))],
                     registry=eng.obs.registry)
    wd.observe(_snap(0, {"health.logit_drift": 99.0}))
    rep.step()
    assert rep.state == DEGRADED
    assert rep.stats()["slo_alerts"] == 1


# ---------------------------------------------------------------------------
# Engine integration: capture + clip telemetry + the acceptance bar
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _reqs(n, new=4):
    rng = np.random.RandomState(0)
    return [Request(prompt=rng.randint(1, 512, size=rng.randint(4, 10))
                    .astype(np.int32), max_new_tokens=new, id=i)
            for i in range(n)]


@pytest.fixture(scope="module")
def int8_shadow_run(setup):
    """One int8-KV serve with shadow_sample=1.0 — several tests read it."""
    cfg, params = setup
    obs = Obs()
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32,
                           page_size=4, quant=QuantPolicy(kv_dtype="int8"),
                           obs=obs, shadow_sample=1.0, seed=0)
    reqs = _reqs(3)
    eng.generate(reqs)
    return eng, obs, reqs


def test_capture_populates_health_histograms(int8_shadow_run):
    eng, obs, _ = int8_shadow_run
    reg = obs.registry
    for phase in ("prefill", "decode"):
        assert reg.histogram("health.logit_absmax", phase=phase).count > 0
        assert reg.histogram("health.logit_entropy", phase=phase).count > 0
        assert reg.histogram("health.top1_margin", phase=phase).count > 0
    assert reg.histogram("health.act_absmax", phase="prefill").count > 0
    st = eng.stats()
    assert st["health"]["nonfinite_dispatches"] == 0
    assert st["health"]["act_absmax_peak"] > 0


def test_kv_clip_counters_within_bounds(int8_shadow_run):
    eng, obs, _ = int8_shadow_run
    reg = obs.registry
    clipped = reg.value("quant.clip.kv_clipped")
    total = reg.value("quant.clip.kv_total")
    assert total > 0 and 0 <= clipped <= total
    st = eng.stats()
    assert st["kv_clip_rate"] == pytest.approx(clipped / total)
    # scale histograms got fed (page scales are positive by construction)
    assert reg.histogram("quant.k_scale").count > 0
    assert reg.histogram("quant.v_scale").count > 0


def test_online_agreement_matches_offline_calibrate(int8_shadow_run,
                                                    setup):
    """ACCEPTANCE: online shadow greedy agreement on int8-KV tinyllama
    matches the offline quant/calibrate.py harness within 1 percentage
    point (same prompts, same teacher-forced definition)."""
    from repro.quant.calibrate import ParityRunner
    from repro.serve.params import precompute_serving_params
    eng, obs, reqs = int8_shadow_run
    st = eng.stats()["shadow_oracle"]
    assert st["replays"] == len(reqs) and st["dropped"] == 0
    online = st["greedy_agreement"]
    assert online is not None
    cfg, params = setup
    policy = QuantPolicy(kv_dtype="int8")
    runner = ParityRunner(cfg, precompute_serving_params(params, cfg),
                          precompute_serving_params(params, cfg, policy),
                          policy=policy, page_size=4)
    steps = agree = 0.0
    for r in reqs:
        rep = runner.run(np.asarray(r.prompt), r.max_new_tokens)
        steps += rep["steps"]
        agree += rep["greedy_agreement"] * rep["steps"]
    offline = agree / steps
    assert abs(online - offline) <= 0.01, (online, offline)
    # the gauges exist now (post-replay) and carry the same numbers
    assert obs.registry.value("health.greedy_agreement") == \
        pytest.approx(online)
    assert obs.registry.value("health.logit_drift") == \
        pytest.approx(st["logit_drift"])


def test_corruption_surfaces_in_health_plane(setup):
    """Under corrupt_p chaos the capture plane surfaces every NaN-guard
    trip: nonfinite_dispatches >= anomalies, at the SAME fenced dispatch
    (the guard retires FROM the plane's signal by construction)."""
    cfg, params = setup
    obs = Obs()
    inj = FaultInjector(FaultConfig(seed=0, corrupt_p=1.0))
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32,
                           page_size=4, precompute=False, obs=obs,
                           faults=inj)
    eng.generate(_reqs(2))
    st = eng.stats()
    assert st["anomalies"] >= 1                      # guard actually fired
    assert st["health"]["nonfinite_dispatches"] >= st["anomalies"]
    assert st["health"]["nonfinite_logits"] > 0


def test_disabled_obs_skips_capture_entirely(setup):
    """obs.enabled=False compiles the pre-health program: stats side-
    outputs are None, no health plane, no clip counters move."""
    cfg, params = setup
    obs = Obs(enabled=False)
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32,
                           page_size=4, precompute=False, obs=obs,
                           quant=QuantPolicy(kv_dtype="int8"))
    eng.generate(_reqs(2))
    assert eng._health is None
    st = eng.stats()
    assert "health" not in st
    assert st["kv_clip_rate"] is None
    assert obs.registry.value("quant.clip.kv_total") == 0


def test_shadow_sample_requires_precompute(setup):
    cfg, params = setup
    with pytest.raises(ValueError):
        ContinuousEngine(cfg, params, max_slots=2, max_seq=32, page_size=4,
                         precompute=False, shadow_sample=0.5)
