"""Sharding rule engine: spec derivation (duck-typed mesh, no devices) and a
subprocess-based compile check on an 8-device host mesh (the dry-run in
miniature, so CI catches partitioning regressions without 512 devices)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as sh


class FakeMesh:
    """Duck-typed stand-in: spec derivation only needs names + shape."""
    def __init__(self, shape, names):
        self.devices = np.zeros(shape)
        self.axis_names = names


MESH = FakeMesh((16, 16), ("data", "model"))
MESH3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_dense_column_row():
    # column: TP(+FSDP) on n_out
    assert sh.param_spec(("attn", "q", "w"), (2048, 4096), MESH) == \
        P(None, ("model", "data"))
    # column, n_out divisible by neither axis -> replicated
    assert sh.param_spec(("attn", "q", "w"), (2048, 4050), MESH) == P(None, None)
    # column, n_out model-divisible only
    assert sh.param_spec(("attn", "q", "w"), (2048, 4048), MESH) == \
        P(None, "model")
    # row: TP on contraction n_in, FSDP on n_out
    assert sh.param_spec(("attn", "o", "w"), (4096, 2048), MESH) == \
        P("model", "data")


def test_circulant_specs():
    # column: p over model when divisible
    assert sh.param_spec(("mlp", "up", "wc"), (32, 16, 128), MESH) == \
        P("model", None, "data")
    # p not divisible -> k carries storage sharding
    assert sh.param_spec(("mlp", "up", "wc"), (10, 16, 128), MESH) == \
        P(None, None, "model")
    # row: q over model
    assert sh.param_spec(("mlp", "down", "wc"), (16, 32, 128), MESH) == \
        P(None, "model", "data")
    # never shard a contraction dim over data (RULE ZERO)
    spec = sh.param_spec(("mlp", "down", "wc"), (16, 44, 128), MESH)
    assert spec[1] != "data"


def test_stacked_leading_dims_ignored():
    spec = sh.param_spec(("segments", "0", "mlp", "up", "wc"),
                         (11, 32, 16, 128), MESH)
    assert spec == P(None, "model", None, "data")


def test_expert_ep_when_divisible():
    # llama4: 128 experts over 16-way model = EP
    spec = sh.param_spec(("segments", "0", "moe", "experts", "up"),
                         (24, 128, 64, 40, 128), MESH)
    assert spec[1] == "model"
    # mixtral: 8 experts -> TP inside the expert (circulant p=112 blocks)
    spec = sh.param_spec(("segments", "0", "moe", "experts", "up"),
                         (32, 8, 112, 32, 128), MESH)
    assert spec[1] is None and spec[2] == "model"


def test_embed_and_norms():
    assert sh.param_spec(("embed", "table"), (256000, 3584), MESH) == \
        P(("model", "data"), None)
    assert sh.param_spec(("embed", "table"), (32128, 3072), MESH) == \
        P("model", None)
    assert sh.param_spec(("ln1", "scale"), (1024,), MESH) == P()


def test_batch_and_cache_specs():
    assert sh.batch_spec((256, 4096), MESH, 256) == P(("data",), None)
    assert sh.batch_spec((256, 4096), MESH3, 256) == P(("pod", "data"), None)
    assert sh.batch_spec((1, 524288), MESH, 1) == P(None, None)
    # seq sharding (tokenpar)
    assert sh.batch_spec((256, 4096), MESH, 256, seq_shard=True) == \
        P(("data",), "model")
    # kv cache: batch over dp, head_dim over model (P normalizes 1-tuples)
    assert sh.cache_spec(("k",), (11, 128, 32768, 4, 64), np.float32,
                         MESH, 128)[1] in ("data", ("data",))
    assert sh.cache_spec(("k",), (11, 128, 32768, 4, 64), np.float32,
                         MESH, 128)[4] == "model"
    # int ring positions replicate
    assert sh.cache_spec(("pos",), (11, 32768), np.int32, MESH, 128) == P()


def test_tokenpar_strategy_replicates_weights():
    spec = sh.param_spec(("mlp", "up", "wc"), (32, 16, 128), MESH,
                         strategy="tokenpar")
    assert "model" not in tuple(spec)      # weights replicate over model


@pytest.mark.slow
def test_small_mesh_compile_subprocess(tmp_path):
    """lower+compile a reduced arch on a (2,4) host mesh in a subprocess
    (XLA_FLAGS must be set before jax import, so this cannot run in-proc)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import jax
        from repro.configs.registry import get_smoke_config
        from repro.launch import dryrun, mesh as mesh_lib
        mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke_config("mixtral-8x7b").replace(remat="none")
        lowered, compiled, meta = dryrun.lower_cell(
            "mixtral-8x7b", "train_4k", mesh, cfg_override=cfg, accum=1)
        print("COMPILED_OK", compiled.cost_analysis()["flops"] > 0)
    """) % (os.path.join(os.path.dirname(__file__), "..", "src"),)
    p = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900)
    assert "COMPILED_OK True" in p.stdout, p.stdout + p.stderr
