"""Shared test config.  NOTE: no XLA_FLAGS here by design — smoke tests and
benchmarks must see the real single CPU device; only the dry-run (and the
subprocess-based sharding tests) force a 512/8-device host platform."""
import jax
import pytest


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)
    yield
