"""Shared test config.  NOTE: no XLA_FLAGS here by design — smoke tests and
benchmarks must see the real single CPU device; only the dry-run (and the
subprocess-based sharding tests) force a 512/8-device host platform."""
import os
import sys

# Path shim: the suite runs against an installed `repro` (pip install -e .)
# OR straight from a checkout via the tier-1 `PYTHONPATH=src` invocation —
# and, with this shim, from a bare checkout with neither.
try:
    import repro  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)
    yield
