"""Recurrent cells: sequence form == step form; chunk-size invariance;
state carry across calls (the contract the decode path relies on)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers import recurrent as rec


def test_rglru_state_carry():
    """Running [S1 | S2] in two calls == one call over S1+S2."""
    d, w, B = 16, 16, 2
    params = rec.init_rglru(jax.random.PRNGKey(0), d, w)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 12, d))
    full, _ = rec.rglru_block(params, x, width=w)
    st = rec.init_rglru_state(B, w)
    o1, st = rec.rglru_block(params, x[:, :5], width=w, state=st)
    o2, st = rec.rglru_block(params, x[:, 5:], width=w, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(full), rtol=3e-3, atol=3e-3)


def test_rglru_step_by_step():
    d, w, B = 8, 8, 1
    params = rec.init_rglru(jax.random.PRNGKey(0), d, w)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 6, d))
    full, _ = rec.rglru_block(params, x, width=w)
    st = rec.init_rglru_state(B, w)
    outs = []
    for t in range(6):
        o, st = rec.rglru_block(params, x[:, t:t + 1], width=w, state=st)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("chunk", [2, 4, 8, 16])
def test_mlstm_chunk_invariance(chunk):
    B, H, S, dh = 1, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q, k, v = (jax.random.normal(ks[i], (B, H, S, dh)) for i in range(3))
    i_pre = jax.random.normal(ks[3], (B, H, S))
    f_pre = jax.random.normal(ks[4], (B, H, S)) + 2.0
    ref, _ = rec._mlstm_seq(q, k, v, i_pre, f_pre, chunk=S)
    out, _ = rec._mlstm_seq(q, k, v, i_pre, f_pre, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-3, atol=3e-3)


def test_mlstm_seq_equals_steps():
    B, H, S, dh = 1, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q, k, v = (jax.random.normal(ks[i], (B, H, S, dh)) for i in range(3))
    i_pre = jax.random.normal(ks[3], (B, H, S))
    f_pre = jax.random.normal(ks[4], (B, H, S)) + 2.0
    seq_out, seq_state = rec._mlstm_seq(q, k, v, i_pre, f_pre, chunk=4)
    st = rec.init_mlstm_state(B, H, dh)
    outs = []
    for t in range(S):
        h, st = rec.mlstm_step(q[:, :, t], k[:, :, t], v[:, :, t],
                               i_pre[:, :, t], f_pre[:, :, t], st)
        outs.append(h)
    step_out = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(step_out), np.asarray(seq_out),
                               rtol=3e-3, atol=3e-3)
    for a, b in zip(seq_state, st):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-3, atol=3e-3)


def test_slstm_state_carry():
    d, B = 8, 2
    params = rec.init_slstm(jax.random.PRNGKey(0), d, 1)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 10, d))
    full, _ = rec.slstm_block(params, x)
    st = rec.init_slstm_state(B, d)
    o1, st = rec.slstm_block(params, x[:, :4], state=st)
    o2, st = rec.slstm_block(params, x[:, 4:], state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(full), rtol=3e-3, atol=3e-3)


def test_rglru_long_context_stability():
    """Bounded state: no blowup over a long roll (the long_500k property)."""
    d, w, B = 8, 8, 1
    params = rec.init_rglru(jax.random.PRNGKey(0), d, w)
    st = rec.init_rglru_state(B, w)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 64, d))
    for _ in range(8):
        out, st = rec.rglru_block(params, x, width=w, state=st)
    assert bool(jnp.isfinite(out).all())
    assert bool(jnp.isfinite(st["h"]).all())
    assert float(jnp.abs(st["h"]).max()) < 1e3
