"""repro.obs: metric invariants (hypothesis sweeps where available),
trace span ordering, emitter schema round-trip, and engine-level
trace/stats integration for both serving engines."""
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.registry import build_model
from repro.obs import (Obs, RequestTrace, TraceStore, validate_jsonl,
                       validate_line)
from repro.obs.emit import Emitter
from repro.obs.metrics import (SECONDS_BUCKETS, Counter, Gauge, Histogram,
                               Registry, flat_name)
from repro.serve.engine import ContinuousEngine, Engine, Request

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Counter / Gauge
# ---------------------------------------------------------------------------
def _counter_monotone(incs):
    c = Counter()
    prev = c.value
    for n in incs:
        c.inc(n)
        assert c.value >= prev
        prev = c.value
    assert abs(c.value - sum(incs)) < 1e-6 * max(sum(incs), 1.0)


def test_counter_monotone_deterministic():
    _counter_monotone([1, 0, 2.5, 1e-9, 1000])


def test_counter_rejects_negative():
    c = Counter()
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 0.0                  # failed inc left no trace


def test_gauge_high_water():
    g = Gauge()
    assert g.min_seen is None              # unset != "saw zero headroom"
    for v, peak, low in [(3, 3, 3), (1, 3, 1), (7, 7, 1), (0, 7, 0)]:
        g.set(v)
        assert g.value == v and g.max_seen == peak and g.min_seen == low


# ---------------------------------------------------------------------------
# Histogram
# ---------------------------------------------------------------------------
def _histogram_conserves(values):
    h = Histogram.of(values)
    assert sum(h.counts) == h.count == len(values)
    assert abs(h.sum - sum(values)) < 1e-6 * max(abs(sum(values)), 1.0)
    if values:
        assert h.min == min(values) and h.max == max(values)


def test_histogram_conservation_deterministic():
    _histogram_conserves([0.0, 1e-5, 0.3, 99.0, 1e4])
    _histogram_conserves([])


def test_histogram_percentile_matches_numpy():
    rng = np.random.RandomState(0)
    vals = rng.exponential(0.1, size=137).tolist()
    h = Histogram.of(vals)
    for q in (0, 25, 50, 90, 99, 100):
        assert h.percentile(q) == pytest.approx(
            float(np.percentile(vals, q)), rel=1e-12)


def test_histogram_overflow_falls_back_to_buckets():
    h = Histogram(bounds=(1.0, 2.0), keep=3)
    for v in (0.5, 1.5, 2.5, 0.7, 1.7):    # 2 past the retention window
        h.observe(v)
    assert h.count == 5 and sum(h.counts) == 5
    p50 = h.percentile(50)                 # bucket-edge interpolation path
    assert p50 is not None and 0.0 < p50 <= h.max


def test_histogram_rejects_bad_bounds():
    with pytest.raises(ValueError):
        Histogram(bounds=(2.0, 1.0))


def test_histogram_observe_many_matches_loop():
    # the health plane's bulk fold must be state-identical to a loop of
    # observe() calls — bucket edges (== bound values) included
    rng = np.random.RandomState(7)
    vals = np.concatenate([rng.exponential(0.1, size=23),
                           np.array(SECONDS_BUCKETS[:4])])
    keep = 10                              # exercise the retention clamp
    h_loop, h_bulk = Histogram(keep=keep), Histogram(keep=keep)
    for v in vals:
        h_loop.observe(float(v))
    h_bulk.observe_many(vals[:11])
    h_bulk.observe_many(vals[11:])
    h_bulk.observe_many(np.array([]))      # empty fold is a no-op
    assert h_bulk.counts == h_loop.counts
    assert h_bulk.count == h_loop.count
    assert h_bulk.sum == pytest.approx(h_loop.sum)
    assert (h_bulk.min, h_bulk.max) == (h_loop.min, h_loop.max)
    assert h_bulk._values == pytest.approx(h_loop._values)
    with pytest.raises(ValueError):
        Histogram(bounds=(1.0, 1.0))


# ---------------------------------------------------------------------------
# Hypothesis sweeps (skipped without the optional dependency)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(min_value=0, max_value=1e6,
                              allow_nan=False), max_size=50))
    def test_counter_monotone_swept(incs):
        _counter_monotone(incs)

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(min_value=-1e3, max_value=1e6,
                              allow_nan=False), max_size=100))
    def test_histogram_conservation_swept(values):
        _histogram_conserves(values)

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(min_value=0, max_value=1e3,
                              allow_nan=False, allow_infinity=False),
                    min_size=1, max_size=80),
           st.floats(min_value=0, max_value=100))
    def test_histogram_percentile_swept(values, q):
        assert Histogram.of(values).percentile(q) == pytest.approx(
            float(np.percentile(values, q)), rel=1e-9, abs=1e-9)

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(min_value=0, max_value=10,
                              allow_nan=False), min_size=4, max_size=4))
    def test_trace_ordering_swept(deltas):
        """Any nonneg-delta timeline validates; any strictly decreasing
        adjacent pair raises."""
        t = np.cumsum(deltas)
        tr = RequestTrace(id=0, order=0, prompt_len=3, enqueue_s=t[0])
        tr.mark_admit(t[1])
        tr.mark_first_token(t[2])
        tr.mark_retire(t[3])
        tr.validate()
else:                                                  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(optional test dependency)")
    def test_obs_property_sweeps():
        pass


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
def test_registry_get_or_create_and_kind_mismatch():
    r = Registry()
    assert r.counter("a") is r.counter("a")
    assert r.counter("d", reason="x") is not r.counter("d", reason="y")
    with pytest.raises(TypeError):
        r.gauge("a")                       # same name, different kind


def test_registry_snapshot_delta_roundtrip():
    r = Registry()
    r.counter("c").inc(3)
    r.gauge("g").set(7)
    r.histogram("h").observe(0.01)
    s1 = r.snapshot()
    json.dumps(s1)                         # JSON-able
    r.counter("c").inc(2)
    d = Registry.delta(r.snapshot(), s1)
    assert d["c"] == 2.0
    assert flat_name("d", (("reason", "x"),)) == "d{reason=x}"


# ---------------------------------------------------------------------------
# Traces
# ---------------------------------------------------------------------------
def _mk_trace(order=0, t=(0.0, 0.1, 0.5, 1.5), decode=5):
    tr = RequestTrace(id=order, order=order, prompt_len=8, enqueue_s=t[0])
    tr.mark_admit(t[1])
    tr.mark_first_token(t[2])
    if decode > 1:
        tr.mark_chunk(t[3], decode - 1)
    tr.mark_retire(t[3])
    return tr


def test_trace_derived_spans():
    tr = _mk_trace()
    assert tr.queue_s == pytest.approx(0.1)
    assert tr.ttft_s == pytest.approx(0.5)
    assert tr.prefill_s == pytest.approx(0.4)
    assert tr.decode_s == pytest.approx(1.0)
    assert tr.latency_s == pytest.approx(1.5)
    assert tr.decode_len == 5
    assert tr.tpot_s == pytest.approx(1.0 / 4)
    assert _mk_trace(decode=1).tpot_s is None


def test_trace_validate_rejects_disorder_and_missing():
    tr = RequestTrace(id=0, order=0, prompt_len=1, enqueue_s=1.0)
    with pytest.raises(ValueError):
        tr.validate()                      # missing marks
    tr.mark_admit(0.5)                     # admit BEFORE enqueue
    tr.mark_first_token(2.0)
    tr.mark_retire(3.0)
    with pytest.raises(ValueError):
        tr.validate()


def test_trace_store_lifecycle():
    s = TraceStore(max_completed=2)
    traces = [s.start(i, i, 4, 0.0) for i in range(3)]
    for tr in traces:
        tr.mark_admit(0.1), tr.mark_first_token(0.2), tr.mark_retire(0.3)
        s.finish(tr)
    assert not s.active
    assert len(s.completed) == 2           # bounded buffer
    assert len(s.drain_pending()) == 2
    assert s.drain_pending() == []         # drained


def test_trace_unserved_status_relaxes_required_marks():
    # a request cancelled in queue never admits: enqueue + retire suffice
    tr = RequestTrace(id=0, order=0, prompt_len=4, enqueue_s=1.0)
    tr.status = "CANCELLED"
    tr.mark_retire(1.5)
    tr.validate()
    assert tr.queue_s is None and tr.latency_s == pytest.approx(0.5)
    d = tr.to_dict()
    assert d["status"] == "CANCELLED" and d["admit_s"] is None
    validate_line({"type": "trace", "t_s": 0.0, **d})
    # a SERVED trace still needs the full timeline
    tr2 = RequestTrace(id=1, order=1, prompt_len=4, enqueue_s=1.0)
    tr2.status = "FINISHED_EOS"
    tr2.mark_retire(1.5)
    with pytest.raises(ValueError):
        tr2.validate()
    with pytest.raises(ValueError):
        validate_line({"type": "trace", "t_s": 0.0, **tr2.to_dict()})


def test_trace_preemptions_recorded():
    tr = _mk_trace()
    tr.mark_preempt(0.7, 3)
    tr.mark_preempt(0.9, 5)
    d = tr.to_dict()
    assert d["preemptions"] == [[0.7, 3], [0.9, 5]]
    validate_line({"type": "trace", "t_s": 0.0, **d})


def test_validate_line_rejects_unknown_status():
    d = _mk_trace().to_dict()
    d["status"] = "DONEISH"
    with pytest.raises(ValueError, match="unknown status"):
        validate_line({"type": "trace", "t_s": 0.0, **d})


# ---------------------------------------------------------------------------
# Emitter
# ---------------------------------------------------------------------------
def test_emitter_roundtrip_file(tmp_path):
    path = str(tmp_path / "m.jsonl")
    obs = Obs(emit_path=path, emit_every=2)
    obs.registry.counter("tokens").inc(5)
    tr = obs.trace_start(0, 0, 4, 0.0)
    tr.mark_admit(0.1), tr.mark_first_token(0.2)
    tr.mark_chunk(0.4, 3), tr.mark_retire(0.4)
    obs.trace_finish(tr)
    obs.tick()                             # tick 1: below cadence, no flush
    assert obs.emitter.lines_written == 0
    obs.tick()                             # tick 2: flush
    assert obs.emitter.lines_written == 2  # snapshot + the trace
    obs.close()
    counts = validate_jsonl(path)
    assert counts["trace"] == 1 and counts["snapshot"] >= 2
    lines = [json.loads(l) for l in open(path)]
    trace = next(l for l in lines if l["type"] == "trace")
    assert trace["decode_len"] == 4 and trace["ttft_s"] == pytest.approx(0.2)
    snap = next(l for l in lines if l["type"] == "snapshot")
    assert snap["counters"]["tokens"] == 5.0
    assert "trace.ttft_s" in snap["histograms"]


def test_emitter_callback_and_validation():
    got = []
    reg, traces = Registry(), TraceStore()
    em = Emitter(reg, traces, callback=got.append, every=1)
    reg.histogram("h").observe(0.2)
    em.tick()
    assert len(got) == 1
    validate_line(got[0])
    with pytest.raises(ValueError):
        validate_line({"type": "nope"})
    bad = dict(got[0])
    bad["histograms"] = {"h": {"buckets": [1.0], "counts": [1], "count": 5}}
    with pytest.raises(ValueError):
        validate_line(bad)                 # bucket-count conservation
    with pytest.raises(ValueError):
        Emitter(reg, traces)               # no sink


# ---------------------------------------------------------------------------
# Engine integration (smoke model, module-scoped)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _reqs(n, new=5):
    rng = np.random.RandomState(0)
    return [Request(prompt=rng.randint(0, 512, size=rng.randint(3, 12))
                    .astype(np.int32), max_new_tokens=new, id=i)
            for i in range(n)]


@pytest.mark.parametrize("engine_cls", [Engine, ContinuousEngine],
                         ids=["batch", "continuous"])
def test_engine_traces_per_request(setup, engine_cls):
    """Every retired request leaves a validated trace with TTFT/TPOT, and
    the result's latency fields agree with the trace's."""
    cfg, params = setup
    obs = Obs()
    kw = (dict(max_batch=2) if engine_cls is Engine
          else dict(max_slots=2, page_size=8))
    eng = engine_cls(cfg, params, max_seq=32, precompute=False, obs=obs,
                     **kw)
    out = eng.generate(_reqs(4))
    traces = {tr.order: tr for tr in obs.traces.completed}
    assert len(traces) == 4 and not obs.traces.active
    for tr in traces.values():
        tr.validate()                      # idempotent: already validated
        assert tr.decode_len == 5
        assert tr.ttft_s > 0 and tr.tpot_s > 0
        assert tr.decode_len == sum(n for _, n in tr.chunks) + 1
    if engine_cls is ContinuousEngine:     # results derive FROM the traces
        by_id = {tr.id: tr for tr in traces.values()}
        for r in out:
            assert r["latency_s"] == pytest.approx(
                by_id[r["id"]].latency_s)
    st = eng.stats()
    assert st["requests"] == 4 and st["tokens"] == 20
    assert obs.registry.histogram("trace.ttft_s").count == 4


@pytest.mark.parametrize("engine_cls", [Engine, ContinuousEngine],
                         ids=["batch", "continuous"])
def test_engine_disabled_obs_keeps_stats(setup, engine_cls):
    """enabled=False: no traces/histograms, but stats() (registry counters)
    still work — the zero-overhead telemetry contract."""
    cfg, params = setup
    obs = Obs(enabled=False)
    kw = (dict(max_batch=2) if engine_cls is Engine
          else dict(max_slots=2, page_size=8))
    eng = engine_cls(cfg, params, max_seq=32, precompute=False, obs=obs,
                     **kw)
    eng.generate(_reqs(3))
    assert not obs.traces.completed and not obs.traces.active
    assert obs.registry.histogram("trace.ttft_s").count == 0
    st = eng.stats()
    assert st["requests"] == 3 and st["tokens"] == 15
    assert st["tokens_per_s"] > 0


def test_engine_stats_schema_unified(setup):
    """Both engines expose the ENGINE_COUNTERS schema plus their legacy
    alias (docs/observability.md)."""
    from repro.serve.engine import ENGINE_COUNTERS
    cfg, params = setup
    b = Engine(cfg, params, max_batch=2, max_seq=32, precompute=False)
    c = ContinuousEngine(cfg, params, max_slots=2, max_seq=32, page_size=8,
                         precompute=False)
    b.generate(_reqs(2))
    c.generate(_reqs(2))
    sb, sc = b.stats(), c.stats()
    for k in ENGINE_COUNTERS + ("prompt_pad_waste", "tokens_per_s",
                                "engine"):
        assert k in sb and k in sc, k
    assert sb["engine"] == "batch" and sc["engine"] == "continuous"
    assert sb["batches"] == sb["dispatches"]           # legacy aliases
    assert sc["decode_dispatches"] == sc["dispatches"]
    assert sc["scale_growths"] == 0                    # f32 pool: no quant


def test_continuous_emitter_end_to_end(setup, tmp_path):
    """ContinuousEngine + emitter: schema-valid JSONL with gauge series."""
    cfg, params = setup
    path = str(tmp_path / "serve.jsonl")
    obs = Obs(emit_path=path, emit_every=1)
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32,
                           page_size=8, precompute=False, obs=obs)
    eng.generate(_reqs(4))
    obs.close()
    counts = validate_jsonl(path)
    assert counts["trace"] == 4 and counts["snapshot"] >= 2
    snaps = [json.loads(l) for l in open(path)
             if json.loads(l)["type"] == "snapshot"]
    assert "sched.queue_depth" in snaps[-1]["gauges"]
    assert "pool.free_pages" in snaps[-1]["gauges"]
    assert snaps[-1]["histograms"]["trace.ttft_s"]["count"] == 4


# -- label-scoped views (the fleet's metrics-isolation seam) ---------------

def test_scoped_registry_labels_and_nesting():
    """Scoped views inject their labels into every metric identity, nest
    by merging, and never create unlabeled twins."""
    reg = Registry()
    r0 = reg.scoped(replica="r0")
    r0.counter("sched.submitted").inc(3)
    reg.scoped(replica="r1").counter("sched.submitted").inc(5)
    assert reg.value("sched.submitted", replica="r0") == 3
    assert reg.value("sched.submitted", replica="r1") == 5
    with pytest.raises(KeyError):           # no unlabeled bleed-through
        reg.value("sched.submitted")
    nested = r0.scoped(shard="s2")
    nested.gauge("pool.free_pages").set(7)
    assert reg.value("pool.free_pages", replica="r0", shard="s2") == 7
    # same (name, labels) through base or view is the same object
    assert reg.counter("sched.submitted", replica="r0") is \
        r0.counter("sched.submitted")


def test_scoped_registry_call_site_wins_on_collision():
    """A call-site label overrides the scope's fixed label of the same
    key — scoped producers can still re-attribute deliberately."""
    reg = Registry()
    view = reg.scoped(replica="r0")
    view.counter("fleet.handoffs", replica="r9").inc()
    assert reg.value("fleet.handoffs", replica="r9") == 1
    with pytest.raises(KeyError):
        reg.value("fleet.handoffs", replica="r0")


def test_scoped_obs_shares_clock_traces_and_emitter(tmp_path):
    """Obs.scoped: shared clock/trace store/emitter; view.close() only
    flushes, the owning Obs closes the shared emitter exactly once."""
    path = str(tmp_path / "fleet.jsonl")
    root = Obs(emit_path=path, emit_every=1)
    v0, v1 = root.scoped(replica="r0"), root.scoped(replica="r1")
    assert v0.emitter is root.emitter and v1.emitter is root.emitter
    assert abs(v0.now() - root.now()) < 0.05        # one clock
    t0 = v0.trace_start(id=0, order=0, prompt_len=4, enqueue_s=v0.now())
    t1 = v1.trace_start(id=0, order=0, prompt_len=4, enqueue_s=v1.now())
    assert t0.replica == "r0" and t1.replica == "r1"
    # (replica, order) keying: same local order, distinct active entries
    assert root.traces.get(0, replica="r0") is t0
    assert root.traces.get(0, replica="r1") is t1
    for tr, v in ((t0, v0), (t1, v1)):
        tr.mark_admit(v.now())
        tr.mark_first_token(v.now())
        tr.status = "FINISHED_EOS"
        tr.mark_retire(v.now())
        v.trace_finish(tr)
    v0.close()                              # flush only — emitter stays open
    assert root.emitter is not None and not root.emitter._closed
    v1.close()
    root.close()
    root.close()                            # owning close is idempotent
    counts = validate_jsonl(path)
    assert counts["trace"] == 2
    lines = [json.loads(l) for l in open(path)]
    assert {t["replica"] for t in lines if t["type"] == "trace"} == \
        {"r0", "r1"}


# ---------------------------------------------------------------------------
# Gauge high/low-water marks on the Prometheus path (obs/metrics.py)
# ---------------------------------------------------------------------------
def test_prometheus_gauge_marks_exact_lines():
    """max_seen/min_seen export as `_max`/`_min` companion series — a
    scrape only sees point-in-time gauges, so the low-water mark of
    pool.free_pages would otherwise be lost.  Exact-line assertions: the
    format is the contract."""
    reg = Registry()
    g = reg.gauge("pool.free_pages", pool="kv")
    for v in (7.0, 2.0, 5.0):
        g.set(v)
    lines = reg.to_prometheus().splitlines()
    assert 'pool_free_pages{pool="kv"} 5.0' in lines
    assert "# TYPE pool_free_pages_max gauge" in lines
    assert 'pool_free_pages_max{pool="kv"} 7.0' in lines
    assert "# TYPE pool_free_pages_min gauge" in lines
    assert 'pool_free_pages_min{pool="kv"} 2.0' in lines


def test_prometheus_gauge_marks_skip_unset_min():
    """min_seen is None until the first set (an unset gauge never claims
    'saw zero headroom'): _max exports (init 0.0), _min must NOT."""
    reg = Registry()
    reg.gauge("sched.queue_depth")         # registered, never set
    lines = reg.to_prometheus().splitlines()
    assert "sched_queue_depth_max 0.0" in lines
    assert not any(l.startswith("sched_queue_depth_min") for l in lines)
    # snapshot carries the same marks the renderer consumed
    marks = reg.snapshot()["gauge_marks"]["sched.queue_depth"]
    assert marks == {"max": 0.0, "min": None}


# ---------------------------------------------------------------------------
# Alert records in the emitter schema (obs/emit.py + obs/slo.py)
# ---------------------------------------------------------------------------
def test_emitter_appends_watchdog_alerts(tmp_path):
    """An Emitter with a bound watchdog evaluates every snapshot it
    writes and appends fired alert lines right behind it; validate_jsonl
    counts all three record types."""
    from repro.obs.slo import Rule, SloWatchdog
    path = str(tmp_path / "alerts.jsonl")
    reg, traces = Registry(), TraceStore()
    wd = SloWatchdog([Rule("drift", metric="health.logit_drift",
                           kind="gauge", op=">", threshold=10.0,
                           windows=((1, 1.0),))])
    em = Emitter(reg, traces, path=path, every=1, watchdog=wd)
    g = reg.gauge("health.logit_drift")
    g.set(1.0)
    em.tick()                              # healthy: snapshot only
    g.set(99.0)
    em.tick()                              # breach: snapshot + alert
    em.close()
    counts = validate_jsonl(path)
    assert counts["alert"] == 1 and counts["snapshot"] >= 2
    lines = [json.loads(l) for l in open(path)]
    kinds = [l["type"] for l in lines]
    # the alert rides immediately behind the snapshot that fired it
    i = kinds.index("alert")
    assert kinds[i - 1] == "snapshot" and lines[i - 1]["seq"] == \
        lines[i]["seq"]
    alert = lines[i]
    validate_line(alert)
    assert alert["rule"] == "drift" and alert["value"] == 99.0
