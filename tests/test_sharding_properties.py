"""Property tests for the sharding rule engine's invariants.

Three invariants hold for EVERY derived spec, whatever the path/shape/mesh:

  I1  every axis in a spec exists on the mesh, and is used at most once;
  I2  divisibility — each sharded dim is divisible by the product of the
      sizes of the axes on it (GSPMD would otherwise pad or error);
  I3  RULE ZERO — a contraction dim never carries a data-parallel axis.

A deterministic randomized sweep (numpy PRNG) always runs, so the invariants
are exercised even where hypothesis is absent; with hypothesis installed the
same properties run again under its shrinking search.
"""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as sh

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


class FakeMesh:
    def __init__(self, shape, names):
        self.devices = np.zeros(shape)
        self.axis_names = names


MESHES = [
    FakeMesh((16, 16), ("data", "model")),
    FakeMesh((2, 16, 16), ("pod", "data", "model")),
    FakeMesh((2, 4), ("data", "model")),
    FakeMesh((3, 5), ("data", "model")),
    FakeMesh((4, 2, 8), ("pod", "data", "model")),
    FakeMesh((1, 1), ("data", "model")),
]

# (path template, core rank, contraction dims relative to the core).
# Mirrors docs/sharding.md: dense contracts n_in (dim 0), circulant contracts
# the input-block dim q (dim 1), experts contract inside the (E, ...) stack.
PARAM_KINDS = [
    (("attn", "q", "w"), 2, (0,)),
    (("attn", "o", "w"), 2, (0,)),
    (("mlp", "up", "wc"), 3, (1,)),
    (("mlp", "down", "wc"), 3, (1,)),
    (("segments", "0", "attn", "k", "w"), 2, (0,)),
    (("segments", "0", "mlp", "gate", "wc"), 3, (1,)),
    (("segments", "0", "moe", "experts", "up"), 4, (2,)),
    (("segments", "0", "moe", "experts", "down"), 4, (2,)),
    (("segments", "0", "moe", "experts", "up"), 3, (1,)),
    (("embed", "table"), 2, ()),
    (("ln1", "scale"), 1, ()),
    (("pos",), 2, ()),
]

_DIM_POOL = (1, 2, 3, 4, 5, 8, 10, 16, 30, 32, 44, 64, 112, 128,
             160, 256, 1000, 4050, 4096)


def _axes_of(entry):
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


def check_param_invariants(path, shape, mesh, strategy, contraction,
                           core_rank):
    """Assert I1-I3 for one derived spec.  ``contraction`` dims are relative
    to the core — the trailing ``core_rank`` dims after any stacked leading
    dim.  A spec shorter than the shape replicates the remaining dims, which
    satisfies every invariant trivially.
    """
    spec = sh.param_spec(path, shape, mesh, strategy)
    sizes = sh.axis_sizes(mesh)
    assert len(spec) <= len(shape), (spec, shape)
    used = []
    for dim, entry in enumerate(spec):          # specs are left-aligned
        axes = _axes_of(entry)
        used.extend(axes)
        for a in axes:
            assert a in sizes, f"{a} not a mesh axis ({path}, {shape})"
        prod = int(np.prod([sizes[a] for a in axes])) if axes else 1
        assert shape[dim] % prod == 0, (path, shape, spec, dim)        # I2
    assert len(used) == len(set(used)), (path, shape, spec)            # I1
    for cdim in contraction:                                           # I3
        spec_idx = len(shape) - core_rank + cdim
        if 0 <= spec_idx < len(spec):
            for a in _axes_of(spec[spec_idx]):
                assert a not in sh.DP_AXES, \
                    f"RULE ZERO violated: {path} {shape} -> {spec}"
    if strategy == "tokenpar":
        assert sh.MODEL_AXIS not in used, (path, shape, spec)
    return spec


def _random_case(rng):
    tmpl, core_rank, contraction = PARAM_KINDS[rng.randint(len(PARAM_KINDS))]
    n_stack = 1 if "segments" in tmpl else 0
    shape = tuple(int(_DIM_POOL[rng.randint(len(_DIM_POOL))])
                  for _ in range(n_stack + core_rank))
    mesh = MESHES[rng.randint(len(MESHES))]
    strategy = ("2d", "megatron", "tokenpar")[rng.randint(3)]
    return tmpl, shape, mesh, strategy, contraction, core_rank


def test_param_spec_invariants_randomized_sweep():
    rng = np.random.RandomState(0)
    for _ in range(2000):
        path, shape, mesh, strategy, contraction, core_rank = _random_case(rng)
        check_param_invariants(path, shape, mesh, strategy, contraction,
                               core_rank)


def test_batch_and_cache_spec_invariants_randomized_sweep():
    rng = np.random.RandomState(1)
    for _ in range(1000):
        mesh = MESHES[rng.randint(len(MESHES))]
        sizes = sh.axis_sizes(mesh)
        nd = rng.randint(2, 6)
        shape = tuple(int(_DIM_POOL[rng.randint(len(_DIM_POOL))])
                      for _ in range(nd))
        spec = sh.batch_spec(shape, mesh, shape[0],
                             seq_shard=bool(rng.randint(2)))
        assert len(spec) == len(shape)
        for dim, entry in enumerate(spec):
            axes = _axes_of(entry)
            prod = int(np.prod([sizes[a] for a in axes])) if axes else 1
            assert shape[dim] % prod == 0, (shape, spec)
        # cache: ints always replicate; float specs obey divisibility
        assert sh.cache_spec(("pos",), shape, np.int32, mesh, shape[0]) == P()
        cspec = sh.cache_spec(("k",), (2,) + shape, np.float32, mesh, shape[0])
        for dim, entry in enumerate(cspec):
            axes = _axes_of(entry)
            prod = int(np.prod([sizes[a] for a in axes])) if axes else 1
            assert ((2,) + shape)[dim] % prod == 0, (shape, cspec)


def test_unknown_strategy_rejected():
    with pytest.raises(ValueError):
        sh.param_spec(("attn", "q", "w"), (8, 8), MESHES[0], "diagonal")


if HAVE_HYPOTHESIS:
    dims = st.sampled_from(_DIM_POOL)

    @settings(max_examples=300, deadline=None)
    @given(st.integers(0, len(PARAM_KINDS) - 1),
           st.lists(dims, min_size=5, max_size=5),
           st.integers(0, len(MESHES) - 1),
           st.sampled_from(["2d", "megatron", "tokenpar"]))
    def test_param_spec_invariants_hypothesis(kind_i, dim_list, mesh_i,
                                              strategy):
        tmpl, core_rank, contraction = PARAM_KINDS[kind_i]
        n_stack = 1 if "segments" in tmpl else 0
        shape = tuple(dim_list[:n_stack + core_rank])
        check_param_invariants(tmpl, shape, MESHES[mesh_i], strategy,
                               contraction, core_rank)

    @settings(max_examples=200, deadline=None)
    @given(st.lists(dims, min_size=2, max_size=5), st.integers(0, len(MESHES) - 1),
           st.booleans())
    def test_batch_spec_invariants_hypothesis(dim_list, mesh_i, seq_shard):
        mesh = MESHES[mesh_i]
        sizes = sh.axis_sizes(mesh)
        shape = tuple(dim_list)
        spec = sh.batch_spec(shape, mesh, shape[0], seq_shard=seq_shard)
        assert len(spec) == len(shape)
        for dim, entry in enumerate(spec):
            axes = _axes_of(entry)
            prod = int(np.prod([sizes[a] for a in axes])) if axes else 1
            assert shape[dim] % prod == 0, (shape, spec)
