"""MoE routing semantics, block-circulant CONV (paper's CONV generalization),
and variational-inference Bayesian training (paper co-optimization leg 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import bayesian, circulant as cc, conv as ccv
from repro.layers import ffn


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def _moe_setup(E=4, topk=2, d=16, dff=32, cf=8.0, bc=0):
    moe_cfg = MoEConfig(num_experts=E, top_k=topk, capacity_factor=cf,
                        router_group_size=32)
    comp = None
    if bc:
        from repro.configs.base import CompressionConfig
        comp = CompressionConfig(enabled=True, block_expert=bc)
    params = ffn.init_moe(jax.random.PRNGKey(0), d, dff, moe_cfg, comp)
    return params, moe_cfg, comp


def test_moe_output_shape_and_aux():
    params, moe_cfg, _ = _moe_setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    out, aux = ffn.moe(params, x, d_ff=32, moe_cfg=moe_cfg)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3     # Switch aux lower bound E*(1/E)


def test_moe_single_expert_equals_mlp_structure():
    """With E=1, routing is trivial: every token hits the same expert."""
    params, moe_cfg, _ = _moe_setup(E=1, topk=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16))
    out, _ = ffn.moe(params, x, d_ff=32, moe_cfg=moe_cfg)
    e = params["experts"]
    up = x @ e["up"][0]
    gate = jax.nn.silu(x @ e["gate"][0])
    ref = (gate * up) @ e["down"][0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_moe_circulant_experts():
    params, moe_cfg, comp = _moe_setup(bc=8)
    assert params["experts"]["up"].ndim == 4     # (E, p, q, k)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    out, _ = ffn.moe(params, x, d_ff=32, moe_cfg=moe_cfg, comp=comp)
    assert out.shape == x.shape
    assert not bool(jnp.isnan(out).any())


def test_moe_decode_dropless():
    """serve-mode single-token step never drops tokens (cap == group)."""
    params, moe_cfg, _ = _moe_setup(E=4, topk=1, cf=0.01)  # tiny capacity
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 1, 16))
    out_serve, _ = ffn.moe(params, x, d_ff=32, moe_cfg=moe_cfg, mode="serve")
    # every token got its expert output (no zeroed rows)
    norms = jnp.linalg.norm(out_serve.reshape(8, -1), axis=-1)
    assert bool((norms > 1e-6).all())


def test_moe_grad_flows_to_router():
    params, moe_cfg, _ = _moe_setup()
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 16))

    def loss(p):
        out, aux = ffn.moe(p, x, d_ff=32, moe_cfg=moe_cfg)
        return jnp.sum(out ** 2) + 0.01 * aux
    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).sum()) > 0.0


# ---------------------------------------------------------------------------
# CONV layers (paper: block-circulant F(r,r,C,P) via im2col)
# ---------------------------------------------------------------------------
def test_im2col_matches_dense_conv():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 3))
    f = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 5))
    ref = ccv.conv2d_dense(x, f)
    cols = ccv.im2col(x, 3)
    flat = f.reshape(9, 3, 5).reshape(27, 5)   # (r*r, C, P) -> (r²C, P)
    out = cols @ flat
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_conv_circulant_equals_materialized():
    """Circulant conv == dense conv with the materialized circulant filter —
    the paper's claim that im2col'd F is block-circulant."""
    r, C, P, k = 3, 4, 8, 4
    w = ccv.init_conv_circulant(jax.random.PRNGKey(0), r, C, P, k)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 6, C))
    out = ccv.conv2d_block_circulant(x, w, r, P)
    dense_F = cc.materialize_dense(w, cc.num_blocks(P, k) * k,
                                   cc.num_blocks(r * r * C, k) * k)
    dense_F = dense_F[:P, :r * r * C].T        # (r²C, P)
    f = dense_F.reshape(r * r, C, P).reshape(r, r, C, P)
    ref = ccv.conv2d_dense(x, f)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_conv_training_step():
    r, C, P, k = 3, 2, 4, 4
    w = ccv.init_conv_circulant(jax.random.PRNGKey(0), r, C, P, k)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 5, 5, C))

    def loss(w):
        return jnp.sum(ccv.conv2d_block_circulant(x, w, r, P) ** 2)
    g = jax.grad(loss)(w)
    assert g.shape == w.shape
    assert float(jnp.abs(g).sum()) > 0


# ---------------------------------------------------------------------------
# Bayesian (variational inference) training
# ---------------------------------------------------------------------------
def test_bayesian_wrap_sample_mean():
    params = {"a": jnp.ones((4, 4)), "nest": {"b": jnp.zeros((3,))}}
    bp = bayesian.init_bayesian(params)
    w = bayesian.sample(jax.random.PRNGKey(0), bp)
    assert w["a"].shape == (4, 4)
    mean = bayesian.posterior_mean(bp)
    np.testing.assert_array_equal(np.asarray(mean["a"]),
                                  np.asarray(params["a"]))
    # sigma = softplus(-5) ~ 0.0067: samples close to mean but not equal
    assert 0 < float(jnp.abs(w["a"] - params["a"]).max()) < 0.1


def test_kl_positive_and_zero_at_prior():
    params = {"a": jnp.zeros((8,))}
    bp = bayesian.init_bayesian(params, init_rho=jnp.log(jnp.expm1(1.0)))
    kl = bayesian.kl_to_prior(bp, prior_sigma=1.0)
    assert float(kl) == pytest.approx(0.0, abs=1e-5)
    bp2 = bayesian.init_bayesian({"a": 3.0 * jnp.ones((8,))})
    assert float(bayesian.kl_to_prior(bp2)) > 0


def test_elbo_loss_runs():
    params = {"w": jnp.ones((4,))}
    bp = bayesian.init_bayesian(params)
    loss, w = bayesian.elbo_loss(
        jax.random.PRNGKey(0), bp, lambda p: jnp.sum(p["w"] ** 2),
        num_examples=100)
    assert jnp.isfinite(loss)
