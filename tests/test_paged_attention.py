"""Fused paged flash-decode attention: the streamed online-softmax paths
(off-scan and interpret-mode Pallas kernel) against the gather-then-attend
oracle, over random pools, unaligned lengths, idle (trash-page) slots, and
GQA ratios — plus the engine-level stream/gather token identity and the
decode head-sharding spec.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_smoke_config
from repro.dist import sharding as sh
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models.registry import build_model
from repro.serve.engine import ContinuousEngine, Engine, Request

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Oracle: paged_gather + attention_ref per slot (independent of the scan)
# ---------------------------------------------------------------------------
def _pool_case(rng, *, num_pages, page, Hkv, G, D, positions, softcap=0.0):
    """Build a random pool + per-slot tables for the given positions (-1 =
    idle slot); owned pages are distinct, unowned entries hold trash 0."""
    B = len(positions)
    Hq = Hkv * G
    maxp = max([p // page + 1 for p in positions if p >= 0], default=1)
    pool_k = rng.randn(num_pages, page, Hkv, D).astype(np.float32)
    pool_v = rng.randn(num_pages, page, Hkv, D).astype(np.float32)
    free = list(range(1, num_pages))
    rng.shuffle(free)
    table = np.zeros((B, maxp), np.int32)
    for b, pos in enumerate(positions):
        need = 0 if pos < 0 else pos // page + 1
        for j in range(need):
            table[b, j] = free.pop()
    q = rng.randn(B, Hq, D).astype(np.float32)
    return (jnp.asarray(q), jnp.asarray(pool_k), jnp.asarray(pool_v),
            jnp.asarray(table), jnp.asarray(np.asarray(positions, np.int32)),
            softcap)


def _oracle(q, pool_k, pool_v, table, positions, softcap):
    """Gathered view + attention_ref, one slot at a time."""
    gk = np.asarray(kops.paged_gather(pool_k, table, mode="off"))
    gv = np.asarray(kops.paged_gather(pool_v, table, mode="off"))
    B, Hq, D = q.shape
    out = np.zeros((B, Hq, D), np.float32)
    for b in range(int(B)):
        L = int(positions[b]) + 1
        if L <= 0:
            continue                         # idle slot: all-masked -> zero
        out[b] = np.asarray(kref.attention_ref(
            q[b:b + 1, :, None],
            jnp.asarray(gk[b:b + 1, :L].transpose(0, 2, 1, 3)),
            jnp.asarray(gv[b:b + 1, :L].transpose(0, 2, 1, 3)),
            causal=True, softcap=softcap, kv_offset=L - 1))[0, :, 0]
    return out


def _check(case, tol=2e-5):
    q, pool_k, pool_v, table, positions, softcap = case
    want = _oracle(q, pool_k, pool_v, table, positions, softcap)
    off = kops.paged_attention(q, pool_k, pool_v, table, positions,
                               softcap=softcap, mode="off")
    interp = kops.paged_attention(q, pool_k, pool_v, table, positions,
                                  softcap=softcap, mode="interpret")
    np.testing.assert_allclose(np.asarray(off), want, rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(interp), want, rtol=tol, atol=tol)
    # idle slots are exactly zero in every lowering
    for b, pos in enumerate(np.asarray(positions)):
        if pos < 0:
            assert not np.asarray(off)[b].any()
            assert not np.asarray(interp)[b].any()


# ---------------------------------------------------------------------------
# Deterministic sweep (always runs): GQA ratios, unaligned lengths, idle
# slots, partial last pages, softcap
# ---------------------------------------------------------------------------
CASES = [
    dict(page=4, Hkv=2, G=2, D=8, positions=[5, -1, 15]),     # mixed + idle
    dict(page=8, Hkv=1, G=4, D=16, positions=[0, 7, 8]),      # MQA, edges
    dict(page=4, Hkv=4, G=1, D=8, positions=[3, 3, 2, 11]),   # MHA, dup len
    dict(page=16, Hkv=2, G=4, D=4, positions=[30, 1]),        # big page
    dict(page=4, Hkv=2, G=2, D=8, positions=[-1, -1]),        # all idle
    dict(page=4, Hkv=2, G=3, D=8, positions=[9, 2], softcap=20.0),
    # table wider than the scan's BLOCK_PAGES: multi-block while_loop with
    # a non-block-aligned maxp (exercises the table-padding branch)
    dict(page=4, Hkv=2, G=2, D=8, positions=[27, 5]),         # maxp=7
    dict(page=2, Hkv=1, G=2, D=4, positions=[19, -1]),        # maxp=10
]


@pytest.mark.parametrize("case", CASES)
def test_streamed_matches_gather_oracle(case):
    rng = np.random.RandomState(0)
    kw = dict(case)
    positions = kw.pop("positions")
    need = sum(p // kw["page"] + 1 for p in positions if p >= 0) + 1
    _check(_pool_case(rng, num_pages=need + 2, positions=positions, **kw))


def test_dispatch_env_default(monkeypatch):
    """REPRO_KERNELS drives the dispatch like every other kernel."""
    rng = np.random.RandomState(1)
    case = _pool_case(rng, num_pages=6, page=4, Hkv=2, G=2, D=8,
                      positions=[5, 9])
    q, pk, pv, tab, pos, _ = case
    monkeypatch.setenv("REPRO_KERNELS", "off")
    off = kops.paged_attention(q, pk, pv, tab, pos)
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    interp = kops.paged_attention(q, pk, pv, tab, pos)
    np.testing.assert_allclose(np.asarray(off), np.asarray(interp),
                               rtol=2e-5, atol=2e-5)


def test_output_dtype_follows_query():
    rng = np.random.RandomState(2)
    q, pk, pv, tab, pos, _ = _pool_case(rng, num_pages=6, page=4, Hkv=2,
                                        G=2, D=8, positions=[5, 9])
    out = kops.paged_attention(q.astype(jnp.bfloat16), pk, pv, tab, pos,
                               mode="off")
    assert out.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Hypothesis property sweep (when available; deterministic sweep above is
# the container fallback)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.data())
    def test_streamed_property_sweep(data):
        page = data.draw(st.sampled_from([2, 4, 8]), label="page")
        Hkv = data.draw(st.sampled_from([1, 2, 4]), label="Hkv")
        G = data.draw(st.sampled_from([1, 2, 4]), label="G")
        D = data.draw(st.sampled_from([4, 8]), label="D")
        B = data.draw(st.integers(1, 4), label="B")
        positions = [
            data.draw(st.one_of(st.just(-1), st.integers(0, 8 * page - 1)),
                      label=f"pos{b}") for b in range(B)]
        softcap = data.draw(st.sampled_from([0.0, 30.0]), label="softcap")
        need = sum(p // page + 1 for p in positions if p >= 0) + 1
        seed = data.draw(st.integers(0, 2 ** 16), label="seed")
        rng = np.random.RandomState(seed)
        _check(_pool_case(rng, num_pages=need + 2, page=page, Hkv=Hkv, G=G,
                          D=D, positions=positions, softcap=softcap))


# ---------------------------------------------------------------------------
# Engine level: stream vs gather token identity + telemetry
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_smoke_config("tinyllama-1.1b").replace(dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _reqs(specs, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(prompt=rng.randint(1, 500, size=s).astype(np.int32),
                    max_new_tokens=n, id=i)
            for i, (s, n) in enumerate(specs)]


def test_engine_stream_matches_gather_and_oracle(tiny_setup):
    """The new default (stream) and the legacy gather path emit identical
    greedy tokens — both equal to the B=1 batch-engine oracle — including
    slot recycling over more requests than slots."""
    cfg, params = tiny_setup
    reqs = _reqs([(20, 13), (12, 21), (16, 17), (9, 10)])
    oracle = Engine(cfg, params, max_batch=1, max_seq=32)
    want = [oracle.generate([r])[0]["tokens"] for r in reqs]
    kw = dict(max_slots=2, max_seq=32, page_size=4, decode_chunk=5)
    stream = ContinuousEngine(cfg, params, **kw)
    gather = ContinuousEngine(cfg, params, paged_attn="gather", **kw)
    assert [g["tokens"] for g in stream.generate(reqs)] == want
    assert [g["tokens"] for g in gather.generate(reqs)] == want


def test_engine_interpret_mode_matches_oracle(tiny_setup, monkeypatch):
    """REPRO_KERNELS=interpret runs the Pallas flash-decode kernel inside
    the real decode loop (slot recycling included) and still emits the
    oracle's greedy tokens."""
    cfg, params = tiny_setup
    reqs = _reqs([(20, 13), (12, 21), (16, 17)])
    oracle = Engine(cfg, params, max_batch=1, max_seq=32)
    want = [oracle.generate([r])[0]["tokens"] for r in reqs]
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32,
                           page_size=4, decode_chunk=5)
    assert [g["tokens"] for g in eng.generate(reqs)] == want


def test_engine_memory_telemetry_and_budget_default(tiny_setup):
    """Streamed decode raises the default admission budget to the slot
    ceiling and reports the attention-memory estimates; the gather oracle
    keeps a conservative budget and a maxp*page-times-wider peak."""
    cfg, params = tiny_setup
    kw = dict(max_slots=2, max_seq=32, page_size=4)
    stream = ContinuousEngine(cfg, params, **kw)
    gather = ContinuousEngine(cfg, params, paged_attn="gather", **kw)
    assert stream.scheduler.max_tokens_in_flight == 2 * 33
    assert gather.scheduler.max_tokens_in_flight == 33
    st_s, st_g = stream.stats(), gather.stats()
    assert st_s["attention_impl"] == "stream"
    assert st_g["attention_impl"] == "gather"
    # gather pays 3x the per-token traffic; its peak buffer spans the full
    # maxp*page reservation vs the scan's BLOCK_PAGES-page working set
    from repro.kernels.paged_attention import BLOCK_PAGES
    assert st_g["attention_bytes_per_token"] == \
        3 * st_s["attention_bytes_per_token"]
    bp = min(BLOCK_PAGES, stream.max_pages_per_slot)
    assert st_g["peak_attention_bytes"] * bp == \
        stream.max_pages_per_slot * st_s["peak_attention_bytes"]
    assert st_s["decode_peak_bytes_est"] == \
        st_s["pool_bytes"] + st_s["peak_attention_bytes"]


# ---------------------------------------------------------------------------
# Sharding: the streamed op's q/out head spec mirrors the pool's placement
# ---------------------------------------------------------------------------
class FakeMesh:
    def __init__(self, shape, names):
        self.devices = np.zeros(shape)
        self.axis_names = names


def test_decode_head_spec():
    mesh = FakeMesh((4, 8), ("data", "model"))
    # slots over DP, heads over model
    assert sh.decode_head_spec((8, 16, 64), mesh) == \
        P(("data",), "model", None)
    # GQA fallback: too few heads -> head_dim carries "model"
    assert sh.decode_head_spec((8, 2, 64), mesh) == \
        P(("data",), None, "model")
    # indivisible everywhere -> replicate (never wrong)
    assert sh.decode_head_spec((3, 2, 3), mesh) == P(None, None, None)
    # head placement agrees with the pool leaf it contracts against
    pool = sh.page_pool_spec((128, 16, 16, 64), mesh)
    q = sh.decode_head_spec((8, 16, 64), mesh)
    assert pool[-2] == q[1] == "model"
