"""Fused three-phase block-circulant Pallas kernel vs the pure-jnp oracle,
swept over shapes/dtypes (interpret mode), plus the REPRO_KERNELS dispatch
through kernels/ops.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import circulant as cc
from repro.kernels import bc_fused
from repro.kernels import ops as kops


@pytest.mark.parametrize("n_in,n_out,k,B", [
    (64, 64, 16, 4), (128, 64, 32, 8), (48, 80, 16, 3), (256, 128, 64, 2),
])
def test_fused_kernel_matches_oracle(n_in, n_out, k, B):
    w = cc.init_block_circulant(jax.random.PRNGKey(0), n_in, n_out, k)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, n_in))
    ref = cc.bc_matmul_direct(x, w, n_out)
    out = bc_fused.bc_linear_fused_kernel(x, w, n_out, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_kernel_dtypes(dtype):
    w = cc.init_block_circulant(jax.random.PRNGKey(0), 64, 64, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 64), dtype)
    ref = cc.bc_matmul_fft(x, w, 64)
    out = bc_fused.bc_linear_fused_kernel(x, w, 64, interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_fused_kernel_grid_tiling():
    """Multiple grid steps on both axes (B and p tiling)."""
    w = cc.init_block_circulant(jax.random.PRNGKey(0), 64, 256, 16)  # p=16
    x = jax.random.normal(jax.random.PRNGKey(1), (9, 64))
    ref = cc.bc_matmul_direct(x, w, 256)
    out_tiled = bc_fused.bc_linear_fused_kernel(x, w, 256, interpret=True)
    np.testing.assert_allclose(np.asarray(out_tiled), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Dispatch policy: bc_linear_fused routes through ops.py like the other two
# kernels — 'off' lowers to the XLA cached-spectral path, 'interpret' runs
# the Pallas body, and the env var drives the default.
# ---------------------------------------------------------------------------
def test_ops_dispatch_off_matches_interpret():
    w = cc.init_block_circulant(jax.random.PRNGKey(0), 64, 96, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 64))
    ref = cc.bc_matmul_direct(x, w, 96)
    off = kops.bc_linear_fused(x, w, 96, mode="off")
    interp = kops.bc_linear_fused(x, w, 96, mode="interpret")
    np.testing.assert_allclose(np.asarray(off), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(interp), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_ops_dispatch_env_default(monkeypatch):
    w = cc.init_block_circulant(jax.random.PRNGKey(0), 32, 32, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 32))
    monkeypatch.setenv("REPRO_KERNELS", "off")
    assert kops.kernel_mode() == "off"
    out = kops.bc_linear_fused(x, w, 32)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(cc.bc_matmul_direct(x, w, 32)),
                               rtol=2e-3, atol=2e-3)
