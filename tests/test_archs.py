"""Per-architecture smoke tests (assignment §f).

Each assigned architecture is instantiated in a REDUCED config of the same
family and runs: one forward/train step, one prefill, and one decode step on
CPU, asserting output shapes and no NaNs.  Full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation) — see launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models.registry import build_model

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend == "audio_stub":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_seq, cfg.d_model))
    elif cfg.frontend == "vision_stub":
        batch["patches"] = jax.random.normal(
            ks[2], (B, cfg.num_patches, cfg.d_model))
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    cfg = get_smoke_config(request.param)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_full_config_matches_assignment(arch):
    """The FULL config carries the published numbers (spot checks)."""
    cfg_small, _, _ = arch
    cfg = get_config(cfg_small.name)
    expect = {
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }[cfg.name]
    got = (cfg.num_layers, cfg.d_model, cfg.attention.num_heads,
           cfg.attention.num_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == expect, f"{cfg.name}: {got} != {expect}"


def test_train_forward(arch):
    cfg, model, params = arch
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = model.forward_train(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab())
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


def test_prefill_then_decode(arch):
    cfg, model, params = arch
    batch = _batch(cfg, jax.random.PRNGKey(2))
    cache = model.init_cache(B, S + 4, dtype=jnp.float32)
    logits, cache = model.prefill(params, batch, cache)
    assert logits.shape[:2] == (B, S)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    logits2, cache = model.decode_step(params, tok, cache, jnp.int32(S))
    assert logits2.shape == (B, 1, cfg.padded_vocab())
    assert not bool(jnp.isnan(logits2.astype(jnp.float32)).any())


def test_decode_matches_full_forward(arch):
    """Teacher-forced decode equals the parallel forward (cache correctness)."""
    cfg, model, params = arch
    if cfg.attention.sliding_window and not cfg.is_encoder_decoder:
        win = cfg.attention.sliding_window
        if win < S:
            pytest.skip("ring-buffer prefill covered by dedicated SWA test")
    batch = _batch(cfg, jax.random.PRNGKey(3))
    full_logits, _ = model.forward_train(params, batch)

    n_pre = S - 4
    pre = {k: (v[:, :n_pre] if k in ("tokens", "labels") else v)
           for k, v in batch.items()}
    cache = model.init_cache(B, S, dtype=jnp.float32)
    logits, cache = model.prefill(params, pre, cache)
    outs = [logits[:, -1]]
    for t in range(n_pre, S - 1):
        lg, cache = model.decode_step(
            params, batch["tokens"][:, t:t + 1], cache, jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)                # logits for positions n_pre-1..S-2
    ref = full_logits[:, n_pre - 1:S - 1]
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)
