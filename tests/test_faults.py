"""Fault-injection harness: deterministic seeding, the chaos invariant
suite (every request exactly one terminal status, no page leaks, oracle
token identity for non-faulted requests), and the optimistic-admission
concurrency win over worst-case reservation."""
import numpy as np

from repro.serve import kvcache as kvc
from repro.serve.engine import Request
from repro.serve.faults import (FaultConfig, FaultInjector,
                                make_chaos_workload, run_chaos)
from repro.serve.scheduler import Scheduler


# ---------------------------------------------------------------------------
# Injector determinism
# ---------------------------------------------------------------------------
def test_fault_injector_deterministic():
    def roll(seed):
        inj = FaultInjector(FaultConfig(seed=seed, alloc_fail_p=0.3,
                                        dispatch_delay_p=0.3, corrupt_p=0.5))
        allocs = [inj.alloc_fault(2) for _ in range(50)]
        delays = [inj.dispatch_delay() for _ in range(50)]
        return allocs, delays, inj.stats()

    assert roll(3) == roll(3)
    assert roll(3) != roll(4)
    allocs, _, st = roll(3)
    assert st["alloc_failures"] == sum(allocs) > 0


def test_fault_injector_corrupts_each_request_once():
    inj = FaultInjector(FaultConfig(seed=0, corrupt_p=1.0))

    class _Slot:
        def __init__(self, rid):
            self.request = Request(prompt=np.array([1], np.int32),
                                   max_new_tokens=1, id=rid)

    s0, s1 = _Slot(0), _Slot(1)
    first = inj.pick_corruption([s0, s1])
    assert first in (s0, s1)
    assert inj.pick_corruption([first]) is None     # once per request id
    other = s1 if first is s0 else s0
    assert inj.pick_corruption([other]) is other
    assert sorted(inj.stats()["corrupted_ids"]) == [0, 1]


def test_chaos_workload_deterministic():
    reqs_a, arr_a = make_chaos_workload(12, vocab=500, seed=5)
    reqs_b, arr_b = make_chaos_workload(12, vocab=500, seed=5)
    assert len(reqs_a) == len(arr_a) == 12
    assert arr_a == arr_b and arr_a == sorted(arr_a)
    for ra, rb in zip(reqs_a, reqs_b):
        assert ra.id == rb.id and ra.max_new_tokens == rb.max_new_tokens
        assert ra.deadline_s == rb.deadline_s
        np.testing.assert_array_equal(ra.prompt, rb.prompt)


# ---------------------------------------------------------------------------
# Optimistic admission vs worst-case reservation (host-only virtual clock)
# ---------------------------------------------------------------------------
def _drive(admission, *, n=30, seed=0):
    """Oversubscribed Poisson traffic through a scheduler whose pool holds
    two worst-case requests; returns (mean concurrent slots per dispatch,
    terminal counts).  Decode is emulated (no device)."""
    rng = np.random.RandomState(seed)
    page, maxp, slots = 4, 8, 6
    num_pages = 17                          # 16 usable = 2 worst-case reqs
    table = kvc.BlockTable(kvc.PageAllocator(num_pages), slots, page, maxp)
    sched = Scheduler(table, max_seq=page * maxp,
                      max_tokens_in_flight=slots * (page * maxp + 1),
                      admission=admission, max_preemptions=1000)
    arrivals = np.cumsum(rng.exponential(0.05, size=n))
    for i, t in enumerate(arrivals):
        # 1-page prompt, 7-page worst case: optimism has room to win
        r = Request(prompt=np.arange(4, dtype=np.int32) + 1,
                    max_new_tokens=25, id=i)
        sched.submit(r, arrival_s=float(t))
    now, samples, guard = 0.0, [], 0
    while not sched.idle:
        guard += 1
        assert guard < 100_000, "virtual clock did not converge"
        now += 0.05
        admitted = sched.try_admit(now, arrived_before=now)
        assert not sched.drain_doomed()     # every request fits the pool
        for slot in admitted:
            slot.tokens.append(7)
        prep = sched.prepare_decode(2)
        assert not prep.stalled             # bound is effectively infinite
        samples.append(len(prep.runnable))
        for slot in prep.runnable:
            emit = min(2, slot.total_budget - len(slot.tokens))
            slot.tokens.extend([7] * emit)
            if len(slot.tokens) >= slot.total_budget:
                sched.retire(slot)
    assert table.allocator.in_use == 0
    conc = float(np.mean([s for s in samples if s > 0]))
    return conc, sched.terminal_counts()


def test_optimistic_sustains_more_concurrency_zero_lost():
    opt, opt_counts = _drive("optimistic")
    res, res_counts = _drive("reserve")
    # zero lost requests under either policy
    assert opt_counts["FINISHED_BUDGET"] == 30
    assert sum(opt_counts.values()) == 30
    assert res_counts["FINISHED_BUDGET"] == 30
    assert sum(res_counts.values()) == 30
    # the acceptance bar: >= 1.2x mean concurrent slots at equal pool size
    assert opt >= 1.2 * res, (opt, res)


# ---------------------------------------------------------------------------
# Chaos invariant suite (device-backed; CI runs 3 seeds via __main__)
# ---------------------------------------------------------------------------
def test_chaos_suite_smoke(tmp_path):
    out = str(tmp_path / "chaos.jsonl")
    summary = run_chaos(seed=0, requests=10, metrics_out=out, verbose=False)
    assert summary["requests"] == 10
    assert sum(summary["statuses"].values()) == 10


def test_fleet_chaos_smoke(tmp_path):
    from repro.serve.faults import run_fleet_chaos
    out = str(tmp_path / "fleet_chaos.jsonl")
    summary = run_fleet_chaos(seed=0, requests=10, metrics_out=out,
                              verbose=False)
    assert summary["requests"] == 10 and summary["replicas"] == 2
    assert sum(summary["statuses"].values()) == 10    # exactly-once, none lost
    assert summary["migrated"]                        # crash forced migration
    assert summary["migrated_finished"]
    assert summary["router"]["live_replicas"] == 1    # the victim stayed dead
