"""Training substrate: loss decreases, NaN guard, accumulation equivalence,
int8 moments, error-feedback gradient compression, Bayesian mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, AttentionConfig, CompressionConfig
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw, grad_compression, schedule
from repro.train import train_step as ts


@pytest.fixture(scope="module")
def tiny_cfg():
    return ArchConfig(
        name="tiny", num_layers=2, d_model=64, d_ff=128, vocab_size=256,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
        compression=CompressionConfig(enabled=True, block_ffn=16,
                                      block_attn=16),
        remat="none")


def _run(cfg, steps=12, **kw):
    opt = adamw.AdamWConfig(lr=3e-3, **kw.pop("opt", {}))
    state = ts.init_state(jax.random.PRNGKey(0), cfg, opt, **{
        k: kw[k] for k in ("compress_grads", "bayesian_mode") if k in kw})
    step = jax.jit(ts.make_train_step(cfg, opt, **kw), donate_argnums=(0,))
    data = SyntheticLM(cfg, batch=4, seq=32, seed=0)
    losses = []
    for i in range(steps):
        state, m = step(state, data(i))
        losses.append(float(m["loss"]))
    return state, losses


def test_loss_decreases(tiny_cfg):
    _, losses = _run(tiny_cfg, steps=15)
    assert losses[-1] < losses[0] - 0.1, losses
    assert np.isfinite(losses).all()


def test_loss_decreases_dense_baseline(tiny_cfg):
    cfg = tiny_cfg.replace(compression=CompressionConfig(enabled=False))
    _, losses = _run(cfg, steps=15)
    assert losses[-1] < losses[0] - 0.1, losses


def test_nan_guard_skips_bad_step(tiny_cfg):
    opt = adamw.AdamWConfig(lr=1e-3)
    state = ts.init_state(jax.random.PRNGKey(0), tiny_cfg, opt)
    step = jax.jit(ts.make_train_step(tiny_cfg, opt))
    data = SyntheticLM(tiny_cfg, batch=2, seq=16, seed=0)
    batch = data(0)
    params_before = jax.tree.map(lambda x: np.asarray(x), state["params"])
    bad = dict(batch)
    # poison the frontend-free path via labels out of range? use huge tokens
    # -> instead poison params is invasive; feed NaNs through a float input:
    state2, m = step(state, bad)
    # craft a genuinely NaN loss by scaling embed table to inf
    state_inf = dict(state2)
    state_inf["params"] = jax.tree.map(lambda x: x, state2["params"])
    inf_tab = state_inf["params"]["embed"]["table"] * jnp.inf
    state_inf["params"] = {**state_inf["params"],
                           "embed": {"table": inf_tab}}
    state3, m3 = step(state_inf, data(1))
    assert int(m3["ok"]) == 0
    assert int(state3["skipped"]) >= 1
    # params unchanged on the skipped step (still inf -> equal to input)
    assert bool(jnp.isinf(state3["params"]["embed"]["table"]).any())


def test_grad_accumulation_matches_full_batch(tiny_cfg):
    opt = adamw.AdamWConfig(lr=1e-3, grad_clip=0.0)
    data = SyntheticLM(tiny_cfg, batch=8, seq=16, seed=3)
    batch = data(0)
    s1 = ts.init_state(jax.random.PRNGKey(0), tiny_cfg, opt)
    s2 = jax.tree.map(lambda x: x, s1)
    step1 = jax.jit(ts.make_train_step(tiny_cfg, opt, accum=1))
    step4 = jax.jit(ts.make_train_step(tiny_cfg, opt, accum=4))
    s1, m1 = step1(s1, batch)
    s2, m4 = step4(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    l1 = jax.tree.leaves(s1["params"])
    l2 = jax.tree.leaves(s2["params"])
    for a, b in zip(l1, l2):
        # f32 summation-order noise through Adam's rsqrt where v ~ 0 gives a
        # few outliers; the update direction must match everywhere else
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-2, atol=3e-3)


def test_int8_moments_track_fp32(tiny_cfg):
    _, losses_q = _run(tiny_cfg, steps=12, opt={"quantize_moments": True})
    _, losses_f = _run(tiny_cfg, steps=12)
    assert losses_q[-1] < losses_q[0] - 0.05
    # quantized run stays within a loose band of the fp32 run
    assert abs(losses_q[-1] - losses_f[-1]) < 1.0


def test_grad_compression_error_feedback(tiny_cfg):
    _, losses = _run(tiny_cfg, steps=12, compress_grads=True)
    assert losses[-1] < losses[0] - 0.05


def test_grad_compression_unbiased_over_steps():
    """EF property: accumulated quantization error stays bounded."""
    g = {"w": jnp.linspace(-1, 1, 1024).reshape(32, 32)}
    ef = grad_compression.init_error_feedback(g)
    total_deq = jnp.zeros_like(g["w"])
    for i in range(16):
        deq, ef = grad_compression.compress_decompress(g, ef)
        total_deq = total_deq + deq["w"]
    np.testing.assert_allclose(np.asarray(total_deq) / 16,
                               np.asarray(g["w"]), atol=2e-3)


def test_bayesian_mode_trains(tiny_cfg):
    state, losses = _run(tiny_cfg, steps=10, bayesian_mode=True)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
    leaf = state["params"]["embed"]["table"]
    assert set(leaf.keys()) == {"mu", "rho"}


def test_schedule_shapes():
    s = schedule.warmup_cosine(jnp.arange(100), peak_lr=1e-3,
                               warmup_steps=10, total_steps=100)
    assert float(s[0]) == 0.0
    assert float(s[10]) == pytest.approx(1e-3, rel=1e-5)
    assert float(s[99]) < 3e-4


def test_trainer_emits_obs_telemetry(tiny_cfg, tmp_path):
    """Trainer rides repro.obs: train.* counters/gauges/histogram land in
    the registry and the JSONL snapshots validate (docs/observability.md
    'Training telemetry')."""
    from repro.data.pipeline import SyntheticLM
    from repro.obs import Obs, validate_jsonl
    from repro.train.trainer import Trainer
    path = str(tmp_path / "train.jsonl")
    obs = Obs(emit_path=path, emit_every=2)
    tr = Trainer(tiny_cfg, adamw.AdamWConfig(lr=3e-3),
                 workdir=str(tmp_path / "wd"),
                 data_fn=SyntheticLM(tiny_cfg, batch=4, seq=32, seed=0),
                 total_steps=5, ckpt_every=100, log_every=100, obs=obs)
    tr.run()
    obs.close()
    reg = obs.registry
    assert reg.value("train.steps") == 5
    assert reg.value("train.tokens") == 5 * 4 * 32
    assert reg.value("train.skipped_steps") == 0
    assert reg.histogram("train.step_s").count == 5
    assert reg.value("train.loss") > 0
    assert reg.value("train.tokens_per_s") > 0
    counts = validate_jsonl(path)
    assert counts["snapshot"] >= 2


def test_trainer_disabled_obs_keeps_step_counters(tiny_cfg, tmp_path):
    """enabled=False: the per-step fence and gauge folds are skipped (the
    async-dispatch pipeline stays intact) but steps/tokens counters — the
    stats() substrate — still advance."""
    from repro.data.pipeline import SyntheticLM
    from repro.obs import Obs
    from repro.train.trainer import Trainer
    obs = Obs(enabled=False)
    tr = Trainer(tiny_cfg, adamw.AdamWConfig(lr=3e-3),
                 workdir=str(tmp_path / "wd"),
                 data_fn=SyntheticLM(tiny_cfg, batch=4, seq=32, seed=0),
                 total_steps=3, ckpt_every=100, log_every=100, obs=obs)
    tr.run()
    reg = obs.registry
    assert reg.value("train.steps") == 3
    assert reg.value("train.tokens") == 3 * 4 * 32
    assert reg.histogram("train.step_s").count == 0
