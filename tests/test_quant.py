"""repro.quant: codec round-trip bounds (hypothesis-guarded), int4
packing, per-page-scale invariants of the scatter path, quantized
paged-attention off/interpret agreement on the dequantized values,
scale sharding rules, pool dtype plumbing, and engine-level greedy
parity of the int8 KV pool against the f32 oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_smoke_config
from repro.core import circulant as cc
from repro.dist import sharding
from repro.kernels import ops as kops
from repro.models.registry import build_model
from repro.quant import QuantPolicy, calibrate
from repro.quant import codec as qc
from repro.serve import kvcache as kvc
from repro.serve.engine import ContinuousEngine, Engine, Request
from repro.serve.params import precompute_serving_params

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Codec round trip
# ---------------------------------------------------------------------------
def _roundtrip(x: np.ndarray, qmax: float):
    xs = jnp.asarray(x)
    scale = qc.absmax_scale(xs, axes=-1, qmax=qmax)[..., None]
    q = qc.quantize(xs, scale, qmax)
    dq = qc.dequantize(q, scale)
    err = np.abs(x - np.asarray(dq))
    bound = np.asarray(scale) / 2 + 1e-7 * (np.abs(x) + 1)
    assert (err <= bound).all(), f"max err {err.max()} > scale/2"
    assert np.abs(np.asarray(q)).max() <= qmax


def test_roundtrip_bound_deterministic():
    rng = np.random.RandomState(0)
    for scale in (1e-3, 1.0, 37.0):
        _roundtrip(rng.randn(4, 33).astype(np.float32) * scale, 127.0)
        _roundtrip(rng.randn(4, 33).astype(np.float32) * scale, 7.0)


def test_zero_block_encodes_and_decodes_zero():
    x = jnp.zeros((2, 8))
    s = qc.absmax_scale(x, axes=-1)[..., None]
    assert (np.asarray(s) == 0).all()
    assert (np.asarray(qc.dequantize(qc.quantize(x, s), s)) == 0).all()


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 2 ** 16), st.integers(1, 40),
           st.floats(1e-4, 1e4), st.sampled_from([127.0, 7.0]))
    def test_roundtrip_bound_property(seed, n, scale, qmax):
        rng = np.random.RandomState(seed)
        _roundtrip(rng.randn(3, n).astype(np.float32) * scale, qmax)


def test_int4_pack_unpack_exact_inverse():
    rng = np.random.RandomState(1)
    for n in (1, 2, 5, 8, 33):
        q = jnp.asarray(rng.randint(-7, 8, size=(3, 4, n)).astype(np.int8))
        packed = qc.pack_int4(q)
        assert packed.dtype == jnp.uint8
        assert packed.shape[-1] == (n + 1) // 2
        assert (np.asarray(qc.unpack_int4(packed, n)) == np.asarray(q)).all()


# ---------------------------------------------------------------------------
# Per-page scale invariants (the decode scatter path)
# ---------------------------------------------------------------------------
def test_page_scatter_invariants():
    """Scales only grow, always cover the page's live content, written
    values round-trip within the codec bound (+ one half-step per scale
    growth for earlier residents), and untouched pages stay untouched."""
    rng = np.random.RandomState(0)
    page, H, D = 4, 2, 3
    pool = jnp.zeros((5, page, H, D), jnp.int8)
    scales = jnp.zeros((5, H), jnp.float32)
    pid = jnp.asarray([1, 3], jnp.int32)
    written = np.zeros((2, page, H, D), np.float32)
    grows = np.zeros((2, page, H), np.int32)     # growth events AFTER write
    prev = np.zeros((2, H), np.float32)
    for i in range(page):
        x = rng.randn(2, H, D).astype(np.float32) * (i + 1)   # forces growth
        pool, scales = qc.page_scatter(pool, scales, pid,
                                       jnp.asarray([i, i], jnp.int32),
                                       jnp.asarray(x))
        s = np.asarray(scales)[np.asarray(pid)]               # (2, H)
        assert (s >= prev - 1e-12).all(), "scale shrank"
        grows[:, :i] += (s > prev + 1e-12)[:, None, :]
        prev = s
        written[:, i] = x
        # scale covers everything currently resident
        content = np.abs(written[:, :i + 1]).max(axis=(1, 3)) / 127.0
        assert (s >= content - 1e-6).all()
    deq = (np.asarray(pool, np.float32)[np.asarray(pid)]
           * prev[:, None, :, None])
    bound = (prev[:, None, :] * (1 + grows) / 2 + 1e-6)[..., None]
    assert (np.abs(deq - written) <= bound).all()
    # pages not in pid untouched
    others = np.asarray([0, 2, 4])
    assert (np.asarray(pool)[others] == 0).all()
    assert (np.asarray(scales)[others] == 0).all()
    # steady state (no growth): the fast path writes ONLY the token row —
    # scales and every other resident row bit-unchanged
    before_pool, before_scales = np.asarray(pool), np.asarray(scales)
    small = rng.randn(2, H, D).astype(np.float32) * 1e-3
    pool, scales = qc.page_scatter(pool, scales, pid,
                                   jnp.asarray([1, 2], jnp.int32),
                                   jnp.asarray(small))
    assert (np.asarray(scales) == before_scales).all()
    after = np.asarray(pool)
    rows = np.ones((5, page), bool)
    rows[np.asarray(pid)[0], 1] = rows[np.asarray(pid)[1], 2] = False
    assert (after[rows] == before_pool[rows]).all()
    want = np.clip(np.round(small / prev[:, :, None]), -127, 127)
    got = after[np.asarray(pid), np.asarray([1, 2])]
    assert (got == want).all()


def test_pack_prefill_quantizes_per_page_per_head():
    cfg = get_smoke_config("tinyllama-1.1b")
    policy = QuantPolicy(kv_dtype="int8")
    pool = kvc.build_pool(cfg, num_pages=9, page_size=4, policy=policy)
    dense = jax.tree.map(
        lambda s: jnp.asarray(np.random.RandomState(0).randn(
            *s.shape).astype(np.float32)),
        jax.eval_shape(lambda: build_model(cfg).init_cache(
            1, 8, dtype=jnp.float32)))
    pages = jnp.asarray([3, 5], jnp.int32)
    packed = kvc.pack_prefill_cache(pool, dense, pages, page_size=4)

    def check(pnode, dnode):
        if kvc._is_kv_leaf(pnode):
            for key in ("k", "v"):
                n, _, _, h, d = dnode[key].shape
                want = np.asarray(dnode[key]).reshape(n, 2, 4, h, d)
                sc = np.asarray(pnode[key + "_scale"])[:, np.asarray(pages)]
                np.testing.assert_allclose(
                    sc, np.abs(want).max(axis=(2, 4)) / 127.0, rtol=1e-6)
                got = (np.asarray(pnode[key][:, np.asarray(pages)],
                                  np.float32) * sc[:, :, None, :, None])
                assert (np.abs(got - want) <= sc.max() / 2 + 1e-6).all()
        elif isinstance(pnode, (list, tuple)):
            for p_, d_ in zip(pnode, dnode):
                check(p_, d_)
    check(packed, dense)


# ---------------------------------------------------------------------------
# Pool dtype plumbing (QuantPolicy is the single source of truth)
# ---------------------------------------------------------------------------
def test_build_pool_policy_dtypes():
    cfg = get_smoke_config("tinyllama-1.1b")
    a = cfg.attention
    for policy, dtype, scaled in ((None, jnp.float32, False),
                                  (QuantPolicy("bf16"), jnp.bfloat16, False),
                                  (QuantPolicy("int8"), jnp.int8, True)):
        pool = kvc.build_pool(cfg, num_pages=9, page_size=4, policy=policy)

        def walk(node):
            if kvc._is_kv_leaf(node):
                assert node["k"].dtype == dtype
                assert ("k_scale" in node) == scaled
                if scaled:
                    n = node["k"].shape[0]
                    assert node["k_scale"].shape == (n, 9, a.num_kv_heads)
                    assert node["k_scale"].dtype == jnp.float32
            elif isinstance(node, (list, tuple)):
                for v in node:
                    walk(v)
        walk(pool)
    with pytest.raises(ValueError, match="kv_dtype"):
        QuantPolicy(kv_dtype="fp4")
    with pytest.raises(ValueError, match="weight_bits"):
        QuantPolicy(weight_bits=2)
    # int8 pool ~4x smaller than f32 at equal pages (scales cost < 2%)
    f32 = kvc.page_bytes(cfg, 16)
    i8 = kvc.page_bytes(cfg, 16, QuantPolicy("int8"))
    assert 3.5 < f32 / i8 <= 4.0


# ---------------------------------------------------------------------------
# Quantized spectral weight planes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [8, 4])
def test_quantized_plane_contraction_close(bits):
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(3, 2, 16).astype(np.float32))
    x = jnp.asarray(rng.randn(5, 32).astype(np.float32))
    exact = cc.bc_matmul_spectral(x, cc.spectral_cache(w), 16, 44)
    qcache = qc.quantize_plane_cache(cc.spectral_cache(w), bits)
    got = cc.bc_matmul_spectral(x, qcache, 16, 44)
    # error budget: per-row absmax scale x contraction width
    tol = 0.02 if bits == 8 else 0.4
    assert float(jnp.abs(got - exact).max()) < tol * float(
        jnp.abs(exact).max() + 1)
    # idempotent
    again = qc.quantize_plane_cache(qcache, bits)
    assert set(again) == set(qcache)
    # gauss vs naive quantized lowering agree on the same quantized planes
    xr, xi = cc.rfft_planes(cc._blockify(x, 2, 16), 16)
    g = cc._gauss_contract(xr, xi, qcache, "...qf,pqf->...pf")
    n = cc._naive_complex_contract(xr, xi, qcache, "...qf,pqf->...pf")
    # (not identical: gauss contracts the quantized combo planes; both must
    # stay within the same quantization band of the exact contraction)
    ref = cc._naive_complex_contract(xr, xi, cc.spectral_cache(w),
                                     "...qf,pqf->...pf")
    for approx in (g, n):
        for got_p, ref_p in zip(approx, ref):
            assert float(jnp.abs(got_p - ref_p).max()) < tol * float(
                jnp.abs(ref_p).max() + 1)


def test_quantize_serving_params_walks_all_caches():
    cfg = get_smoke_config("llama4-maverick-400b-a17b").replace(
        dtype="float32")                       # MoE: expert caches too
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    baked = precompute_serving_params(params, cfg)
    quant = precompute_serving_params(
        params, cfg, QuantPolicy(quant_weights=True))
    n_caches, n_scaled = 0, 0

    def walk(node):
        nonlocal n_caches, n_scaled
        if isinstance(node, dict):
            for key, v in node.items():
                if key.endswith("_cache") and isinstance(v, dict):
                    n_caches += 1
                    if "wr_s" in v:
                        n_scaled += 1
                        assert v["wr"].dtype == jnp.int8
                        assert v["wr_s"].shape[-1] == 1
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)
    walk(quant)
    assert n_caches > 0 and n_scaled == n_caches
    # baked (unquantized) tree untouched by comparison
    n_caches = n_scaled = 0
    walk(baked)
    assert n_scaled == 0 and n_caches > 0


# ---------------------------------------------------------------------------
# Quantized paged attention: off == interpret on the dequantized values
# ---------------------------------------------------------------------------
def test_quantized_paged_attention_modes_agree():
    rng = np.random.RandomState(0)
    P_, page, Hkv, G, D = 9, 4, 2, 2, 8
    qk, sk = qc.quantize_page_block(jnp.asarray(
        rng.randn(P_, page, Hkv, D).astype(np.float32)))
    qv, sv = qc.quantize_page_block(jnp.asarray(
        rng.randn(P_, page, Hkv, D).astype(np.float32)))
    table = jnp.asarray([[1, 2, 3, 0], [4, 5, 0, 0], [0, 0, 0, 0]],
                        jnp.int32)
    pos = jnp.asarray([13, 5, -1], jnp.int32)
    q = jnp.asarray(rng.randn(3, Hkv * G, D).astype(np.float32))
    kw = dict(k_scale=sk, v_scale=sv)
    off = kops.paged_attention(q, qk, qv, table, pos, mode="off", **kw)
    interp = kops.paged_attention(q, qk, qv, table, pos, mode="interpret",
                                  **kw)
    # both lanes read the SAME dequantized values: the f32 lane run on the
    # explicitly dequantized pool is the bit-level reference for 'off'
    dqk = qc.dequantize(qk, sk[:, None, :, None])
    dqv = qc.dequantize(qv, sv[:, None, :, None])
    ref = kops.paged_attention(q, dqk, dqv, table, pos, mode="off")
    np.testing.assert_array_equal(np.asarray(off), np.asarray(ref))
    np.testing.assert_allclose(np.asarray(interp), np.asarray(ref),
                               rtol=2e-6, atol=2e-6)
    assert not np.asarray(off)[2].any()        # idle slot exactly zero


# ---------------------------------------------------------------------------
# Engine-level greedy parity: int8 KV vs the f32 oracle
# ---------------------------------------------------------------------------
def _reqs(specs, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(prompt=rng.randint(1, 500, size=s).astype(np.int32),
                    max_new_tokens=n, id=i)
            for i, (s, n) in enumerate(specs)]


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen3-4b"])
def test_engine_int8_kv_greedy_parity(arch):
    """int8 KV pool vs the f32 oracle on tinyllama + a GQA arch: the
    teacher-forced sweep must clear the 99% agreement bar (acceptance
    criterion), and the free-running engine must agree with the f32
    continuous engine on >= 80% of emitted positions (free-running
    divergence compounds after one near-tie flip — methodology in
    docs/quantization.md)."""
    cfg = get_smoke_config(arch).replace(dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    rep = calibrate.parity_report(cfg, params,
                                  policy=QuantPolicy(kv_dtype="int8"),
                                  prompt_len=20, new_tokens=16)
    assert rep["greedy_agreement"] >= 0.99
    assert rep["max_logit_drift"] < 1.0

    reqs = _reqs([(20, 8), (12, 10), (16, 6)])
    oracle = Engine(cfg, params, max_batch=1, max_seq=32)
    want = [oracle.generate([r])[0]["tokens"] for r in reqs]
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32, page_size=4,
                           decode_chunk=5, quant=QuantPolicy("int8"))
    got = [g["tokens"] for g in eng.generate(reqs)]
    agree = sum(int(a == b) for g, w in zip(got, want)
                for a, b in zip(g, w))
    total = sum(len(w) for w in want)
    assert agree / total >= 0.8, f"{agree}/{total}"
    assert eng.stats()["pages_in_use"] == 0    # lifecycle invariants intact


def test_engine_bf16_pool_matches_f32_oracle():
    """bf16 pool storage keeps greedy token identity on the tie-free arch
    (the no-regression guard for the non-quantized dtypes)."""
    cfg = get_smoke_config("tinyllama-1.1b").replace(dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    reqs = _reqs([(20, 8), (12, 10)])
    oracle = Engine(cfg, params, max_batch=1, max_seq=32)
    want = [oracle.generate([r])[0]["tokens"] for r in reqs]
    eng = ContinuousEngine(cfg, params, max_slots=2, max_seq=32, page_size=4,
                           quant=QuantPolicy("bf16"))
    assert [g["tokens"] for g in eng.generate(reqs)] == want


def test_engine_quant_telemetry():
    cfg = get_smoke_config("tinyllama-1.1b").replace(dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    f32 = ContinuousEngine(cfg, params, max_slots=2, max_seq=32, page_size=4)
    i8 = ContinuousEngine(cfg, params, max_slots=2, max_seq=32, page_size=4,
                          quant=QuantPolicy("int8"))
    st_f, st_i = f32.stats(), i8.stats()
    assert st_f["quant_policy"]["kv_dtype"] == "f32"
    assert st_i["quant_policy"]["kv_dtype"] == "int8"
    assert st_i["kv_pool_bytes"] * 3.5 < st_f["kv_pool_bytes"]
    # attention-byte telemetry recomputed for int8 page traffic
    assert st_i["attention_bytes_per_token"] * 3.9 < \
        st_f["attention_bytes_per_token"]


# ---------------------------------------------------------------------------
# Sharding rules for the scale tensors
# ---------------------------------------------------------------------------
class _FakeMesh:
    def __init__(self, shape, axes):
        self.devices = np.empty(shape, dtype=object)
        self.axis_names = axes


def test_page_scale_spec_rules():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    # (n, P, Hkv): pages over DP like the payload, heads indivisible ->
    # replicated (a scale has no head_dim to fall back to)
    assert sharding.page_scale_spec((2, 64, 4), mesh) == P(None, ("data",),
                                                          None)
    assert sharding.page_scale_spec((2, 64, 16), mesh) == P(None, ("data",),
                                                            "model")
    # indivisible page count replicates; never an in-page-offset dim
    assert sharding.page_scale_spec((2, 63, 4), mesh) == P(None, None, None)


def test_pool_specs_route_scales_and_int8_payloads():
    cfg = get_smoke_config("tinyllama-1.1b")
    mesh = _FakeMesh((4, 2), ("data", "model"))
    pool = jax.eval_shape(lambda: kvc.build_pool(
        cfg, num_pages=8, page_size=4, policy=QuantPolicy("int8")))
    specs = sharding.pool_specs(pool, mesh)

    def walk(snode, pnode):
        if isinstance(snode, dict) and "k" in snode:
            # int8 payloads still shard: pages over DP, offset unsharded
            assert snode["k"][1] == ("data",) and snode["k"][2] is None
            assert snode["k_scale"][1] == ("data",)
            assert len(snode["k_scale"]) == 3     # no in-page-offset dim
        elif isinstance(snode, (list, tuple)):
            for s, p_ in zip(snode, pnode):
                walk(s, p_)
    walk(specs, pool)


def test_plane_scale_param_specs():
    mesh = _FakeMesh((4, 4), ("data", "model"))
    # column projection: block-row dim carries "model" like its payload
    assert sharding.param_spec(("segments", "attn", "q", "wc_cache", "wr_s"),
                               (3, 8, 1), mesh) == P(None, "model", None)
    # row projection (o/down/out): payload model-shards q, which the scale
    # does not have -> replicated
    assert sharding.param_spec(("segments", "attn", "o", "wc_cache", "wr_s"),
                               (3, 8, 1), mesh) == P(None, None, None)
    # expert scales (E, p, 1): EP-first like the expert planes
    assert sharding.param_spec(
        ("segments", "moe", "experts", "up_cache", "ws1_s"),
        (3, 4, 8, 1), mesh) == P(None, "model", None, None)
    # E indivisible by the model axis: column scales fall back to the
    # block-row dim like their payload; row scales replicate (their
    # payload model-shards q, which a scale does not have) — regression
    # for the experts branch previously shadowing the scale rule
    assert sharding.param_spec(("moe", "experts", "up_cache", "wr_s"),
                               (3, 8, 1), mesh) == P(None, "model", None)
    assert sharding.param_spec(("moe", "experts", "down_cache", "wr_s"),
                               (3, 8, 1), mesh) == P(None, None, None)
    # never a DP axis on a scale
    spec = sharding.param_spec(("attn", "q", "qkv_cache", "ws2_s"),
                               (16, 1), mesh)
    assert "data" not in jax.tree.leaves(tuple(spec))


# ---------------------------------------------------------------------------
# Calibration report
# ---------------------------------------------------------------------------
def test_weight_absmax_report():
    cfg = get_smoke_config("tinyllama-1.1b").replace(dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    baked = precompute_serving_params(params, cfg)
    rep = calibrate.weight_absmax_report(baked)
    assert rep, "no serving caches found"
    for entry in rep.values():
        for stats in entry.values():
            assert stats["absmax"] > 0
            assert 0 <= stats["scale_min"] <= stats["scale_max"]
            assert stats["scale_max"] == pytest.approx(
                stats["absmax"] / 127.0)
    # the quantized tree reports consistent scales (read back, not derived)
    qrep = calibrate.weight_absmax_report(
        precompute_serving_params(params, cfg, QuantPolicy(
            quant_weights=True)))
    assert set(qrep) == set(rep)
    for path in rep:
        got = qrep[path]["wr"]["scale_max"]
        assert got == pytest.approx(rep[path]["wr"]["scale_max"], rel=1e-5)
    # int4-packed trees read back with qmax=7: absmax stays the true
    # absmax, not 127/7x it (regression)
    q4rep = calibrate.weight_absmax_report(
        precompute_serving_params(params, cfg, QuantPolicy(
            quant_weights=True, weight_bits=4)))
    for path in rep:
        assert q4rep[path]["wr"]["absmax"] == pytest.approx(
            rep[path]["wr"]["absmax"], rel=1e-5)
        # nibble packing halves the int8 payload (round up on odd kf:
        # ceil(kf/2)/kf <= 3/4 for kf >= 2)
        b8, b4 = qrep[path]["wr"]["bytes"], q4rep[path]["wr"]["bytes"]
        assert b8 / 2 <= b4 <= b8 * 0.75
