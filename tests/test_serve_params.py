"""Offline spectral precompute pass: plane correctness, train invariance,
and the no-weight-FFT-inside-decode property (trace counting)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core import circulant as cc
from repro.layers import ffn as ffn_lib
from repro.models.registry import build_model
from repro.serve import decode as dec
from repro.serve.params import (precompute_serving_params,
                                serving_cache_bytes, strip_serving_params)


def _cfg(arch="tinyllama-1.1b", fuse=False):
    cfg = get_smoke_config(arch)
    return cfg.replace(compression=dataclasses.replace(
        cfg.compression, fuse_projections=fuse))


@pytest.fixture(scope="module")
def tiny():
    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_planes_match_on_the_fly_cache(tiny):
    """Baked wc_cache == spectral_cache(wc) computed on the fly (fp32)."""
    cfg, _, params = tiny
    baked = precompute_serving_params(params, cfg)
    seg = baked["segments"][0]
    checked = 0
    for blk in seg:
        for name in ("q", "k", "v", "o"):
            node = blk["attn"][name]
            assert "wc_cache" in node
            want = cc.spectral_cache(node["wc"], cfg.compression.gauss_trick)
            for plane in want:
                np.testing.assert_allclose(
                    np.asarray(node["wc_cache"][plane]),
                    np.asarray(want[plane]), rtol=1e-6, atol=1e-6)
                checked += 1
    assert checked


def test_fused_planes_are_concatenated(tiny):
    """qkv_cache is the generators' planes stacked on the p axis in q/k/v
    order (what bc_matmul_fused splits back apart); the per-projection
    planes it shadows are dropped, while unfused projections keep theirs."""
    cfg, _, params = tiny
    gauss = cfg.compression.gauss_trick
    baked = precompute_serving_params(
        params, cfg.replace(compression=dataclasses.replace(
            cfg.compression, fuse_projections=True)))
    blk = baked["segments"][0][0]
    qkv = blk["attn"]["qkv_cache"]
    want = cc.spectral_cache(jnp.concatenate(
        [blk["attn"][n]["wc"] for n in ("q", "k", "v")], axis=-3), gauss)
    np.testing.assert_allclose(np.asarray(qkv["wr"]),
                               np.asarray(want["wr"]), rtol=1e-6, atol=1e-6)
    up = blk["mlp"]["upgate_cache"]
    want = cc.spectral_cache(jnp.concatenate(
        [blk["mlp"][n]["wc"] for n in ("up", "gate")], axis=-3), gauss)
    np.testing.assert_allclose(np.asarray(up["wr"]),
                               np.asarray(want["wr"]), rtol=1e-6, atol=1e-6)
    # single-copy footprint: fused planes replace the per-projection ones
    for n in ("q", "k", "v"):
        assert "wc_cache" not in blk["attn"][n]
    assert "wc_cache" in blk["attn"]["o"]
    for n in ("up", "gate"):
        assert "wc_cache" not in blk["mlp"][n]
    assert "wc_cache" in blk["mlp"]["down"]


def test_decode_logits_match_with_and_without_precompute(tiny):
    """Serving math is unchanged by the offline pass (fp32 tolerance)."""
    cfg, model, params = tiny
    baked = precompute_serving_params(params, cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 500)

    def run(p):
        cache = model.init_cache(B, S + 2, dtype=jnp.float32)
        lg, cache = model.prefill(p, {"tokens": toks}, cache)
        nxt = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        lg2, _ = model.decode_step(p, nxt, cache, jnp.int32(S))
        return lg, lg2

    for a, b in zip(run(params), run(baked)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-4)


def test_train_forward_ignores_baked_caches(tiny):
    """forward_train differentiates through wc, not the baked planes."""
    cfg, model, params = tiny
    baked = precompute_serving_params(params, cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 8),
                                          0, 500),
             "labels": jnp.zeros((2, 8), jnp.int32)}
    a, _ = model.forward_train(params, batch)
    b, _ = model.forward_train(baked, batch)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_strip_and_idempotence(tiny):
    cfg, _, params = tiny
    baked = precompute_serving_params(params, cfg)
    assert serving_cache_bytes(baked) > 0
    again = precompute_serving_params(baked, cfg)
    assert (jax.tree_util.tree_structure(again)
            == jax.tree_util.tree_structure(baked))
    stripped = strip_serving_params(baked)
    assert (jax.tree_util.tree_structure(stripped)
            == jax.tree_util.tree_structure(params))
    for a, b in zip(jax.tree.leaves(stripped), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# The acceptance property: with precomputed params, tracing the jitted decode
# step performs ZERO weight-side FFTs.  Every weight FFT funnels through
# `spectral_cache` (apply_linear's on-the-fly path) or `bc_matmul_fft` (the
# train lowering / expert FFN / fused projections), so spying on those during
# trace is an exact count.  ffn.py binds bc_matmul_fft by from-import, so its
# reference is patched too.
# ---------------------------------------------------------------------------
def _weight_fft_trace_count(cfg, params) -> int:
    counts = [0]
    orig_sc, orig_fft = cc.spectral_cache, cc.bc_matmul_fft
    orig_ffn_fft = ffn_lib.bc_matmul_fft

    def sc(w, gauss=True):
        counts[0] += 1
        return orig_sc(w, gauss)

    def fft(x, w, n_out, gauss=True):
        counts[0] += 1
        return orig_fft(x, w, n_out, gauss)

    cc.spectral_cache, cc.bc_matmul_fft = sc, fft
    ffn_lib.bc_matmul_fft = fft
    try:
        step = dec.make_decode_step(cfg)
        cache = jax.eval_shape(
            lambda: build_model(cfg).init_cache(2, 24, dtype=jnp.float32))
        jax.eval_shape(step, params, jax.ShapeDtypeStruct((2, 1), jnp.int32),
                       cache, jax.ShapeDtypeStruct((), jnp.int32))
    finally:
        cc.spectral_cache, cc.bc_matmul_fft = orig_sc, orig_fft
        ffn_lib.bc_matmul_fft = orig_ffn_fft
    return counts[0]


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mixtral-8x7b"])
@pytest.mark.parametrize("fuse", [False, True])
def test_no_weight_fft_in_decode_trace(arch, fuse):
    cfg = _cfg(arch, fuse=fuse)
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    baked = jax.eval_shape(
        lambda p: precompute_serving_params(p, cfg), params)
    assert _weight_fft_trace_count(cfg, params) > 0      # spy sanity
    assert _weight_fft_trace_count(cfg, baked) == 0
