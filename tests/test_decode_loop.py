"""Device-resident decode loop: bit-identity with the seed per-token loop,
per-request length handling, EOS early-exit, and the cache-length clamp."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models.registry import build_model
from repro.serve.engine import Engine, Request

# Prompts must cover the smoke sliding window (16): the ring-buffer prefill
# keeps the window tail and asserts S >= window (pre-existing engine
# behavior, see DESIGN.md).
PROMPT_LEN = 20


def _reqs(n=2, new=5, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(prompt=rng.randint(1, 500, size=PROMPT_LEN)
                    .astype(np.int32), max_new_tokens=new, id=i)
            for i in range(n)]


@pytest.fixture(scope="module", params=ARCH_IDS)
def engine(request):
    cfg = get_smoke_config(request.param)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(cfg, params, max_batch=4, max_seq=64)


def test_scanned_matches_per_token(engine):
    """Greedy tokens from the device loop == the seed host loop, bitwise."""
    a = [r["tokens"] for r in engine.generate(_reqs())]
    engine.decode_mode = "per_token"
    try:
        b = [r["tokens"] for r in engine.generate(_reqs())]
    finally:
        engine.decode_mode = "scan"
    assert a == b


def test_scanned_matches_per_token_fused():
    """Same bit-identity with projection fusion (the fused spectral path)."""
    cfg = get_smoke_config("tinyllama-1.1b")
    cfg = cfg.replace(compression=dataclasses.replace(
        cfg.compression, fuse_projections=True))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=4, max_seq=64)
    a = [r["tokens"] for r in eng.generate(_reqs())]
    eng.decode_mode = "per_token"
    b = [r["tokens"] for r in eng.generate(_reqs())]
    assert a == b


@pytest.fixture(scope="module")
def tiny_engine():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params, Engine(cfg, params, max_batch=4, max_seq=64)


def test_ragged_lengths_on_device(tiny_engine):
    """Per-request budgets are honored inside the device loop: a short
    request's tokens are a prefix of the same request run longer."""
    _, _, eng = tiny_engine
    reqs = _reqs(n=3, new=8)
    reqs[1] = dataclasses.replace(reqs[1], max_new_tokens=3)
    out = eng.generate(reqs)
    assert [r["decode_len"] for r in out] == [8, 3, 8]
    long = eng.generate([dataclasses.replace(reqs[1], max_new_tokens=8)])
    assert out[1]["tokens"] == long[0]["tokens"][:3]


def test_eos_early_exit(tiny_engine):
    """With eos_id set, tokens stop at the first EOS the model emits."""
    cfg, params, ref = tiny_engine
    reqs = _reqs(n=2, new=8)
    base = ref.generate(reqs)
    # pick the token the model actually emits mid-stream as the "EOS"
    eos = base[0]["tokens"][2]
    eng = Engine(cfg, params, max_batch=4, max_seq=64, eos_id=eos)
    out = eng.generate(reqs)
    toks = out[0]["tokens"]
    assert toks == base[0]["tokens"][:base[0]["tokens"].index(eos) + 1]
    assert toks[-1] == eos and eos not in toks[:-1]


def test_cache_clamp_regression(tiny_engine):
    """Prompts near max_seq clamp the step budget instead of writing past
    the allocated cache (seed bug: decode positions reached S + steps - 1
    with only min(S + steps, max_seq) slots allocated)."""
    cfg, params, ref = tiny_engine
    eng = Engine(cfg, params, max_batch=4, max_seq=24)
    req = _reqs(n=1, new=16)[0]                    # S=20 -> budget 24-20+1=5
    out = eng.generate([req])
    assert out[0]["decode_len"] == 5
    # the clamped tokens agree with an engine that has cache headroom
    want = ref.generate([dataclasses.replace(req, max_new_tokens=5)])
    assert out[0]["tokens"] == want[0]["tokens"]


def test_prompt_longer_than_max_seq_raises(tiny_engine):
    cfg, params, _ = tiny_engine
    eng = Engine(cfg, params, max_batch=4, max_seq=16)
    with pytest.raises(ValueError, match="max_seq"):
        eng.generate(_reqs(n=1))                   # PROMPT_LEN=20 > 16


def test_prompt_shorter_than_swa_window_raises():
    """SWA ring-buffer prefill needs prompts covering the window — a clean
    engine error now, not a trace-time assert."""
    cfg = get_smoke_config("mixtral-8x7b")         # window 16, every layer
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_seq=64)
    short = [Request(prompt=np.arange(6, dtype=np.int32) + 1,
                     max_new_tokens=4, id=0)]
    with pytest.raises(ValueError, match="sliding-window"):
        eng.generate(short)


def test_request_metrics(tiny_engine):
    _, _, eng = tiny_engine
    out = eng.generate(_reqs(n=2, new=4))
    for r in out:
        assert r["decode_len"] == len(r["tokens"]) == 4
        assert r["tokens_per_s"] > 0
        assert r["latency_s"] == pytest.approx(
            r["prefill_s"] + r["decode_s"])
