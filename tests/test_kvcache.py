"""Paged KV-cache pool: allocator/block-table invariants (hypothesis
sweeps), pool construction, the paged-gather kernel dispatch, and the
pool sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.dist import sharding
from repro.kernels import ops as kops
from repro.models.registry import build_model
from repro.serve import kvcache as kvc

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# PageAllocator
# ---------------------------------------------------------------------------
def test_allocator_basics():
    a = kvc.PageAllocator(8)
    assert a.available == 7                 # page 0 reserved (trash)
    pages = a.alloc(3)
    assert len(pages) == 3 and kvc.TRASH_PAGE not in pages
    assert a.available == 4 and a.in_use == 3
    assert a.alloc(5) is None               # exhausted: None, state unchanged
    assert a.available == 4
    a.free(pages)
    assert a.available == 7 and a.in_use == 0
    with pytest.raises(ValueError):
        a.free(pages)                       # double free


def test_allocator_free_rejects_corruption():
    a = kvc.PageAllocator(8)
    pages = a.alloc(2)
    with pytest.raises(ValueError, match="trash page"):
        a.free([kvc.TRASH_PAGE])
    with pytest.raises(ValueError, match="double free"):
        a.free([pages[0], pages[0]])            # second hit within one call
    with pytest.raises(ValueError, match="foreign page"):
        a.free([99])
    with pytest.raises(ValueError, match="foreign page"):
        a.free([-1])


def test_allocator_fault_hook_fails_alloc():
    calls = []

    def fault(n):
        calls.append(n)
        return len(calls) == 1                  # first alloc only

    a = kvc.PageAllocator(8, fault=fault)
    assert a.alloc(2) is None                   # injected failure
    assert a.available == 7 and a.in_use == 0   # state untouched
    assert a.alloc(2) is not None
    assert calls == [2, 2]


def _allocator_schedule(num_pages, sizes):
    """No page is ever held twice; free fully restores the pool."""
    a = kvc.PageAllocator(num_pages)
    held = []
    seen = set()
    for n in sizes:
        pages = a.alloc(n)
        if pages is None:
            assert n > a.available
            continue
        assert not seen.intersection(pages), "page handed out twice"
        assert kvc.TRASH_PAGE not in pages
        seen.update(pages)
        held.append(pages)
        if len(held) > 2:                   # free the oldest now and then
            old = held.pop(0)
            a.free(old)
            seen.difference_update(old)
    for pages in held:
        a.free(pages)
    assert a.available == num_pages - 1 and a.in_use == 0


def test_allocator_random_schedules():
    """Deterministic randomized sweep (runs with or without hypothesis)."""
    rng = np.random.RandomState(0)
    for _ in range(100):
        num_pages = int(rng.randint(2, 40))
        sizes = rng.randint(0, 7, size=rng.randint(0, 40)).tolist()
        _allocator_schedule(num_pages, sizes)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.integers(2, 40), st.lists(st.integers(0, 6), max_size=40))
    def test_allocator_never_double_hands_out(num_pages, sizes):
        _allocator_schedule(num_pages, sizes)


# ---------------------------------------------------------------------------
# BlockTable
# ---------------------------------------------------------------------------
def test_block_table_reserve_release():
    a = kvc.PageAllocator(8)                # 7 usable pages (+ trash)
    t = kvc.BlockTable(a, max_slots=2, page_size=4, max_pages_per_slot=4)
    assert t.reserve(0, 9)                  # 3 pages
    assert len(t.pages(0)) == 3
    assert t.reserve(0, 5)                  # shrink request: no-op
    assert len(t.pages(0)) == 3
    assert t.reserve(1, 16)                 # 4 pages -> pool now empty
    assert not t.reserve(0, 16)             # exhausted -> False, no change
    assert len(t.pages(0)) == 3
    row = t.table[0]
    assert all(p != kvc.TRASH_PAGE for p in row[:3]) and row[3] == 0
    assert not set(t.pages(0)) & set(t.pages(1))
    t.release(0)
    t.release(1)
    assert a.available == 7
    assert (t.table == kvc.TRASH_PAGE).all()


def test_block_table_release_idempotent():
    a = kvc.PageAllocator(8)
    t = kvc.BlockTable(a, max_slots=2, page_size=4, max_pages_per_slot=4)
    t.reserve(0, 9)
    t.release(0)
    assert a.available == 7
    t.release(0)                                # second release: no-op
    t.release(1)                                # never-reserved slot: no-op
    assert a.available == 7 and a.in_use == 0


def test_block_table_version_tracks_mutations():
    a = kvc.PageAllocator(8)
    t = kvc.BlockTable(a, max_slots=2, page_size=4, max_pages_per_slot=4)
    v0 = t.version
    assert t.reserve(0, 9)                      # grows: version moves
    v1 = t.version
    assert v1 > v0
    assert t.reserve(0, 5)                      # no growth: version still
    assert t.version == v1
    assert t.reserve(1, 16)
    assert not t.reserve(0, 16)                 # failed reserve: no change
    v2 = t.version
    t.release(0)
    assert t.version > v2
    v3 = t.version
    t.release(0)                                # idempotent: version still
    assert t.version == v3


def test_block_table_overflow_raises():
    t = kvc.BlockTable(kvc.PageAllocator(10), 1, 4, 2)
    with pytest.raises(ValueError, match="max_pages_per_slot"):
        t.reserve(0, 100)


def _table_schedule(slots, page, maxp, num_pages, ops):
    """Randomized reserve/release schedule: no page in two rows at once,
    free list fully restored after all rows release."""
    t = kvc.BlockTable(kvc.PageAllocator(num_pages), slots, page, maxp)
    for s, do_reserve, n in ops:
        if do_reserve:
            t.reserve(s, n)
        else:
            t.release(s)
        owned = [set(t.pages(i)) for i in range(slots)]
        for i in range(slots):
            for j in range(i + 1, slots):
                assert not owned[i] & owned[j], "page owned by two slots"
        assert kvc.TRASH_PAGE not in set().union(*owned)
    for s in range(slots):
        t.release(s)
    assert t.allocator.available == num_pages - 1


def test_block_table_random_schedules():
    """Deterministic randomized sweep (runs with or without hypothesis)."""
    rng = np.random.RandomState(1)
    for _ in range(60):
        slots = int(rng.randint(1, 6))
        page = int(rng.choice([2, 4, 8]))
        maxp = int(rng.randint(1, 7))
        num_pages = int(rng.randint(2, slots * maxp + 2))
        ops = [(int(rng.randint(0, slots)), bool(rng.randint(0, 2)),
                int(rng.randint(1, maxp * page + 1)))
               for _ in range(rng.randint(1, 30))]
        _table_schedule(slots, page, maxp, num_pages, ops)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.data())
    def test_block_table_no_shared_ownership(data):
        slots = data.draw(st.integers(1, 5))
        page = data.draw(st.sampled_from([2, 4, 8]))
        maxp = data.draw(st.integers(1, 6))
        num_pages = data.draw(st.integers(2, slots * maxp + 1))
        ops = data.draw(st.lists(st.tuples(
            st.integers(0, slots - 1), st.booleans(),
            st.integers(1, maxp * page)), min_size=1, max_size=30))
        _table_schedule(slots, page, maxp, num_pages, ops)


# ---------------------------------------------------------------------------
# Pool construction + gather dispatch
# ---------------------------------------------------------------------------
def test_build_pool_shapes():
    cfg = get_smoke_config("tinyllama-1.1b")
    pool = kvc.build_pool(cfg, num_pages=9, page_size=4)
    leaves = jax.tree.leaves(pool)
    a = cfg.attention
    for leaf in leaves:
        assert leaf.shape[1:] == (9, 4, a.num_kv_heads, a.head_dim)
    assert kvc.pool_bytes(pool) == sum(
        leaf.size * 4 for leaf in leaves)


def test_build_pool_rejects_unservable():
    for arch in ("mixtral-8x7b", "whisper-large-v3", "xlstm-125m",
                 "recurrentgemma-2b", "gemma2-9b"):
        cfg = get_smoke_config(arch)
        assert kvc.servable_reasons(cfg)
        with pytest.raises(ValueError, match="not paged-servable"):
            kvc.build_pool(cfg, num_pages=5, page_size=4)


def test_paged_gather_modes_agree():
    rng = np.random.RandomState(0)
    pool = jnp.asarray(rng.randn(9, 4, 2, 8).astype(np.float32))
    table = jnp.asarray(rng.randint(0, 9, size=(3, 5)).astype(np.int32))
    off = kops.paged_gather(pool, table, mode="off")
    ref = np.asarray(pool)[np.asarray(table).reshape(-1)].reshape(3, 20, 2, 8)
    np.testing.assert_array_equal(np.asarray(off), ref)
    interp = kops.paged_gather(pool, table, mode="interpret")
    np.testing.assert_array_equal(np.asarray(interp), ref)


def test_pack_prefill_cache_places_pages():
    cfg = get_smoke_config("tinyllama-1.1b")
    pool = kvc.build_pool(cfg, num_pages=9, page_size=4)
    model_cache = jax.tree.map(
        lambda s: jnp.arange(np.prod(s.shape), dtype=jnp.float32).reshape(
            s.shape),
        jax.eval_shape(lambda: build_model(cfg).init_cache(
            1, 8, dtype=jnp.float32)))
    pages = jnp.asarray([3, 5], jnp.int32)
    packed = kvc.pack_prefill_cache(pool, model_cache, pages, page_size=4)

    def check(pnode, dnode):
        if kvc._is_kv_leaf(pnode):
            for key in ("k", "v"):
                got = np.asarray(pnode[key][:, np.asarray(pages)])
                n, _, _, h, d = dnode[key].shape
                want = np.asarray(dnode[key]).reshape(n, 2, 4, h, d)
                np.testing.assert_array_equal(got, want)
        elif isinstance(pnode, (list, tuple)):
            for p, d in zip(pnode, dnode):
                check(p, d)
    check(packed, model_cache)


# ---------------------------------------------------------------------------
# Sharding rules for the pool
# ---------------------------------------------------------------------------
class _FakeMesh:
    def __init__(self, shape, axes):
        self.devices = np.empty(shape, dtype=object)
        self.axis_names = axes


def test_page_pool_spec_rules():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    # (n, P, page, Hkv, D): pages over DP, heads indivisible -> head_dim
    spec = sharding.page_pool_spec((2, 64, 16, 4, 32), mesh)
    assert spec == jax.sharding.PartitionSpec(None, ("data",), None, None,
                                              "model")
    # divisible heads take the model axis
    spec = sharding.page_pool_spec((2, 64, 16, 16, 32), mesh)
    assert spec == jax.sharding.PartitionSpec(None, ("data",), None, "model",
                                              None)
    # indivisible page count replicates, page dim NEVER sharded
    spec = sharding.page_pool_spec((2, 63, 16, 4, 32), mesh)
    assert spec[1] is None and spec[2] is None


def test_dp_round_up_keeps_page_dim_shardable():
    """The engine's default pool (slots * maxp + 1 trash) is indivisible by
    any DP product >= 2; dp_round_up restores divisibility so the page dim
    shards instead of silently replicating."""
    mesh = _FakeMesh((16, 16), ("data", "model"))
    n = sharding.dp_round_up(32 * 16 + 1, mesh)        # 513 -> 528
    assert n % 16 == 0 and n >= 513
    spec = sharding.page_pool_spec((2, n, 16, 16, 32), mesh)
    assert spec[1] == ("data",)
    # no DP axes (or size-1): identity
    assert sharding.dp_round_up(7, _FakeMesh((1, 4), ("data", "model"))) == 7


def test_pool_specs_match_dense_cache_story():
    """Pages shard like the dense cache they replace: batch->DP becomes
    page->DP, heads->model unchanged; block tables replicate."""
    cfg = get_smoke_config("tinyllama-1.1b")
    mesh = _FakeMesh((4, 2), ("data", "model"))
    pool = jax.eval_shape(lambda: kvc.build_pool(cfg, num_pages=8,
                                                 page_size=4))
    specs = sharding.pool_specs(pool, mesh)
    for spec in jax.tree.leaves(specs,
                                is_leaf=lambda x: isinstance(
                                    x, jax.sharding.PartitionSpec)):
        assert spec[1] == ("data",)          # page-id dim over DP
        assert spec[2] is None               # in-page offset never sharded
    table = jnp.zeros((4, 8), jnp.int32)
    assert sharding.pool_specs(table, mesh) == jax.sharding.PartitionSpec()
