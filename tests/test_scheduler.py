"""Continuous-batching scheduler: FIFO token-budget admission, slot
lifecycle, and full-restoration invariants under randomized schedules."""
import numpy as np
import pytest

from repro.serve import kvcache as kvc
from repro.serve.engine import Request
from repro.serve.scheduler import Scheduler

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


def make_sched(*, slots=4, page=4, maxp=8, num_pages=None, max_seq=32,
               budget=None, **kw):
    num_pages = num_pages if num_pages is not None else slots * maxp + 1
    table = kvc.BlockTable(kvc.PageAllocator(num_pages), slots, page, maxp)
    return Scheduler(table, max_seq=max_seq,
                     max_tokens_in_flight=budget or slots * (max_seq + 1),
                     **kw)


def req(s, new, rid=0):
    return Request(prompt=np.arange(s, dtype=np.int32) + 1,
                   max_new_tokens=new, id=rid)


# ---------------------------------------------------------------------------
# Directed tests
# ---------------------------------------------------------------------------
def test_fifo_admission_into_free_slots():
    sched = make_sched(slots=2)
    for i in range(4):
        sched.submit(req(8, 4, rid=i))
    admitted = sched.try_admit()
    assert [s.request.id for s in admitted] == [0, 1]
    assert sched.queue_depth == 2
    assert not sched.try_admit()            # no free slot
    res = sched.retire(admitted[0])
    assert res["id"] == 0
    nxt = sched.try_admit()
    assert [s.request.id for s in nxt] == [2]    # FIFO, into the freed slot


def test_token_budget_gates_admission():
    sched = make_sched(slots=4, budget=30)
    sched.submit(req(8, 6))                 # footprint 14
    sched.submit(req(8, 6))                 # 28 total
    sched.submit(req(8, 6))                 # would exceed 30
    admitted = sched.try_admit()
    assert len(admitted) == 2 and sched.tokens_in_flight == 28
    sched.retire(admitted[0])
    assert sched.tokens_in_flight == 14
    assert len(sched.try_admit()) == 1


def test_page_exhaustion_blocks_head_without_skipping():
    # 5 usable pages, page_size 4: a 17-position request needs 5 pages
    # (worst-case reservation policy — optimistic would admit the second
    # request into the page the first didn't reserve up front)
    sched = make_sched(slots=2, page=4, maxp=5, num_pages=6, max_seq=20,
                       admission="reserve")
    sched.submit(req(16, 2, rid=0))         # 16 prompt + 1 -> 17 pos, 5 pages
    sched.submit(req(4, 2, rid=1))          # would fit 1 page — must NOT skip
    admitted = sched.try_admit()
    assert [s.request.id for s in admitted] == [0]
    assert not sched.try_admit()            # head (id=1) blocked: 0 pages free
    sched.retire(admitted[0])
    assert [s.request.id for s in sched.try_admit()] == [1]


def test_budget_clamped_to_cache_bound():
    sched = make_sched(max_seq=16)
    sched.submit(req(12, 50))
    slot = sched.try_admit()[0]
    assert slot.budget == 16 - 12 + 1       # batch-engine clamp rule


def test_arrival_gating():
    sched = make_sched()
    sched.submit(req(8, 4), arrival_s=1.0)
    assert not sched.try_admit(0.5, arrived_before=0.5)
    assert len(sched.try_admit(1.5, arrived_before=1.5)) == 1


def test_prompt_longer_than_max_seq_raises():
    sched = make_sched(max_seq=8)
    sched.submit(req(12, 2))
    with pytest.raises(ValueError, match="max_seq"):
        sched.try_admit()


def test_stats_shape():
    sched = make_sched()
    sched.submit(req(8, 4))
    sched.try_admit()
    st = sched.stats()
    for key in ("queue_depth", "running", "tokens_in_flight",
                "pages_in_use", "page_utilization", "submitted",
                "admitted", "retired", "peak_tokens_in_flight"):
        assert key in st
    assert st["running"] == 1 and st["queue_depth"] == 0


# ---------------------------------------------------------------------------
# Lifecycle: rejection, deadlines, cancel, drain
# ---------------------------------------------------------------------------
def test_bounded_queue_rejects_with_backpressure():
    sched = make_sched(max_queue=2)
    assert sched.submit(req(4, 2, rid=0)) == (0, True)
    assert sched.submit(req(4, 2, rid=1)) == (1, True)
    order, accepted = sched.submit(req(4, 2, rid=2))
    assert order == 2 and not accepted          # full: rejected, order unique
    assert sched.terminal_counts()["REJECTED"] == 1
    assert sched.queue_depth == 2


def test_close_intake_rejects_and_flush_sheds_fresh_only():
    sched = make_sched(slots=1)
    sched.submit(req(4, 8, rid=0))
    slot = sched.try_admit()[0]
    slot.tokens.extend([7, 7])
    entry = sched.preempt(slot)                 # resume entry at queue head
    sched.submit(req(4, 2, rid=1))              # fresh entry behind it
    sched.close_intake()
    assert sched.submit(req(4, 2, rid=2)) == (2, False)
    dropped = sched.flush_queue()
    assert [e.request.id for e in dropped] == [1]
    assert [e.request.id for e in sched.queue] == [0]   # resume survives
    assert entry.resume_tokens == [7, 7]
    assert sched.terminal_counts()["REJECTED"] == 2


def test_expire_queue_times_out_by_absolute_deadline():
    sched = make_sched()
    r = req(4, 2, rid=0)
    r.deadline_s = 1.0
    sched.submit(r, arrival_s=2.0)              # absolute deadline = 3.0
    sched.submit(req(4, 2, rid=1))              # no deadline: never expires
    assert not sched.expire_queue(2.5)
    expired = sched.expire_queue(3.5)
    assert [e.request.id for e in expired] == [0]
    assert sched.terminal_counts()["TIMEOUT"] == 1
    assert sched.queue_depth == 1


def test_cancel_queued_and_running():
    sched = make_sched(slots=1)
    sched.submit(req(4, 4, rid=0))
    sched.submit(req(4, 4, rid=1))
    sched.try_admit()
    where, slot = sched.cancel(0)
    assert where == "running" and slot.request.id == 0
    assert not slot.free                        # caller retires at boundary
    where, entry = sched.cancel(1)
    assert where == "queued" and entry.request.id == 1
    assert sched.queue_depth == 0
    assert sched.terminal_counts()["CANCELLED"] == 1
    assert sched.cancel(7) is None


def test_retire_rejects_unknown_status():
    sched = make_sched()
    sched.submit(req(4, 2))
    slot = sched.try_admit()[0]
    with pytest.raises(ValueError, match="terminal status"):
        sched.retire(slot, status="DONEISH")


# ---------------------------------------------------------------------------
# Optimistic admission + preemption
# ---------------------------------------------------------------------------
def test_optimistic_admits_where_reserve_defers():
    # 5 usable pages, page 4: a 16-prompt request prefills into 4 pages but
    # its worst case is 5 — reserve admits it alone, optimistic fits a
    # 1-page neighbour beside it.
    kw = dict(slots=2, page=4, maxp=5, num_pages=6, max_seq=20)
    opt = make_sched(admission="optimistic", **kw)
    opt.submit(req(16, 2, rid=0))               # spad 16 -> 4 pages (worst 5)
    opt.submit(req(4, 2, rid=1))                # spad 4 -> 1 page
    assert [s.request.id for s in opt.try_admit()] == [0, 1]
    res = make_sched(admission="reserve", **kw)
    res.submit(req(16, 2, rid=0))
    res.submit(req(4, 2, rid=1))
    assert [s.request.id for s in res.try_admit()] == [0]


def test_prepare_decode_preempts_youngest_on_page_pressure():
    # 4 usable pages, page 2: both slots prefill into 2 pages each (pool
    # full); first growth step must evict the younger slot.
    sched = make_sched(slots=2, page=2, maxp=5, num_pages=5, max_seq=10)
    sched.submit(req(4, 5, rid=0))
    sched.submit(req(4, 5, rid=1))
    s0, s1 = sched.try_admit()
    for slot in (s0, s1):
        slot.tokens.append(7)                   # engine: prefill's first token
    prep = sched.prepare_decode(chunk=4)
    assert [s.request.id for s in prep.runnable] == [0]
    assert [(i, e.request.id) for i, e in prep.preempted] == [(s1.index, 1)]
    assert not prep.stalled
    entry = prep.preempted[0][1]
    assert entry.resume_tokens == [7] and entry.preemptions == 1
    assert sched.queue[0] is entry              # re-queued at the head
    # the resumed entry's footprint never inflates
    s, steps, spad, worst = sched._plan(entry)
    assert s == 5 and steps == 4 and worst == 8


def test_preemption_bound_stalls_instead_of_thrashing():
    sched = make_sched(slots=2, page=2, maxp=5, num_pages=5, max_seq=10,
                       max_preemptions=0)
    sched.submit(req(4, 5, rid=0))
    sched.submit(req(4, 5, rid=1))
    for slot in sched.try_admit():
        slot.tokens.append(7)
    prep = sched.prepare_decode(chunk=4)
    assert not prep.preempted                   # nobody is evictable
    assert [s.request.id for s in prep.stalled] == [0, 1]
    assert sched.stats()["stalled"] == 2


def test_doomed_entry_fails_instead_of_deferring_forever():
    # 2 usable pages, page 2: a worst-case-8-position request can NEVER
    # fit — admission fails it (liveness) rather than parking it forever
    sched = make_sched(slots=1, page=2, maxp=5, num_pages=3, max_seq=10)
    sched.submit(req(4, 5, rid=0))
    sched.submit(req(2, 1, rid=1))              # fits: must not be blocked
    admitted = sched.try_admit()
    assert [s.request.id for s in admitted] == [1]
    doomed = sched.drain_doomed()
    assert [e.request.id for e in doomed] == [0]
    assert sched.terminal_counts()["FAILED"] == 1
    assert not sched.drain_doomed()             # drained once


def test_self_preemption_when_alone():
    # one slot, pool large enough, but a transient alloc fault (the chaos
    # harness's injection point) hits its growth: it evicts itself
    faults = iter([False, True])                # admit ok, first growth fails
    table = kvc.BlockTable(
        kvc.PageAllocator(6, fault=lambda n: next(faults, False)),
        max_slots=1, page_size=2, max_pages_per_slot=5)
    sched = Scheduler(table, max_seq=10, max_tokens_in_flight=11)
    sched.submit(req(4, 5, rid=0))
    (slot,) = sched.try_admit()
    slot.tokens.append(7)
    prep = sched.prepare_decode(chunk=4)
    assert not prep.runnable and not prep.stalled
    assert [(i, e.request.id) for i, e in prep.preempted] == [(0, 0)]
    assert sched.queue[0].resume_tokens == [7]
    assert sched.table.allocator.in_use == 0


# ---------------------------------------------------------------------------
# Randomized schedule invariants
# ---------------------------------------------------------------------------
def _run_schedule(slots, page, maxp, max_seq, budget, reqs, steps_draw):
    """Drive submit/admit/decode/retire; check invariants at every step:

    * admissions strictly FIFO, never more running than slots;
    * token budget respected; no page owned by two slots;
    * every request eventually retires with exactly its clamped budget of
      tokens; the free list and tables are fully restored at the end.
    """
    num_pages = slots * maxp + 1
    sched = make_sched(slots=slots, page=page, maxp=maxp,
                       num_pages=num_pages, max_seq=max_seq, budget=budget)
    for i, r in enumerate(reqs):
        sched.submit(r)
    admitted_order = []
    retired = {}
    guard = 0
    while not sched.idle:
        guard += 1
        assert guard < 10_000, "schedule did not converge"
        for slot in sched.try_admit():
            admitted_order.append(slot.request.id)
            assert sched.tokens_in_flight <= sched.max_tokens_in_flight
        running = sched.running
        assert len(running) <= slots
        owned = [set(sched.table.pages(s.index)) for s in running]
        for i in range(len(owned)):
            for j in range(i + 1, len(owned)):
                assert not owned[i] & owned[j]
        if not running:
            assert not sched.queue, "stalled with work queued"
            break
        # emulate a decode chunk: each running slot emits some tokens
        for slot in list(running):
            emit = min(steps_draw(slot), slot.budget - len(slot.tokens))
            slot.tokens.extend([7] * emit)
            if len(slot.tokens) >= slot.budget:
                res = sched.retire(slot)
                retired[res["id"]] = res
    assert admitted_order == [r.id for r in reqs]      # strict FIFO
    assert set(retired) == {r.id for r in reqs}
    for r in reqs:
        clamp = max(1, min(r.max_new_tokens, max_seq - len(r.prompt) + 1))
        assert retired[r.id]["decode_len"] == clamp
    assert sched.tokens_in_flight == 0
    assert sched.table.allocator.available == num_pages - 1
    assert (sched.table.table == kvc.TRASH_PAGE).all()


def test_randomized_schedules():
    rng = np.random.RandomState(0)
    for _ in range(40):
        slots = int(rng.randint(1, 5))
        page = int(rng.choice([2, 4, 8]))
        maxp = int(rng.randint(2, 8))
        max_seq = page * maxp
        n = int(rng.randint(1, 12))
        reqs = [req(int(rng.randint(1, max_seq + 1)),
                    int(rng.randint(1, 20)), rid=i) for i in range(n)]
        _run_schedule(slots, page, maxp, max_seq,
                      slots * (max_seq + 1), reqs,
                      lambda slot: int(rng.randint(1, 9)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_schedule_invariants_hypothesis(data):
        slots = data.draw(st.integers(1, 4))
        page = data.draw(st.sampled_from([2, 4, 8]))
        maxp = data.draw(st.integers(2, 7))
        max_seq = page * maxp
        reqs = [req(data.draw(st.integers(1, max_seq)),
                    data.draw(st.integers(1, 20)), rid=i)
                for i in range(data.draw(st.integers(1, 10)))]
        chunk = data.draw(st.integers(1, 8))
        _run_schedule(slots, page, maxp, max_seq, slots * (max_seq + 1),
                      reqs, lambda slot: chunk)


# ---------------------------------------------------------------------------
# Lifecycle state machine: every request reaches exactly one terminal
# status; the pool is fully restored — under random interleavings of
# submit / admit / cancel / deadline-expiry / preempt / decode / retire.
# ---------------------------------------------------------------------------
def _lifecycle_machine(draw, slots, page, maxp, n_requests, n_events):
    """``draw(lo, hi)`` -> int in [lo, hi] (rng- or hypothesis-backed)."""
    num_pages = max(2, slots * maxp // 2 + 1)   # undersized: organic pressure
    max_seq = page * maxp
    table = kvc.BlockTable(kvc.PageAllocator(num_pages), slots, page, maxp)
    sched = Scheduler(table, max_seq=max_seq,
                      max_tokens_in_flight=slots * (max_seq + 1),
                      max_queue=n_requests, max_preemptions=3)
    free0 = table.allocator.available
    terminal = {}                               # order -> status (driver view)

    def settle(order, status):
        assert order not in terminal, \
            f"order {order} terminal twice: {terminal[order]} then {status}"
        terminal[order] = status

    now = [0.0]
    next_rid = [0]

    def do_submit():
        if next_rid[0] >= n_requests:
            return
        rid = next_rid[0]
        next_rid[0] += 1
        r = req(draw(1, max(1, max_seq // 2)), draw(1, 12), rid=rid)
        if draw(0, 3) == 0:
            r.deadline_s = draw(1, 5) / 10.0
        order, accepted = sched.submit(r, arrival_s=now[0])
        if not accepted:
            settle(order, "REJECTED")

    def do_cancel():
        rid = draw(0, n_requests - 1)
        hit = sched.cancel(rid)
        if hit is None:
            return
        where, obj = hit
        if where == "queued":
            settle(obj.order, "CANCELLED")
        else:
            settle(sched.retire(obj, status="CANCELLED")["order"],
                   "CANCELLED")

    def do_tick():
        now[0] += draw(0, 3) / 10.0
        for e in sched.expire_queue(now[0]):
            settle(e.order, "TIMEOUT")
        for slot in list(sched.running):
            if slot.deadline_s is not None and now[0] > slot.deadline_s:
                settle(sched.retire(slot, status="TIMEOUT")["order"],
                       "TIMEOUT")

    def do_decode():
        admitted = sched.try_admit(now[0], arrived_before=now[0])
        for e in sched.drain_doomed():
            settle(e.order, "FAILED")
        for slot in admitted:
            slot.tokens.append(7)               # prefill's first token
            if len(slot.tokens) >= slot.total_budget:
                settle(sched.retire(slot)["order"], "FINISHED_BUDGET")
        chunk = draw(1, 6)
        prep = sched.prepare_decode(chunk)
        for slot in prep.runnable:
            emit = min(chunk, slot.total_budget - len(slot.tokens))
            slot.tokens.extend([7] * emit)
            if len(slot.tokens) >= slot.total_budget:
                settle(sched.retire(slot)["order"], "FINISHED_BUDGET")

    actions = (do_submit, do_submit, do_decode, do_decode, do_tick,
               do_cancel)
    for _ in range(n_events):
        actions[draw(0, len(actions) - 1)]()
        # mid-run invariants: slot/page consistency
        owned = [set(table.pages(s.index)) for s in sched.running]
        for i in range(len(owned)):
            for j in range(i + 1, len(owned)):
                assert not owned[i] & owned[j]
        assert sched.tokens_in_flight <= sched.max_tokens_in_flight

    # drain: shed the queue, then run whatever is resident to completion
    while next_rid[0] < n_requests:
        do_submit()
    sched.close_intake()
    for e in sched.flush_queue():
        settle(e.order, "REJECTED")
    guard = 0
    while not sched.idle:
        guard += 1
        assert guard < 10_000, "drain did not converge"
        if not sched.running and sched.queue:   # resume entries only
            admitted = sched.try_admit(now[0])
            for e in sched.drain_doomed():
                settle(e.order, "FAILED")
            for slot in admitted:
                slot.tokens.append(7)
                if len(slot.tokens) >= slot.total_budget:
                    settle(sched.retire(slot)["order"], "FINISHED_BUDGET")
            continue
        do_decode()
        # a fully stalled pack (all at the preemption bound) can't make
        # progress page-wise; force-fail the youngest, as the engine does
        prep = sched.prepare_decode(1)
        if (not prep.runnable and not prep.preempted and prep.stalled
                and not any(len(s.tokens) >= s.total_budget
                            for s in sched.running)):
            victim = max(prep.stalled, key=lambda s: s.order)
            settle(sched.retire(victim, status="FAILED")["order"], "FAILED")

    # exactly one terminal per submitted order, counters agree, no leaks
    assert set(terminal) == set(range(sched.submitted))
    counts = sched.terminal_counts()
    assert sum(counts.values()) == sched.submitted
    for status in counts:
        assert counts[status] == sum(1 for s in terminal.values()
                                     if s == status), (status, terminal)
    assert sched.tokens_in_flight == 0
    assert table.allocator.available == free0
    assert table.allocator.in_use == 0
    assert (table.table == kvc.TRASH_PAGE).all()


def test_lifecycle_machine_random():
    """Deterministic randomized sweep (runs with or without hypothesis)."""
    rng = np.random.RandomState(7)
    for _ in range(60):
        slots = int(rng.randint(1, 4))
        page = int(rng.choice([2, 4]))
        maxp = int(rng.randint(2, 6))
        _lifecycle_machine(
            lambda lo, hi: int(rng.randint(lo, hi + 1)),
            slots, page, maxp,
            n_requests=int(rng.randint(1, 10)),
            n_events=int(rng.randint(1, 60)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_lifecycle_machine_hypothesis(data):
        slots = data.draw(st.integers(1, 3))
        page = data.draw(st.sampled_from([2, 4]))
        maxp = data.draw(st.integers(2, 5))
        _lifecycle_machine(
            lambda lo, hi: data.draw(st.integers(lo, hi)),
            slots, page, maxp,
            n_requests=data.draw(st.integers(1, 8)),
            n_events=data.draw(st.integers(1, 40)))


# ---------------------------------------------------------------------------
# Fleet lifecycle state machine: the router over host-only fake replicas
# under random interleavings of submit / step / tick / cancel / replica
# kill — with hedging armed and migration-by-resume on every kill.  Every
# fleet request must settle EXACTLY ONCE, counters must agree with the
# settled statuses, and any tokens delivered must be the fakes'
# deterministic stream (resume/hedge/migration never fork it).
# ---------------------------------------------------------------------------
def _fleet_machine(draw, n_replicas, n_requests, n_events):
    from repro.fleet import DOWN, Router
    from repro.serve.scheduler import TERMINAL_STATUSES
    from test_fleet import FakeReplica

    now = [0.0]
    # max_queue >= 1: a replica that refuses EVERY submit forever would
    # livelock the workload itself (real engines always have some intake)
    reps = [FakeReplica(f"f{i}", capacity=draw(1, 2),
                        max_queue=draw(1, 3))
            for i in range(n_replicas)]
    router = Router(reps, policy=("jsq", "round_robin")[draw(0, 1)],
                    hedge_after_s=0.3, backoff_base_s=0.01,
                    backoff_cap_s=0.1,
                    max_pending=draw(1, 2 * n_replicas + 2),
                    seed=draw(0, 99), clock=lambda: now[0])
    orders = {}
    next_rid = [0]

    def do_submit():
        if next_rid[0] >= n_requests:
            return
        rid = next_rid[0]
        next_rid[0] += 1
        r = Request(prompt=np.arange(draw(1, 6), dtype=np.int32) + 1,
                    max_new_tokens=draw(1, 8), id=rid)
        r.priority = draw(0, 2)
        if draw(0, 3) == 0:
            r.deadline_s = draw(1, 6) / 10.0
        orders[rid] = router.submit(r, arrival_s=now[0])

    def do_step():
        router.step()

    def do_tick():
        now[0] += draw(0, 4) / 10.0

    def do_cancel():
        if next_rid[0]:
            router.cancel(draw(0, next_rid[0] - 1))

    def do_kill():
        live = [r for r in reps if r.state != DOWN]
        if live and draw(0, 2) == 0:
            live[draw(0, len(live) - 1)].force_crash()

    actions = (do_submit, do_submit, do_step, do_step, do_tick,
               do_cancel, do_kill)
    for _ in range(n_events):
        actions[draw(0, len(actions) - 1)]()
    while next_rid[0] < n_requests:
        do_submit()
    guard = 0
    while any(router.result(o) is None for o in orders.values()):
        guard += 1
        assert guard < 5000, "fleet machine did not converge"
        now[0] += 0.05                     # backoff + hedge timers advance
        router.step()

    results = {rid: router.result(o) for rid, o in orders.items()}
    assert all(res is not None for res in results.values())  # zero lost
    assert all(res["status"] in TERMINAL_STATUSES
               for res in results.values())
    counts = router.terminal_counts()
    assert sum(counts.values()) == n_requests, counts
    for status in counts:                   # counters == settled statuses:
        assert counts[status] == sum(       # nothing settled twice
            1 for res in results.values() if res["status"] == status), \
            (status, counts, results)
    assert router.idle and not router._leg_index
    for res in results.values():            # stream integrity across
        toks = res["tokens"]                # migration/hedging/cancel
        assert toks == [100 + i for i in range(len(toks))], res


def test_fleet_machine_random():
    """Deterministic randomized sweep (runs with or without hypothesis)."""
    rng = np.random.RandomState(11)
    for _ in range(40):
        _fleet_machine(
            lambda lo, hi: int(rng.randint(lo, hi + 1)),
            n_replicas=int(rng.randint(1, 4)),
            n_requests=int(rng.randint(1, 10)),
            n_events=int(rng.randint(1, 60)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.data())
    def test_fleet_machine_hypothesis(data):
        _fleet_machine(
            lambda lo, hi: data.draw(st.integers(lo, hi)),
            n_replicas=data.draw(st.integers(1, 3)),
            n_requests=data.draw(st.integers(1, 8)),
            n_events=data.draw(st.integers(1, 40)))
