"""Continuous-batching scheduler: FIFO token-budget admission, slot
lifecycle, and full-restoration invariants under randomized schedules."""
import numpy as np
import pytest

from repro.serve import kvcache as kvc
from repro.serve.engine import Request
from repro.serve.scheduler import Scheduler

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


def make_sched(*, slots=4, page=4, maxp=8, num_pages=None, max_seq=32,
               budget=None):
    num_pages = num_pages if num_pages is not None else slots * maxp + 1
    table = kvc.BlockTable(kvc.PageAllocator(num_pages), slots, page, maxp)
    return Scheduler(table, max_seq=max_seq,
                     max_tokens_in_flight=budget or slots * (max_seq + 1))


def req(s, new, rid=0):
    return Request(prompt=np.arange(s, dtype=np.int32) + 1,
                   max_new_tokens=new, id=rid)


# ---------------------------------------------------------------------------
# Directed tests
# ---------------------------------------------------------------------------
def test_fifo_admission_into_free_slots():
    sched = make_sched(slots=2)
    for i in range(4):
        sched.submit(req(8, 4, rid=i))
    admitted = sched.try_admit()
    assert [s.request.id for s in admitted] == [0, 1]
    assert sched.queue_depth == 2
    assert not sched.try_admit()            # no free slot
    res = sched.retire(admitted[0])
    assert res["id"] == 0
    nxt = sched.try_admit()
    assert [s.request.id for s in nxt] == [2]    # FIFO, into the freed slot


def test_token_budget_gates_admission():
    sched = make_sched(slots=4, budget=30)
    sched.submit(req(8, 6))                 # footprint 14
    sched.submit(req(8, 6))                 # 28 total
    sched.submit(req(8, 6))                 # would exceed 30
    admitted = sched.try_admit()
    assert len(admitted) == 2 and sched.tokens_in_flight == 28
    sched.retire(admitted[0])
    assert sched.tokens_in_flight == 14
    assert len(sched.try_admit()) == 1


def test_page_exhaustion_blocks_head_without_skipping():
    # 5 usable pages, page_size 4: a 17-position request needs 5 pages
    sched = make_sched(slots=2, page=4, maxp=5, num_pages=6, max_seq=20)
    sched.submit(req(16, 2, rid=0))         # 16 prompt + 1 -> 17 pos, 5 pages
    sched.submit(req(4, 2, rid=1))          # would fit 1 page — must NOT skip
    admitted = sched.try_admit()
    assert [s.request.id for s in admitted] == [0]
    assert not sched.try_admit()            # head (id=1) blocked: 0 pages free
    sched.retire(admitted[0])
    assert [s.request.id for s in sched.try_admit()] == [1]


def test_budget_clamped_to_cache_bound():
    sched = make_sched(max_seq=16)
    sched.submit(req(12, 50))
    slot = sched.try_admit()[0]
    assert slot.budget == 16 - 12 + 1       # batch-engine clamp rule


def test_arrival_gating():
    sched = make_sched()
    sched.submit(req(8, 4), arrival_s=1.0)
    assert not sched.try_admit(0.5, arrived_before=0.5)
    assert len(sched.try_admit(1.5, arrived_before=1.5)) == 1


def test_prompt_longer_than_max_seq_raises():
    sched = make_sched(max_seq=8)
    sched.submit(req(12, 2))
    with pytest.raises(ValueError, match="max_seq"):
        sched.try_admit()


def test_stats_shape():
    sched = make_sched()
    sched.submit(req(8, 4))
    sched.try_admit()
    st = sched.stats()
    for key in ("queue_depth", "running", "tokens_in_flight",
                "pages_in_use", "page_utilization", "submitted",
                "admitted", "retired", "peak_tokens_in_flight"):
        assert key in st
    assert st["running"] == 1 and st["queue_depth"] == 0


# ---------------------------------------------------------------------------
# Randomized schedule invariants
# ---------------------------------------------------------------------------
def _run_schedule(slots, page, maxp, max_seq, budget, reqs, steps_draw):
    """Drive submit/admit/decode/retire; check invariants at every step:

    * admissions strictly FIFO, never more running than slots;
    * token budget respected; no page owned by two slots;
    * every request eventually retires with exactly its clamped budget of
      tokens; the free list and tables are fully restored at the end.
    """
    num_pages = slots * maxp + 1
    sched = make_sched(slots=slots, page=page, maxp=maxp,
                       num_pages=num_pages, max_seq=max_seq, budget=budget)
    for i, r in enumerate(reqs):
        sched.submit(r)
    admitted_order = []
    retired = {}
    guard = 0
    while not sched.idle:
        guard += 1
        assert guard < 10_000, "schedule did not converge"
        for slot in sched.try_admit():
            admitted_order.append(slot.request.id)
            assert sched.tokens_in_flight <= sched.max_tokens_in_flight
        running = sched.running
        assert len(running) <= slots
        owned = [set(sched.table.pages(s.index)) for s in running]
        for i in range(len(owned)):
            for j in range(i + 1, len(owned)):
                assert not owned[i] & owned[j]
        if not running:
            assert not sched.queue, "stalled with work queued"
            break
        # emulate a decode chunk: each running slot emits some tokens
        for slot in list(running):
            emit = min(steps_draw(slot), slot.budget - len(slot.tokens))
            slot.tokens.extend([7] * emit)
            if len(slot.tokens) >= slot.budget:
                res = sched.retire(slot)
                retired[res["id"]] = res
    assert admitted_order == [r.id for r in reqs]      # strict FIFO
    assert set(retired) == {r.id for r in reqs}
    for r in reqs:
        clamp = max(1, min(r.max_new_tokens, max_seq - len(r.prompt) + 1))
        assert retired[r.id]["decode_len"] == clamp
    assert sched.tokens_in_flight == 0
    assert sched.table.allocator.available == num_pages - 1
    assert (sched.table.table == kvc.TRASH_PAGE).all()


def test_randomized_schedules():
    rng = np.random.RandomState(0)
    for _ in range(40):
        slots = int(rng.randint(1, 5))
        page = int(rng.choice([2, 4, 8]))
        maxp = int(rng.randint(2, 8))
        max_seq = page * maxp
        n = int(rng.randint(1, 12))
        reqs = [req(int(rng.randint(1, max_seq + 1)),
                    int(rng.randint(1, 20)), rid=i) for i in range(n)]
        _run_schedule(slots, page, maxp, max_seq,
                      slots * (max_seq + 1), reqs,
                      lambda slot: int(rng.randint(1, 9)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_schedule_invariants_hypothesis(data):
        slots = data.draw(st.integers(1, 4))
        page = data.draw(st.sampled_from([2, 4, 8]))
        maxp = data.draw(st.integers(2, 7))
        max_seq = page * maxp
        reqs = [req(data.draw(st.integers(1, max_seq)),
                    data.draw(st.integers(1, 20)), rid=i)
                for i in range(data.draw(st.integers(1, 10)))]
        chunk = data.draw(st.integers(1, 8))
        _run_schedule(slots, page, maxp, max_seq, slots * (max_seq + 1),
                      reqs, lambda slot: chunk)
