"""repro.fleet: replica health machine, router placement/hedging/
failover, and fleet-level oracle parity across a replica crash.

Host-only fakes drive the router logic (virtual clocks, deterministic
token streams); the device-backed tests at the bottom assert the real
property — greedy token identity to the B=1 oracle for requests migrated
across a mid-serving replica kill — and the per-engine metrics isolation
of two live engines sharing one Registry."""
import collections
import dataclasses

import numpy as np
import pytest

from repro.fleet import DEGRADED, DOWN, HEALTHY, EngineReplica, Router
from repro.obs import Obs
from repro.serve.engine import Request
from repro.serve.faults import FaultConfig, FaultInjector

# deterministic stream fakes emit: token i is always 100 + i, so any
# migrated/hedged/resumed request that finishes must carry exactly the
# prefix-closed stream — a host-only analogue of oracle parity
def _stream(n):
    return [100 + i for i in range(n)]


def req(rid, new=4, prompt_len=4, deadline_s=None, priority=0):
    return Request(prompt=np.arange(prompt_len, dtype=np.int32) + 1,
                   max_new_tokens=new, id=rid, deadline_s=deadline_s,
                   priority=priority)


# ---------------------------------------------------------------------------
# Fakes: a host-only engine (health tests) and replica (router tests)
# ---------------------------------------------------------------------------
class FakeEngine:
    """The slice of ContinuousEngine that EngineReplica touches."""

    def __init__(self):
        self.obs = Obs()
        self.anomalies = 0
        self._results = {}
        self._traces = {}
        self.step_fn = lambda: True
        self.max_seq = None

        class _Sched:
            queue_depth = 0
            running = ()
            queue = collections.deque()

            def drain_doomed(self):
                return []

            def close_intake(self):
                pass

        self.scheduler = _Sched()

    def step(self):
        return self.step_fn()

    def stats(self):
        return {}


class FakeReplica:
    """Host-only replica honouring the Router's interface.

    One token per step per running job; ``capacity`` running slots and a
    ``max_queue``-bounded wait queue (a full queue refuses the submit,
    like the real engine's bounded intake).  ``stalled`` replicas admit
    but never emit — hedge bait."""

    def __init__(self, name, capacity=2, max_queue=8, stalled=False):
        self.name = name
        self.state = HEALTHY
        self.salvaged = False
        self.capacity = capacity
        self.max_queue = max_queue
        self.stalled = stalled
        self._next = 0
        self.jobs = {}                 # local order -> job dict
        self.run = []                  # local orders occupying slots
        self.wait = []                 # local orders queued
        self.results = {}
        self.cancels = 0

    @property
    def live(self):
        return self.state != DOWN

    @property
    def load(self):
        return len(self.jobs)

    @property
    def max_seq(self):
        return None

    def submit(self, request, arrival_s=0.0, resume_tokens=None,
               preemptions=0):
        if not self.live:
            return -1, False
        local = self._next
        self._next += 1
        if len(self.wait) >= self.max_queue:
            return local, False        # bounded intake: transient refusal
        self.jobs[local] = {"req": request,
                            "tokens": list(resume_tokens or []),
                            "resume0": len(resume_tokens or []),
                            "budget": request.max_new_tokens,
                            "preempts": preemptions}
        self.wait.append(local)
        return local, True

    def step(self):
        if not self.live:
            return False
        progress = False
        while self.wait and len(self.run) < self.capacity:
            self.run.append(self.wait.pop(0))
            progress = True
        if self.stalled:
            return progress
        for local in list(self.run):
            job = self.jobs[local]
            job["tokens"].append(100 + len(job["tokens"]))
            progress = True
            if len(job["tokens"]) >= job["budget"]:
                self._finish(local, "FINISHED_BUDGET")
        return progress

    def _finish(self, local, status):
        job = self.jobs.pop(local)
        if local in self.run:
            self.run.remove(local)
        if local in self.wait:
            self.wait.remove(local)
        served = len(job["tokens"]) > job["resume0"] or status.startswith(
            "FINISHED")
        self.results[local] = {
            "id": job["req"].id, "tokens": list(job["tokens"]),
            "decode_len": len(job["tokens"]), "status": status,
            "preemptions": job["preempts"], "tokens_per_s": 0.0,
            "prefill_s": 0.0 if served else None, "decode_s": 0.0,
            "queue_s": 0.0, "latency_s": 0.0,
        }

    def result(self, local, pop=False):
        return self.results.pop(local, None) if pop \
            else self.results.get(local)

    def cancel(self, request_id):
        if not self.live:
            return False
        for local, job in list(self.jobs.items()):
            if job["req"].id == request_id:
                self.cancels += 1
                self._finish(local, "CANCELLED")
                return True
        return False

    def first_token_seen(self, local):
        job = self.jobs.get(local)
        if job is not None:
            return len(job["tokens"]) > job["resume0"]
        return local in self.results

    def drain(self):
        self.stalled = False
        while self.jobs:
            self.step()
        return []

    def force_crash(self, reason="forced crash"):
        self.state = DOWN

    def salvage(self):
        from repro.fleet import LostRequest, Salvage
        if self.state != DOWN:
            raise RuntimeError("salvage on a live fake")
        if self.salvaged:
            return Salvage({}, [])
        self.salvaged = True
        results, self.results = self.results, {}
        lost = [LostRequest(job["req"], list(job["tokens"]),
                            job["preempts"], local)
                for local, job in sorted(self.jobs.items())]
        self.jobs.clear()
        self.run, self.wait = [], []
        return Salvage(results, lost)

    def stats(self):
        return {"name": self.name, "state": self.state}


# ---------------------------------------------------------------------------
# EngineReplica health machine (fake engine, fake clock)
# ---------------------------------------------------------------------------
def _ticking_clock(step):
    t = [0.0]

    def clock():
        t[0] += step
        return t[0]
    return clock


def test_health_step_timeout_degrades_then_downs():
    rep = EngineReplica("r0", FakeEngine(), step_timeout_s=1.0,
                        down_after=3, clock=_ticking_clock(1.1))
    rep.step()                         # 1.2s elapsed between t0 and t1
    assert rep.state == DEGRADED and rep.consecutive_timeouts == 1
    rep.step()
    assert rep.state == DEGRADED
    rep.step()
    assert rep.state == DOWN and "hung" in rep.down_reason
    assert rep.engine.obs.registry.value("replica.step_timeouts") == 3
    assert rep.engine.obs.registry.value("replica.health") == 2.0
    assert not rep.step()              # DOWN replicas are inert


def test_health_anomaly_degrades_and_recovers():
    eng = FakeEngine()
    rep = EngineReplica("r0", eng, step_timeout_s=10.0, recover_after=2,
                        clock=_ticking_clock(0.001))
    rep.step()
    assert rep.state == HEALTHY
    eng.anomalies = 2                  # NaN guard tripped since last step
    rep.step()
    assert rep.state == DEGRADED
    rep.step()                         # clean step 1
    assert rep.state == DEGRADED
    rep.step()                         # clean step 2 -> recovered
    assert rep.state == HEALTHY
    assert rep.engine.obs.registry.value("replica.health") == 0.0


def test_health_engine_exception_is_a_crash():
    eng = FakeEngine()
    eng.step_fn = lambda: (_ for _ in ()).throw(RuntimeError("device lost"))
    rep = EngineReplica("r0", eng)
    assert not rep.step()
    assert rep.state == DOWN and "device lost" in rep.down_reason
    assert rep.engine.obs.registry.value("replica.crashes") == 1
    assert rep.submit(req(0)) == (-1, False)
    assert not rep.cancel(0) and rep.drain() == []


def test_health_injected_crash_and_hang_faults():
    inj = FaultInjector(FaultConfig(seed=0, crash_p=1.0))
    rep = EngineReplica("r0", FakeEngine(), faults=inj)
    rep.step()
    assert rep.state == DOWN and rep.down_reason == "injected crash"
    assert inj.stats()["crashes"] == 1

    inj2 = FaultInjector(FaultConfig(seed=0, hang_p=1.0, hang_s=0.01))
    rep2 = EngineReplica("r1", FakeEngine(), faults=inj2,
                         step_timeout_s=0.001, down_after=100)
    rep2.step()                        # real clock: the sleep IS the stall
    assert rep2.state == DEGRADED and rep2.consecutive_timeouts == 1
    assert inj2.stats()["hangs"] == 1


def test_salvage_only_when_down_and_exactly_once():
    import types
    eng = FakeEngine()
    rep = EngineReplica("r0", eng)
    with pytest.raises(RuntimeError, match="only DOWN"):
        rep.salvage()
    # unconsumed result + one queued entry + one running slot
    eng._results[0] = {"status": "FINISHED_BUDGET", "id": 0}
    eng.scheduler.queue.append(types.SimpleNamespace(
        request=req(1), resume_tokens=[7], preemptions=1, order=1))
    eng.scheduler.running = (types.SimpleNamespace(
        request=req(2), tokens=[5, 6], preemptions=0, order=2),)
    rep.force_crash("test kill")
    salvage = rep.salvage()
    assert set(salvage.results) == {0}
    assert [(l.local_order, l.resume_tokens) for l in salvage.lost] == \
        [(1, [7]), (2, [5, 6])]
    again = rep.salvage()              # idempotent: second call is empty
    assert not again.results and not again.lost


# ---------------------------------------------------------------------------
# Router: placement, retry, shedding (host fakes, virtual clock)
# ---------------------------------------------------------------------------
def _router(reps, **kw):
    now = [0.0]
    kw.setdefault("clock", lambda: now[0])
    return Router(reps, **kw), now


def test_jsq_places_on_least_loaded():
    r0, r1 = FakeReplica("r0"), FakeReplica("r1")
    r0.submit(req(100)), r0.submit(req(101))     # preload r0
    router, _ = _router([r0, r1])
    router.submit(req(0))
    assert any(j["req"].id == 0 for j in r1.jobs.values())
    assert not any(j["req"].id == 0 for j in r0.jobs.values())


def test_round_robin_rotates():
    r0, r1 = FakeReplica("r0", max_queue=8), FakeReplica("r1", max_queue=8)
    router, _ = _router([r0, r1], policy="round_robin")
    for i in range(4):
        router.submit(req(i))
    assert len(r0.jobs) == 2 and len(r1.jobs) == 2


def test_retry_backoff_then_placement():
    rep = FakeReplica("r0", max_queue=0)         # refuses everything
    router, now = _router([rep], backoff_base_s=0.01, backoff_cap_s=0.1)
    router.submit(req(0))
    st = router.stats()
    assert st["pending_depth"] == 1 and st["place_retries"] >= 1
    rep.max_queue = 4                            # pressure clears
    now[0] += 1.0                                # past any backoff
    router.step()
    assert router.stats()["pending_depth"] == 0
    assert any(j["req"].id == 0 for j in rep.jobs.values())


def test_overflow_sheds_lowest_priority_youngest():
    rep = FakeReplica("r0", max_queue=0)
    router, _ = _router([rep], max_pending=2)
    o_hi = router.submit(req(0, priority=5))
    o_mid = router.submit(req(1, priority=3))
    o_lo = router.submit(req(2, priority=0))     # overflow: shed the lowest
    res = router.result(o_lo)
    assert res is not None and res["status"] == "REJECTED"
    assert router.result(o_hi) is None and router.result(o_mid) is None
    assert router.stats()["shed"]["overflow"] == 1


def test_deadline_doomed_pending_is_shed():
    rep = FakeReplica("r0", max_queue=0)
    router, now = _router([rep])
    order = router.submit(req(0, deadline_s=0.1), arrival_s=0.0)
    assert router.result(order) is None
    now[0] += 1.0
    router.step()
    res = router.result(order)
    assert res["status"] == "REJECTED" and res["replica"] is None
    assert router.stats()["shed"]["deadline"] == 1


def test_all_replicas_down_fails_pending():
    r0, r1 = FakeReplica("r0"), FakeReplica("r1")
    r0.force_crash(), r1.force_crash()
    router, _ = _router([r0, r1])
    order = router.submit(req(0))
    assert router.result(order)["status"] == "FAILED"
    assert router.stats()["shed"]["no_live_replicas"] == 1


def test_cancel_pending_and_placed():
    slow = FakeReplica("r0", max_queue=0)
    router, _ = _router([slow])
    order = router.submit(req(0))                # unplaceable -> pending
    assert router.cancel(0)
    assert router.result(order)["status"] == "CANCELLED"
    slow.max_queue = 4
    order1 = router.submit(req(1, new=8))
    router.step()
    assert router.cancel(1)                      # lives on the replica now
    router.step()                                # collect the terminal
    assert router.result(order1)["status"] == "CANCELLED"


def test_closed_intake_rejects_immediately():
    router, _ = _router([FakeReplica("r0")])
    router.intake_closed = True
    order = router.submit(req(0))
    res = router.result(order)
    assert res["status"] == "REJECTED" and res["migrations"] == 0
    # a closed-intake reject is not a shed (no reason counted)
    assert all(v == 0 for v in router.stats()["shed"].values())


def test_submit_rejects_prompt_over_fleet_max_seq():
    class _Bounded(FakeReplica):
        @property
        def max_seq(self):
            return 8

    router, _ = _router([_Bounded("r0")])
    with pytest.raises(ValueError, match="max_seq"):
        router.submit(req(0, prompt_len=12))


# ---------------------------------------------------------------------------
# Router: hedging and failover (host fakes)
# ---------------------------------------------------------------------------
def test_hedge_fires_and_first_winner_settles_once():
    slow = FakeReplica("r0", stalled=True)       # admits, never emits
    fast = FakeReplica("r1")
    router, now = _router([slow, fast], hedge_after_s=0.1)
    order = router.submit(req(0, new=3))
    router.step()                                # placed on r0 (name tie)
    assert any(j["req"].id == 0 for j in slow.jobs.values())
    now[0] += 0.5
    router.step()                                # past threshold -> hedge
    st = router.stats()
    assert st["hedges"] == 1
    for _ in range(6):
        router.step()
    res = router.result(order)
    assert res is not None and res["status"] == "FINISHED_BUDGET"
    assert res["replica"] == "r1" and res["tokens"] == _stream(3)
    assert router.stats()["hedge_wins"] == {"primary": 0, "hedge": 1}
    assert sum(router.terminal_counts().values()) == 1   # settled ONCE
    assert slow.cancels == 1                     # loser leg cancelled
    assert not slow.results and not router._zombies      # zombie drained


def test_hedge_waits_for_ttft_samples_when_adaptive():
    slow = FakeReplica("r0", stalled=True)
    fast = FakeReplica("r1")
    router, now = _router([slow, fast], hedge_min_samples=8)
    router.submit(req(0))
    now[0] += 100.0
    router.step()                                # no p99 yet -> no hedge
    assert router.stats()["hedges"] == 0


def test_failover_migrates_with_resume_and_stream_is_identical():
    r0, r1 = FakeReplica("r0", capacity=1), FakeReplica("r1", capacity=1)
    router, _ = _router([r0, r1])
    order_a = router.submit(req(0, new=6))       # -> r0 (name tie)
    order_b = router.submit(req(1, new=2))       # -> r1 (jsq)
    router.step()
    router.step()                                # A has 2 tokens on r0
    job_a = next(iter(r0.jobs.values()))
    assert job_a["tokens"] == _stream(2)
    r0.force_crash()
    guard = 0
    while router.result(order_a) is None or router.result(order_b) is None:
        guard += 1
        assert guard < 50, "failover did not converge"
        router.step()
    res_a = router.result(order_a)
    assert res_a["status"] == "FINISHED_BUDGET"
    assert res_a["replica"] == "r1" and res_a["migrations"] == 1
    assert res_a["tokens"] == _stream(6)         # resumed, not restarted
    st = router.stats()
    assert st["failovers"] == 1 and st["migrated_requests"] == 1
    assert router.result(order_b)["status"] == "FINISHED_BUDGET"
    assert sum(router.terminal_counts().values()) == 2


def test_failover_surfaces_salvaged_terminal_results():
    r0, r1 = FakeReplica("r0"), FakeReplica("r1", max_queue=0)
    router, _ = _router([r0, r1])
    order = router.submit(req(0, new=1))
    r0.step()                                    # finishes INSIDE the replica
    assert r0.results                            # ...unconsumed by the router
    r0.force_crash()
    router.step()                                # failover surfaces it
    res = router.result(order)
    assert res["status"] == "FINISHED_BUDGET" and res["replica"] == "r0"
    assert res["tokens"] == _stream(1)
    assert sum(router.terminal_counts().values()) == 1


def test_generate_over_fakes_orders_results():
    reps = [FakeReplica("r0"), FakeReplica("r1")]
    router = Router(reps, seed=0)
    reqs = [req(i, new=2 + i % 3) for i in range(6)]
    results = router.generate(reqs)
    assert [r["id"] for r in results] == list(range(6))
    assert all(r["status"] == "FINISHED_BUDGET" for r in results)
    assert all(r["tokens"] == _stream(len(r["tokens"])) for r in results)


# ---------------------------------------------------------------------------
# Device-backed: metrics isolation + failover oracle parity
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_setup():
    import jax
    from repro.configs.registry import get_smoke_config
    from repro.models.registry import build_model
    cfg = get_smoke_config("tinyllama-1.1b").replace(dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _tiny_reqs(specs, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(prompt=rng.randint(1, 500, size=s).astype(np.int32),
                    max_new_tokens=n, id=i)
            for i, (s, n) in enumerate(specs)]


def test_two_live_engines_metrics_isolation(tiny_setup):
    """Two engines share one Registry through scoped views: every series
    carries its replica label, per-engine stats() stay disjoint, and the
    shared TraceStore keeps same-order traces apart."""
    from repro.serve.engine import ContinuousEngine
    cfg, params = tiny_setup
    root = Obs()
    engs = [ContinuousEngine(cfg, params, max_slots=2, max_seq=32,
                             page_size=4, decode_chunk=4,
                             obs=root.scoped(replica=f"e{i}"))
            for i in range(2)]
    reqs = _tiny_reqs([(8, 3), (10, 4), (9, 2), (12, 5)])
    orders = [[], []]
    for i, eng in enumerate(engs):
        for r in reqs[2 * i:2 * i + 2]:
            orders[i].append(eng.submit(r))
    while not all(e.scheduler.idle for e in engs):   # both LIVE at once
        for eng in engs:
            eng.step()
    reg = root.registry
    for i, eng in enumerate(engs):
        assert reg.value("sched.submitted", replica=f"e{i}") == 2
        assert reg.value("sched.retired", replica=f"e{i}") == 2
        assert eng.stats()["retired"] == 2           # reads its own scope
    with pytest.raises(KeyError):
        reg.value("sched.submitted")                 # no unlabelled bleed
    for fname, _ in reg.items():
        if fname.startswith(("sched.", "engine.", "pool.", "trace.")):
            assert "replica=" in fname, fname
    done = list(root.traces.completed)
    assert len(done) == 4
    assert {t.replica for t in done} == {"e0", "e1"}
    by = {(t.replica, t.order) for t in done}
    assert by == {("e0", 0), ("e0", 1), ("e1", 0), ("e1", 1)}


def test_fleet_failover_oracle_parity(tiny_setup):
    """Kill a replica mid-serving; every finished request — including the
    migrated ones — must be token-identical to its B=1 oracle."""
    from repro.serve.engine import ContinuousEngine, Engine
    cfg, params = tiny_setup
    reqs = _tiny_reqs([(12, 10), (10, 12), (14, 9), (9, 11), (11, 10),
                       (13, 8)])
    oracle = Engine(cfg, params, max_batch=1, max_seq=32)
    want = [oracle.generate([r])[0]["tokens"] for r in reqs]
    root = Obs()
    pool = [EngineReplica(
        f"r{i}", ContinuousEngine(cfg, params, max_slots=2, max_seq=32,
                                  page_size=4, decode_chunk=3,
                                  obs=root.scoped(replica=f"r{i}")))
        for i in range(2)]
    router = Router(pool, seed=0, obs=root)
    orders = [router.submit(r) for r in reqs]
    victim = pool[0]
    free0 = {rep.name: rep.engine.block_table.allocator.available
             for rep in pool}
    killed, guard = False, 0
    while any(router.result(o) is None for o in orders):
        guard += 1
        assert guard < 5000, "fleet run did not converge"
        router.step()
        if not killed and any(s.tokens
                              for s in victim.engine.scheduler.running):
            victim.force_crash("test kill")
            killed = True
    assert killed and victim.salvaged
    results = [router.result(o) for o in orders]
    migrated = [r for r in results if r["migrations"] > 0]
    assert migrated, "nothing migrated across the kill"
    for res, toks in zip(results, want):
        assert res["status"] in ("FINISHED_EOS", "FINISHED_BUDGET"), res
        assert res["tokens"] == toks, (res, toks)
    survivor = pool[1]
    assert survivor.engine.block_table.allocator.available == \
        free0[survivor.name]
    assert survivor.engine.scheduler.tokens_in_flight == 0
    assert sum(router.terminal_counts().values()) == len(reqs)
