"""End-to-end training driver: a ~10M-param block-circulant LM trained for a
few hundred steps on the deterministic synthetic pipeline, with checkpoints,
resume, NaN guard, and the paper's compression on every projection.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--dense]
"""
import argparse

import jax

from repro.configs.base import (ArchConfig, AttentionConfig,
                                CompressionConfig)
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--bayesian", action="store_true")
    ap.add_argument("--workdir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    comp = (CompressionConfig(enabled=False) if args.dense else
            CompressionConfig(enabled=True, block_ffn=32, block_attn=32))
    cfg = ArchConfig(
        name="lm-10m", num_layers=4, d_model=256, d_ff=1024, vocab_size=4096,
        attention=AttentionConfig(num_heads=8, num_kv_heads=4, head_dim=32),
        compression=comp, remat="none")

    data = SyntheticLM(cfg, batch=16, seq=128, seed=0)
    trainer = Trainer(
        cfg, adamw.AdamWConfig(lr=1e-3, quantize_moments=False),
        workdir=args.workdir, data_fn=data, total_steps=args.steps,
        ckpt_every=100, log_every=10, bayesian_mode=args.bayesian)
    state = trainer.run()
    n = sum(p.size for p in jax.tree.leaves(state["params"]))
    print(f"done: {int(state['step'])} steps, {n:,} params, "
          f"final loss {trainer.history[-1]['loss']:.4f}, "
          f"skipped {int(state['skipped'])} bad steps")


if __name__ == "__main__":
    main()
