"""Quickstart: the paper's block-circulant compression as a first-class
feature of a transformer LM, in four steps.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.core import circulant as cc
from repro.models.registry import build_model

# 1. A single block-circulant layer: three equivalent lowerings ------------
key = jax.random.PRNGKey(0)
w = cc.init_block_circulant(key, n_in=512, n_out=256, k=64)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 512))
y_fft = cc.bc_matmul_fft(x, w, 256)                 # train path (O(n log n))
y_spec = cc.bc_matmul_spectral(x, cc.spectral_cache(w), 64, 256)  # serve path
y_ref = cc.bc_matmul_direct(x, w, 256)              # dense oracle
print(f"paths agree: {float(jnp.abs(y_fft - y_ref).max()):.2e} "
      f"(spectral {float(jnp.abs(y_spec - y_ref).max()):.2e})")
print(f"params: dense {512*256:,} -> circulant {w.size:,} "
      f"({512*256 // w.size}x compression)")

# 2. A full model with compression on ---------------------------------------
cfg = get_smoke_config("qwen3-4b")                  # reduced same-family cfg
model = build_model(cfg)
params = model.init(key)
n = sum(p.size for p in jax.tree.leaves(params))

cfg_dense = get_smoke_config("qwen3-4b", compress=False)
n_dense = sum(p.size for p in jax.tree.leaves(
    build_model(cfg_dense).init(key)))
print(f"model params: dense {n_dense:,} -> block-circulant {n:,}")

# 3. Forward + loss ---------------------------------------------------------
tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab_size)
logits, aux = model.forward_train(params, {"tokens": tokens})
print(f"logits {logits.shape}, finite={bool(jnp.isfinite(logits).all())}")

# 4. Serving: prefill + a few decode steps ----------------------------------
cache = model.init_cache(2, 40, dtype=jnp.float32)
lg, cache = model.prefill(params, {"tokens": tokens}, cache)
tok = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
for t in range(32, 36):
    lg, cache = model.decode_step(params, tok, cache, jnp.int32(t))
    tok = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
print("decoded 4 tokens:", tok.ravel().tolist())
