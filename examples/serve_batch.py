"""End-to-end serving driver (the paper's deployment mode: batched
inference on a compressed model): batched requests through the engine's
prefill + ring/linear-KV decode, with cached spectral weights.

  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models.registry import build_model
from repro.serve.engine import Engine, Request


def main():
    cfg = get_smoke_config("mixtral-8x7b")          # MoE + SWA family
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_batch=4, max_seq=128)

    rng = np.random.RandomState(0)
    # prompts cover the smoke sliding window (16): the ring-buffer prefill
    # keeps the window tail and needs S >= window
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size,
                                       size=rng.randint(16, 24)).astype(np.int32),
                    max_new_tokens=12, id=i) for i in range(10)]
    t0 = time.time()
    results = engine.generate(reqs)
    dt = time.time() - t0
    toks = sum(r["decode_len"] for r in results)
    for r in results[:4]:
        print(f"req {r['id']}: {r['tokens']}  ({r['tokens_per_s']:.0f} tok/s,"
              f" prefill {r['prefill_s']*1e3:.0f}ms /"
              f" decode {r['decode_s']*1e3:.0f}ms)")
    print(f"... {len(results)} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s on 1 CPU core)")


if __name__ == "__main__":
    main()
