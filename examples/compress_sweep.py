"""The paper's co-optimization loop in miniature: sweep the block size k,
train each model, and print the accuracy/compression frontier (paper Fig. 5
loop: "model selection and optimization" against an accuracy requirement).

  PYTHONPATH=src python examples/compress_sweep.py
"""
from benchmarks.bench_accuracy_tradeoff import main

if __name__ == "__main__":
    main()
